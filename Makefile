PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-matrix ci cli-smoke bench-serve bench-pp bench-obs bench-ft docs-check deps deps-dev

# tier-1 verification
test:
	python -m pytest -x -q

# cross-axis parallelism parity matrix: (dp, tp, pp) x grad_accum x schedule
# cells vs the fused single-device step on the forced-host mesh
test-matrix:
	python -m pytest -x -q tests/test_parallel_matrix.py

# execute every fenced python block in docs/*.md (CPU-safe) so docs can't rot
docs-check:
	python tools/docs_check.py

# end-to-end CPU smoke of the unified CLI (train + serve workloads)
cli-smoke:
	python -m repro train --arch qwen2-0.5b --smoke --steps 8 \
		--set train.seq_len=64 --set train.log_every=4
	python -m repro serve --arch qwen2-0.5b --smoke --continuous \
		--requests 8 --max-new 8 --rate 500

ci: test test-matrix docs-check cli-smoke bench-serve bench-pp bench-obs bench-ft

# decode-latency-vs-max_len sweep (paged vs gathered), flash-vs-dense prefill
# sweep (op-count gated, measured parity), cold-vs-warm start-to-first-token
# through the persistent compile cache, + continuous-vs-static; persists the
# perf trajectory to BENCH_serve.json
bench-serve:
	python benchmarks/serve_bench.py --smoke --sweep --prefill-sweep \
		--coldstart --router-sweep --out BENCH_serve.json

# pipeline-schedule sweep (simkit + real executor on a pp=2 host mesh);
# asserts pipelined-vs-reference loss parity and persists BENCH_pp.json
bench-pp:
	python benchmarks/pp_bench.py --out BENCH_pp.json

# observability overhead gate: full metrics + online-detection stack vs a
# bare train loop; asserts < 5% median step overhead, persists BENCH_obs.json
bench-obs:
	python benchmarks/obs_bench.py --out BENCH_obs.json

# fault-tolerance gate: crash -> restore -> replay must complete with the
# fault-free final loss; recovery overhead + checkpoint stall are bounded
# and persisted to BENCH_ft.json
bench-ft:
	python benchmarks/ft_bench.py --out BENCH_ft.json

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
