PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test ci bench-serve deps deps-dev

# tier-1 verification
test:
	python -m pytest -x -q

ci: test

# decode-latency-vs-max_len sweep (paged vs gathered) + continuous-vs-static;
# persists the perf trajectory to BENCH_serve.json
bench-serve:
	python benchmarks/serve_bench.py --smoke --sweep --out BENCH_serve.json

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
