PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test ci bench-serve docs-check deps deps-dev

# tier-1 verification
test:
	python -m pytest -x -q

# execute every fenced python block in docs/*.md (CPU-safe) so docs can't rot
docs-check:
	python tools/docs_check.py

ci: test docs-check

# decode-latency-vs-max_len sweep (paged vs gathered) + continuous-vs-static;
# persists the perf trajectory to BENCH_serve.json
bench-serve:
	python benchmarks/serve_bench.py --smoke --sweep --out BENCH_serve.json

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
