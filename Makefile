PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test ci bench-serve deps deps-dev

# tier-1 verification
test:
	python -m pytest -x -q

ci: test

bench-serve:
	python benchmarks/serve_bench.py --smoke

deps:
	pip install -r requirements.txt

deps-dev:
	pip install -r requirements-dev.txt
