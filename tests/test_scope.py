"""MegaScope: probe capture + compression, perturbation injection, PCA,
generation records, dashboard artifact, and zero-overhead-when-off."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.scope import (
    PerturbSpec,
    ProbeSpec,
    ScopeCollector,
    generate_with_scope,
    pca_fit,
    pca_project,
    write_dashboard,
)
from repro.core.scope.collector import _bitflip
from repro.core.scope.compress import histogram, stats_of, subsample
from repro.models import get_model, make_batch
from repro.models import lm as lm_mod


@pytest.fixture(scope="module")
def qwen_smoke():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------- compress ---


def test_stats_match_numpy():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    s = stats_of(x)
    xn = np.asarray(x)
    assert np.isclose(float(s["mean"]), xn.mean(), atol=1e-6)
    assert np.isclose(float(s["max"]), xn.max(), atol=1e-6)
    assert np.isclose(float(s["l2"]), np.linalg.norm(xn), rtol=1e-5)


def test_histogram_counts_total():
    x = jax.random.normal(jax.random.PRNGKey(1), (100,))
    h = histogram(x, bins=16)
    assert int(h["hist"].sum()) == 100


def test_subsample_bounded():
    x = jnp.ones((64, 256))
    s = subsample(x, k=16)
    assert s.shape[0] <= 16 and s.shape[1] <= 16


# --------------------------------------------------------------- capture ---


def test_capture_through_scanned_layers(qwen_smoke):
    cfg, params = qwen_smoke
    scope = ScopeCollector(probes=[ProbeSpec("mlp_hidden", "stats"),
                                   ProbeSpec("att_resid", "stats")])
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))
    _, metrics = jax.jit(
        lambda p, b: lm_mod.loss_fn(cfg, p, b, scope)
    )(params, batch)
    caps = metrics["captures"]["seg0"]
    assert "mlp_hidden.stats" in caps
    # stacked over layers
    assert caps["mlp_hidden.stats"]["mean"].shape == (cfg.num_layers,)
    assert np.all(np.isfinite(np.asarray(caps["mlp_hidden.stats"]["l2"])))


def test_no_probes_means_no_capture_aux(qwen_smoke):
    cfg, params = qwen_smoke
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))
    _, metrics = jax.jit(lambda p, b: lm_mod.loss_fn(cfg, p, b))(params, batch)
    assert "captures" not in metrics


# -------------------------------------------------------------- perturb ----


def test_gaussian_perturbation_changes_loss(qwen_smoke):
    cfg, params = qwen_smoke
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(2))
    loss0, _ = lm_mod.loss_fn(cfg, params, batch)
    scope = ScopeCollector(
        perturbs=[PerturbSpec("att_resid", "gaussian", amount=0.5)]
    )
    loss1, _ = lm_mod.loss_fn(cfg, params, batch, scope)
    assert not np.isclose(float(loss0), float(loss1))


def test_layer_targeted_offset_perturbs_single_layer(qwen_smoke):
    cfg, params = qwen_smoke
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(3))
    loss0, _ = lm_mod.loss_fn(cfg, params, batch)
    one = ScopeCollector(perturbs=[PerturbSpec("ffn_resid", "offset", 1.0, layer=0)])
    none = ScopeCollector(perturbs=[PerturbSpec("ffn_resid", "offset", 1.0, layer=99)])
    loss_one, _ = lm_mod.loss_fn(cfg, params, batch, one)
    loss_none, _ = lm_mod.loss_fn(cfg, params, batch, none)
    assert abs(float(loss_one) - float(loss0)) > 1e-4   # hit layer -> effect
    assert np.isclose(float(loss_none), float(loss0), atol=1e-6)  # miss -> none


def test_bitflip_expected_rate():
    x = jnp.zeros((64, 64), jnp.float32)
    y = _bitflip(x, 0.01, jax.random.PRNGKey(0))
    bits = np.asarray(
        jax.lax.bitcast_convert_type(y, jnp.uint32)
    )
    n_flipped = np.unpackbits(bits.view(np.uint8)).sum()
    expect = 64 * 64 * 32 * 0.01
    assert 0.5 * expect < n_flipped < 1.5 * expect


def test_bitflip_zero_prob_identity():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    y = _bitflip(x, 0.0, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------ pca ----


def test_pca_recovers_planted_direction():
    rng = np.random.default_rng(0)
    d = rng.normal(size=(64,))
    d /= np.linalg.norm(d)
    x = rng.normal(size=(200, 1)) * 5 @ d[None, :] + rng.normal(size=(200, 64)) * 0.1
    fit = pca_fit(x, k=2)
    cos = abs(fit["components"][0] @ d)
    assert cos > 0.98
    proj = pca_project(x, fit)
    assert proj.shape == (200, 2)


# ----------------------------------------------------------- generation ----


def test_generation_records_and_dashboard(tmp_path, qwen_smoke):
    cfg, params = qwen_smoke
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab_size)
    scope = ScopeCollector(probes=[ProbeSpec("final_hidden", "stats")])
    records, toks = generate_with_scope(cfg, params, prompt, n_steps=4, scope=scope)
    assert len(records) == 4 and toks.shape == (1, 4)
    for r in records:
        assert 0 <= r.prob <= 1
        assert len(r.topk_tokens) == 8
        assert abs(sum(r.topk_probs)) <= 1.001
    out = write_dashboard(
        tmp_path / "dash.html", records,
        attention=np.eye(8), pca_points=np.random.default_rng(0).normal(size=(8, 2)),
        meta="qwen2-0.5b-smoke",
    )
    html = out.read_text()
    assert "MegaScope dashboard" in html and "DATA" in html
    assert len(html) > 2000
