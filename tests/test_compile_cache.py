"""CompileCache: key sensitivity, hit/miss/put accounting, corrupt-entry
fail-open, layout-version isolation, ``aot_compile`` composition, and the
restart story itself — a second *process* reusing the first one's entries."""

import os
import subprocess
import sys
from dataclasses import dataclass

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.compile_cache import (
    _MAGIC,
    CompileCache,
    aot_compile,
    mesh_descriptor,
)

AV = (
    jax.ShapeDtypeStruct((4,), jnp.float32),
    jax.ShapeDtypeStruct((4,), jnp.float32),
)


def _jitted():
    return jax.jit(lambda a, b: a * 2.0 + b)


# ---------------------------------------------------------------- keys ---


def test_key_stable_and_sensitive(tmp_path):
    cc = CompileCache(tmp_path)
    k1 = cc.key(bucket=("decode", 8), donate=[1], mesh="nomesh/cpux1")
    k2 = cc.key(mesh="nomesh/cpux1", donate=[1], bucket=("decode", 8))
    assert k1 == k2, "key must not depend on kwarg order"
    assert cc.key(bucket=("decode", 16), donate=[1],
                  mesh="nomesh/cpux1") != k1
    assert cc.key(bucket=("decode", 8), donate=[],
                  mesh="nomesh/cpux1") != k1


def test_key_canonicalizes_dataclasses(tmp_path):
    @dataclass
    class Cfg:
        n: int = 4
        name: str = "x"

    cc = CompileCache(tmp_path)
    assert cc.key(model=Cfg()) == cc.key(model={"n": 4, "name": "x"})
    assert cc.key(model=Cfg(n=5)) != cc.key(model=Cfg(n=4))


def test_mesh_descriptor_nomesh():
    d = mesh_descriptor(None)
    assert d.startswith("nomesh/") and jax.default_backend() in d


# ------------------------------------------------------- load/put/compile ---


def test_compile_miss_then_hit_roundtrip(tmp_path):
    jf = _jitted()
    cc = CompileCache(tmp_path)
    key = cc.key(bucket="t1")
    exe, hit = cc.compile(key, jf.lower(*AV))
    assert not hit and cc.stats.puts == 1 and cc.stats.misses == 1

    cc2 = CompileCache(tmp_path)  # fresh instance, same directory
    exe2, hit2 = cc2.compile(key, jf.lower(*AV))
    assert hit2 and cc2.stats.hits == 1 and cc2.stats.puts == 0
    a = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(exe2(a, a)), np.asarray(a * 3.0))


def test_corrupt_entry_fails_open_and_unlinks(tmp_path):
    jf = _jitted()
    cc = CompileCache(tmp_path)
    key = cc.key(bucket="t2")
    cc.compile(key, jf.lower(*AV))
    path = cc._path(key)
    assert path.exists()

    # truncate mid-payload: magic is intact, pickle is not
    blob = path.read_bytes()
    path.write_bytes(blob[: len(blob) // 2])
    assert cc.load(key) is None
    assert cc.stats.errors == 1
    assert not path.exists(), "corrupt entry must be dropped"

    # bad magic: an alien file in the cache dir
    path.write_bytes(b"XXXX" + blob[len(_MAGIC):])
    assert cc.load(key) is None and cc.stats.errors == 2

    # after both failures a plain recompile repopulates the slot
    exe, hit = cc.compile(key, jf.lower(*AV))
    assert not hit and path.exists()


def test_version_bump_misses_old_entries(tmp_path):
    jf = _jitted()
    cc = CompileCache(tmp_path)
    key = cc.key(bucket="t3")
    cc.compile(key, jf.lower(*AV))

    class V2(CompileCache):
        VERSION = 2

    cc2 = V2(tmp_path)
    # same parts hash differently *and* live in a different directory —
    # a layout bump can never deserialize a v1 entry
    assert cc2.key(bucket="t3") != key
    assert cc2.load(cc2.key(bucket="t3")) is None
    assert "v1" in str(cc._path(key)) and "v2" in str(cc2._path(key))


# ----------------------------------------------------------- aot_compile ---


def test_aot_compile_without_cache(tmp_path):
    exe, hit = aot_compile(_jitted(), AV, cache=None, key_parts={})
    assert not hit
    a = jnp.ones(4, jnp.float32)
    np.testing.assert_allclose(np.asarray(exe(a, a)), 3.0)


def test_aot_compile_hit_skips_lowering(tmp_path):
    cc = CompileCache(tmp_path)
    parts = {"bucket": ("decode", 4), "donate": []}
    exe1, hit1 = aot_compile(_jitted(), AV, cache=cc, key_parts=parts)
    assert not hit1 and cc.stats.puts == 1

    class Boom:
        def lower(self, *a):  # a hit must never trace/lower
            raise AssertionError("lowered on a hit")

    exe2, hit2 = aot_compile(Boom(), AV, cache=cc, key_parts=parts)
    assert hit2
    a = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(exe2(a, a)), np.asarray(exe1(a, a)))


# -------------------------------------------------------- cross-process ---

_CHILD = """
import sys
import jax, jax.numpy as jnp
from repro.core.compile_cache import CompileCache, aot_compile

cc = CompileCache(sys.argv[1])
av = (jax.ShapeDtypeStruct((4,), jnp.float32),) * 2
exe, hit = aot_compile(jax.jit(lambda a, b: a * 2.0 + b), av,
                       cache=cc, key_parts={"bucket": "xproc"})
out = exe(jnp.arange(4, dtype=jnp.float32), jnp.ones(4, jnp.float32))
print("HIT" if hit else "MISS", [float(x) for x in out])
"""


def test_cross_process_reuse(tmp_path):
    """The actual restart scenario: process 2 must hit entries process 1
    wrote, and the deserialized executable must compute the same thing."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [os.path.abspath("src"), env.get("PYTHONPATH", "")])
    )

    def run():
        r = subprocess.run(
            [sys.executable, "-c", _CHILD, str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=300,
        )
        assert r.returncode == 0, r.stderr
        return r.stdout.strip()

    first, second = run(), run()
    assert first.startswith("MISS") and second.startswith("HIT")
    assert first.split(" ", 1)[1] == second.split(" ", 1)[1]
