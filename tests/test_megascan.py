"""MegaScan: tracer, chrome export, dependency reconstruction, clock
alignment, and 3-stage straggler detection (paper §3.2)."""

import json
import time

import numpy as np
import pytest

from repro.core.simkit.engine import FaultModel
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing import (
    ClockModel,
    Tracer,
    align_clocks,
    apply_alignment,
    detect,
    from_chrome,
    gather_traces,
    reconstruct_collectives,
    simulate_trace,
    to_chrome,
)

TOPO = Topology(dp=2, pp=2, tp=2)
PROF = ModelProfile(fwd_time=1e-3, bwd_time=2e-3)


def _trace(faults=None, clocks=None, n_iters=2, topo=TOPO):
    return simulate_trace(
        topo, PROF, n_micro=4, n_iters=n_iters,
        faults=faults, clocks=clocks or ClockModel(seed=3),
    )


# ---------------------------------------------------------------- tracer ---


def test_tracer_scope_and_gather():
    tr0, tr1 = Tracer(rank=0), Tracer(rank=1)
    with tr0.scope("fwd", mb=0, op="fwd"):
        time.sleep(0.002)
    with tr1.scope("allreduce", kind="coll", group=(0, 1), bytes=1024):
        time.sleep(0.001)
    merged = gather_traces([tr0, tr1])
    assert len(merged) == 2
    assert merged[0].dur >= 0.002
    assert any(e.kind == "coll" and e.args["group"] == (0, 1) for e in merged)


def test_tracer_disabled_is_zero_cost_path():
    tr = Tracer(rank=0, enabled=False)
    with tr.scope("x"):
        pass
    assert tr.events == []


def test_chrome_roundtrip():
    events, _ = _trace()
    doc = to_chrome(events)
    json.dumps(doc)  # valid JSON
    assert any(e["ph"] == "M" for e in doc["traceEvents"])  # process names
    back = from_chrome(doc)
    assert len(back) == len(events)
    e0, b0 = events[0], back[0]
    assert abs(e0.ts - b0.ts) < 1e-9 and e0.rank == b0.rank


# --------------------------------------------- dependency reconstruction ---


def test_collective_matching_complete():
    events, _ = _trace(n_iters=1)
    instances = reconstruct_collectives(events)
    assert instances
    for inst in instances:
        assert set(inst.members) == set(inst.key[0])  # all participants found
    # every coll event got a related_sync_op annotation
    for e in events:
        if e.kind == "coll":
            assert "related_sync_op" in e.args


# ----------------------------------------------------------- alignment ----


def test_clock_alignment_recovers_offsets():
    clocks = ClockModel(offset_sigma=20e-3, drift_sigma=1e-4, read_noise=1e-6, seed=7)
    events, truth = _trace(clocks=clocks)
    aligned = apply_alignment(events, align_clocks(events))
    # after alignment, matched collective instances end nearly simultaneously
    insts = reconstruct_collectives(aligned)
    spreads = [
        max(i.ends.values()) - min(i.ends.values()) for i in insts if len(i.members) > 1
    ]
    assert np.median(spreads) < 5e-4, np.median(spreads)
    # and raw (unaligned) spreads are much worse
    raw = [
        max(i.ends.values()) - min(i.ends.values())
        for i in reconstruct_collectives(events) if len(i.members) > 1
    ]
    assert np.median(raw) > 5 * np.median(spreads)


# ----------------------------------------------------------- detection ----


def test_detects_downclocked_rank():
    faults = FaultModel(compute_slowdown={5: 0.5})  # rank 5 at half speed
    events, truth = _trace(faults=faults)
    aligned = apply_alignment(events, align_clocks(events))
    diag = detect(aligned, TOPO)
    assert diag.slow_ranks == [5], diag.summary()


def test_no_false_positive_on_healthy_run():
    events, _ = _trace(faults=FaultModel(jitter=0.02, seed=11))
    aligned = apply_alignment(events, align_clocks(events))
    diag = detect(aligned, TOPO)
    assert diag.slow_ranks == [], diag.summary()


def test_detects_degraded_link():
    topo = Topology(dp=1, pp=4, tp=1)
    faults = FaultModel(link_slowdown={(1, 2): 0.25, (2, 1): 0.25})
    events, _ = simulate_trace(topo, PROF, n_micro=6, faults=faults,
                               clocks=ClockModel(seed=5))
    diag = detect(events, topo)
    flat = {tuple(sorted(l)) for l in diag.degraded_links}
    assert (1, 2) in flat, diag.summary()


@pytest.mark.parametrize("seed", range(4))
def test_detection_precision_recall_across_seeds(seed):
    rng = np.random.default_rng(seed)
    bad = int(rng.integers(0, TOPO.world))
    faults = FaultModel(compute_slowdown={bad: 0.55}, jitter=0.01, seed=seed)
    events, _ = _trace(faults=faults, clocks=ClockModel(seed=seed))
    aligned = apply_alignment(events, align_clocks(events))
    diag = detect(aligned, TOPO)
    assert diag.slow_ranks == [bad], (bad, diag.summary())
