"""Cross-module integration: the full MegatronApp loop (trace -> align ->
detect -> mitigate -> re-plan), training with checkpoint/resume equivalence,
and decoupled-FBD gradients on a real model."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dpp.planner import Planner
from repro.core.fbd.decouple import decoupled_grad, make_decoupled_step
from repro.core.simkit.engine import FaultModel
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing import (
    ClockModel, align_clocks, apply_alignment, detect, simulate_trace,
)
from repro.data.pipeline import DataConfig
from repro.ft.mitigation import MitigationAction, MitigationPolicy
from repro.models import get_model, make_batch
from repro.train.loop import LoopConfig, train
from repro.train.optim import OptimizerConfig


def test_full_management_loop_detect_mitigate_replan():
    """Paper's end-to-end story: MegaScan telemetry drives MegaDPP re-planning
    around a straggler, recovering most of the lost throughput."""
    topo = Topology(dp=2, pp=2, tp=2)
    prof = ModelProfile(n_chunks=2)
    faults = FaultModel(compute_slowdown={3: 0.45}, jitter=0.01, seed=2)

    # 1. trace the degraded cluster
    events, truth = simulate_trace(
        topo, prof, n_micro=8, n_iters=2, faults=faults, clocks=ClockModel(seed=2)
    )
    # 2. align + diagnose
    diag = detect(apply_alignment(events, align_clocks(events)), topo)
    assert diag.slow_ranks == [3]
    # 3. policy decides a soft mitigation
    action, info = MitigationPolicy().decide(diag)
    assert action in (MitigationAction.REPLAN, MitigationAction.EXCLUDE_RESTART)
    # 4. planner folds the telemetry in; the plan stays valid and the planner
    #    now models the slow rank
    planner = Planner(topo, prof, n_micro=8, memory_cap=1 << 62)
    healthy = planner.plan()
    degraded = planner.replan(diag)
    assert 3 in planner.faults.compute_slowdown
    assert degraded.makespan >= healthy.makespan  # slow node costs time
    assert degraded.wave >= 1


def test_train_checkpoint_resume_equivalence(tmp_path):
    """Interrupted-and-resumed training must match an uninterrupted run
    exactly (step-indexed data + checkpointed state)."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=8)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)

    _, hist_full = train(cfg, ocfg, data, LoopConfig(n_steps=8, log_every=1, seed=1))

    d = str(tmp_path / "ck")
    train(cfg, ocfg, data, LoopConfig(n_steps=4, log_every=1, ckpt_dir=d,
                                      ckpt_every=4, seed=1))
    _, hist_resumed = train(cfg, ocfg, data, LoopConfig(n_steps=8, log_every=1,
                                                        ckpt_dir=d, ckpt_every=4,
                                                        seed=1))
    full_tail = {h["step"]: h["loss"] for h in hist_full}[8]
    res_tail = {h["step"]: h["loss"] for h in hist_resumed}[8]
    np.testing.assert_allclose(res_tail, full_tail, rtol=1e-4, atol=1e-5)


def test_decoupled_fbd_grads_on_real_model():
    cfg = get_config("qwen2-0.5b", smoke=True).replace(remat="none")
    m = get_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, jax.random.PRNGKey(1))

    def loss_fn(p, b):
        return m.loss_fn(cfg, p, b)[0]

    step = make_decoupled_step(loss_fn)
    loss, grads = decoupled_grad(step, params, batch)
    loss_ref, grads_ref = jax.value_and_grad(loss_fn)(params, batch)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-5)
    flat = jax.tree.leaves(grads)
    flat_ref = jax.tree.leaves(grads_ref)
    for g, gr in zip(flat, flat_ref):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(gr, np.float32),
            rtol=5e-2, atol=5e-4,
        )
    assert step.residual_bytes(params, batch) > 0


def test_grad_accum_matches_single_batch():
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("qwen2-0.5b", smoke=True)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    state1 = init_train_state(cfg, jax.random.PRNGKey(0))
    state2 = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32, jax.random.PRNGKey(1))

    s1, m1 = make_train_step(cfg, ocfg, grad_accum=1)(state1, batch)
    s2, m2 = make_train_step(cfg, ocfg, grad_accum=2)(state2, batch)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=2e-2)
    w1 = jax.tree.leaves(s1.master)[0]
    w2 = jax.tree.leaves(s2.master)[0]
    # bf16 accumulation-order noise: bound absolutely by a fraction of the
    # per-step update scale (lr = 1e-3)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-3)
