"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, swept
over shapes and dtypes, plus hypothesis property tests on invariants."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic few-example fallback
    from _hypothesis_shim import given, settings
    import _hypothesis_shim as st

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru.kernel import rglru_pallas
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------- rmsnorm ---


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 7, 128), (1, 64, 512), (33, 256)])
def test_rmsnorm_kernel(shape, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, 1), shape, dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 2), shape[-1:], jnp.float32)
    out = rmsnorm_pallas(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


# ----------------------------------------------------------- flash attn ----


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,D,causal,window",
    [
        (2, 128, 4, 2, 64, True, None),
        (1, 96, 4, 4, 32, True, None),       # pad path (96 % 64 != 0)
        (2, 64, 8, 1, 64, True, 32),         # MQA + window
        (1, 128, 2, 2, 128, False, None),    # bidirectional
    ],
)
def test_flash_attention_kernel(B, S, H, K, D, causal, window, dtype):
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, K, D), dtype)
    kw = dict(scale=D ** -0.5, causal=causal, window=window)
    out = flash_attention(q, k, v, block_q=64, block_k=64,
                          impl="pallas_interpret", **kw)
    ref = flash_attention(q, k, v, impl="xla", **kw)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


# ------------------------------------------------------------------ wkv6 ---


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,T,Kd,Vd,chunk", [(4, 64, 16, 16, 16), (2, 96, 32, 32, 32)])
def test_wkv6_kernel(BH, T, Kd, Vd, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, 6), 5)
    r = jax.random.normal(ks[0], (BH, T, Kd), dtype)
    k = jax.random.normal(ks[1], (BH, T, Kd), dtype)
    v = jax.random.normal(ks[2], (BH, T, Vd), dtype)
    # moderate decay (clamp region not hit): w in [exp(-1.5), exp(-0.01)]
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (BH, T, Kd), minval=-4.0, maxval=0.4))).astype(dtype)
    u = jax.random.normal(ks[4], (BH, Kd), jnp.float32)
    y, s = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    y_ref, s_ref = wkv6_ref(r, k, v, w, u)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=tol, atol=tol)


def test_wkv6_extreme_decay_exact():
    """The kernel's per-channel decay form is exact even under brutal decay
    (exponents <= 0: underflow only)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 7), 5)
    BH, T, Kd = 2, 64, 16
    r = jax.random.normal(ks[0], (BH, T, Kd))
    k = jax.random.normal(ks[1], (BH, T, Kd))
    v = jax.random.normal(ks[2], (BH, T, Kd))
    w = jnp.full((BH, T, Kd), 1e-4)  # brutal decay
    u = jax.random.normal(ks[4], (BH, Kd))
    y, s = wkv6_pallas(r, k, v, w, u, chunk=16, interpret=True)
    y_ref, s_ref = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- rglru ---


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,W,chunk", [(2, 64, 128, 16), (1, 96, 512, 32)])
def test_rglru_kernel(B, T, W, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, 8), 2)
    a = jax.random.uniform(ks[0], (B, T, W), minval=0.2, maxval=0.999).astype(dtype)
    b = jax.random.normal(ks[1], (B, T, W), dtype)
    y, s = rglru_pallas(a, b, chunk=chunk, interpret=True)
    y_ref, s_ref = rglru_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=tol, atol=tol)


# --------------------------------------------------- property invariants ---


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), t_blocks=st.integers(1, 4))
def test_rglru_chunking_invariance(seed, t_blocks):
    """The chunked kernel must be invariant to the chunk size."""
    key = jax.random.PRNGKey(seed)
    B, W = 1, 128
    T = 16 * t_blocks * 2
    a = jax.random.uniform(key, (B, T, W), minval=0.3, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, T, W))
    y1, s1 = rglru_pallas(a, b, chunk=16, interpret=True)
    y2, s2 = rglru_pallas(a, b, chunk=16 * t_blocks, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_flash_attention_softmax_rows_boundedness(seed):
    """Attention outputs are convex combinations of V rows: bounded by the
    extremes of V (softmax weights sum to 1)."""
    key = jax.random.PRNGKey(seed)
    B, S, H, K, D = 1, 64, 2, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=True,
                          block_q=32, block_k=32, impl="pallas_interpret")
    vmax = float(np.abs(np.asarray(v)).max())
    assert float(np.abs(np.asarray(out)).max()) <= vmax + 1e-4
