"""Substrate tests: data pipeline determinism, checkpoint atomicity/restore,
failover supervisor, mitigation policy, gradient compression."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore, save
from repro.core.tracing.detect import Diagnosis
from repro.data.pipeline import DataConfig, SyntheticTokens, make_pipeline
from repro.ft.compress import GradCompressor
from repro.ft.failover import TrainSupervisor
from repro.ft.mitigation import MitigationAction, MitigationPolicy


# ------------------------------------------------------------------ data ---


def test_data_step_indexed_determinism():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    ds = SyntheticTokens(cfg)
    a, b = ds.batch_at(13), ds.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["targets"].dtype == np.int32


def test_data_host_sharding_partitions_batch():
    base = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, n_hosts=2, host_id=0)
    h0 = SyntheticTokens(base).batch_at(0)
    h1 = SyntheticTokens(DataConfig(**{**base.__dict__, "host_id": 1})).batch_at(0)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_resumes_at_step():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    ds = SyntheticTokens(cfg)
    pf = make_pipeline(cfg, start_step=5)
    got = pf.next()
    pf.close()
    np.testing.assert_array_equal(got["tokens"], ds.batch_at(5)["tokens"])


# ------------------------------------------------------------ checkpoint ---


def _toy_state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((4, 4)) * 2, "step": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    st = _toy_state(1.5)
    save(st, 10, tmp_path, metadata={"arch": "toy"})
    assert latest_step(tmp_path) == 10
    restored, manifest = restore(tmp_path, jax.tree.map(lambda x: x, st))
    assert manifest["metadata"]["arch"] == "toy"
    np.testing.assert_array_equal(restored["params"]["w"], st["params"]["w"])
    assert int(restored["opt"]["step"]) == 3


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    # a stale .tmp dir must never be listed as a restorable step
    (tmp_path / "step_00000099.tmp").mkdir(parents=True)
    save(_toy_state(), 5, tmp_path)
    assert latest_step(tmp_path) == 5


def test_checkpointer_async_and_prune(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        ck.save_async(_toy_state(float(s)), s)
    ck.wait()
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == ["step_00000002", "step_00000003"]


def test_elastic_restore_with_new_sharding(tmp_path):
    st = _toy_state(2.0)
    save(st, 1, tmp_path)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    restored, _ = restore(tmp_path, st, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


# -------------------------------------------------------------- failover ---


def test_supervisor_recovers_from_injected_failure(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 7:  # one-shot injected failure after ckpt at step 4
            raise RuntimeError("simulated device loss")
        return {"w": state["w"] + batch["x"]}, {"loss": jnp.float32(0.0)}

    sup = TrainSupervisor(
        step_fn=step_fn,
        make_batch=lambda step: {"x": jnp.float32(step)},
        ckpt_dir=str(tmp_path),
        ckpt_every=4,
        max_restarts=2,
    )
    state, step = sup.run({"w": jnp.float32(0.0)}, n_steps=10)
    assert step == 10
    # deterministic data => final state identical to an uninterrupted run
    expect = sum(range(10))
    assert float(state["w"]) == expect


# ------------------------------------------------------------- mitigation --


def _diag(slow_frac, n_inst=50, ranks=(3,)):
    return Diagnosis(
        slow_ranks=list(ranks), candidate_ranks=list(ranks), degraded_links=[],
        rank_scores={r: {"slow_op_frac": slow_frac, "late_start_frac": 0.9}
                     for r in ranks},
        evidence={"n_instances": n_inst},
    )


def test_policy_thresholds():
    pol = MitigationPolicy()
    act, _ = pol.decide(_diag(0.4))
    assert act is MitigationAction.REPLAN
    act, _ = pol.decide(_diag(0.9))
    assert act is MitigationAction.EXCLUDE_RESTART
    act, _ = pol.decide(Diagnosis([], [], [], evidence={"n_instances": 50}))
    assert act is MitigationAction.NONE
    act, _ = pol.decide(_diag(0.9, n_inst=2))
    assert act is MitigationAction.NONE  # insufficient evidence


# -------------------------------------------------------------- compress ---


def test_compression_error_bounded():
    comp = GradCompressor(block=64, bits=8)
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    err0 = jnp.zeros((1000,))
    deq, err = comp.apply({"g": g}, {"g": err0})
    rel = float(jnp.linalg.norm(deq["g"] - g) / jnp.linalg.norm(g))
    assert rel < 0.02
    # wire volume: ~4x smaller than bf16
    c, base = comp.wire_bytes({"g": g})
    assert c < base


def test_error_feedback_removes_bias():
    """Sum of compressed grads with feedback converges to the true sum."""
    comp = GradCompressor(block=32, bits=4)  # coarse to make bias visible
    rng = jax.random.PRNGKey(1)
    g_true = jax.random.normal(rng, (256,)) * 1e-3
    total_fb = jnp.zeros_like(g_true)
    total_nofb = jnp.zeros_like(g_true)
    err = {"g": jnp.zeros_like(g_true)}
    for _ in range(50):
        deq, err = comp.apply({"g": g_true}, err)
        total_fb = total_fb + deq["g"]
        deq2, _ = comp.apply({"g": g_true}, {"g": jnp.zeros_like(g_true)})
        total_nofb = total_nofb + deq2["g"]
    true_total = g_true * 50
    err_fb = float(jnp.linalg.norm(total_fb - true_total))
    err_nofb = float(jnp.linalg.norm(total_nofb - true_total))
    assert err_fb < err_nofb
