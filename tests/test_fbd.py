"""MegaFBD: bit-vector coordinator (deadlock freedom, O(G) state, ordered
execution), heterogeneous placement planning, decoupled F/B autodiff."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic few-example fallback
    from _hypothesis_shim import given, settings
    import _hypothesis_shim as st

from repro.core.fbd.coordinator import (
    BitVectorCoordinator,
    ThreadProgram,
    run_fcfs,
    run_with_coordinator,
)
from repro.core.fbd.decouple import decoupled_grad, make_decoupled_step
from repro.core.fbd.ranks import (
    colocated_placement,
    evaluate_placement,
    plan_placement,
)

# ------------------------------------------------------------ coordinator --


def _cross_control_scenario():
    # two controls, two workers each; two 2-member cross-control collectives
    groups = {1: (0, 2), 2: (1, 3)}
    programs = [
        ThreadProgram(vrank=0, control=0, group_ids=[1]),
        ThreadProgram(vrank=1, control=0, group_ids=[2]),
        ThreadProgram(vrank=2, control=1, group_ids=[1]),
        ThreadProgram(vrank=3, control=1, group_ids=[2]),
    ]
    return programs, groups


def test_fcfs_launcher_can_deadlock():
    programs, groups = _cross_control_scenario()
    outcomes = {run_fcfs(programs, groups, 2, arrival_seed=s) is None
                for s in range(24)}
    assert True in outcomes, "expected at least one deadlocking interleaving"


def test_coordinator_never_deadlocks_on_same_scenario():
    programs, groups = _cross_control_scenario()
    order = run_with_coordinator(programs, groups, 2)
    assert sorted(order) == [1, 2]
    assert order == [1, 2]  # ascending group order among simultaneously-ready


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_coordinator_deadlock_freedom_property(data):
    """Any *consistent* set of thread programs (per-thread orders drawn from
    one global order) completes under the coordinator."""
    n_vranks = data.draw(st.integers(2, 8))
    n_controls = data.draw(st.integers(1, 4))
    control_of = [data.draw(st.integers(0, n_controls - 1)) for _ in range(n_vranks)]
    n_colls = data.draw(st.integers(1, 12))
    groups = {}
    for g in range(1, n_colls + 1):
        members = data.draw(
            st.sets(st.integers(0, n_vranks - 1), min_size=1, max_size=n_vranks)
        )
        groups[g] = tuple(sorted(members))
    programs = [
        ThreadProgram(
            vrank=v, control=control_of[v],
            group_ids=[g for g in sorted(groups) if v in groups[g]],
        )
        for v in range(n_vranks)
    ]
    order = run_with_coordinator(programs, groups, n_controls)
    assert sorted(order) == sorted(groups)


def test_coordinator_state_is_linear_in_groups():
    g_small = {i: (0, 1) for i in range(4)}
    g_big = {i: (0, 1) for i in range(64)}
    c_small = BitVectorCoordinator(g_small, 2, 1)
    c_big = BitVectorCoordinator(g_big, 2, 1)
    assert c_big.state_bytes == 16 * c_small.state_bytes  # O(G)


# -------------------------------------------------------------- placement --


def test_decoupling_wins_on_heterogeneous_cluster():
    # 4 fast devices + 4 at 40% speed (e.g. older accelerators / CPUs)
    speed = {d: 1.0 for d in range(4)} | {d: 0.4 for d in range(4, 8)}
    dec = evaluate_placement(plan_placement(8, speed))
    col = evaluate_placement(colocated_placement(8, speed))
    assert dec < col, (dec, col)


def test_colocated_fine_on_homogeneous_cluster():
    speed = {d: 1.0 for d in range(8)}
    dec = evaluate_placement(plan_placement(8, speed))
    col = evaluate_placement(colocated_placement(8, speed))
    assert dec >= col * 0.95  # no spurious "win" from the transfer model


def test_virtual_rank_counts_preserved():
    pl = plan_placement(8, {0: 1.0, 1: 0.5})
    assert pl.mapping.n_virtual == 8
    assert len(pl.mapping.fwd_device) == len(pl.mapping.bwd_device) == 8


# --------------------------------------------------------- decoupled grad --


def test_decoupled_grad_matches_jax_grad():
    key = jax.random.PRNGKey(0)
    W1 = jax.random.normal(key, (8, 16)) * 0.3
    W2 = jax.random.normal(jax.random.fold_in(key, 1), (16, 4)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 2), (5, 8))
    t = jax.random.normal(jax.random.fold_in(key, 3), (5, 4))

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["W1"])
        y = h @ params["W2"]
        return jnp.mean((y - batch["t"]) ** 2)

    params = {"W1": W1, "W2": W2}
    batch = {"x": x, "t": t}
    step = make_decoupled_step(loss_fn)
    loss, grads = decoupled_grad(step, params, batch)
    loss_ref, grads_ref = jax.value_and_grad(loss_fn)(params, batch)
    assert np.allclose(loss, loss_ref)
    for k in grads:
        np.testing.assert_allclose(grads[k], grads_ref[k], rtol=1e-5, atol=1e-6)


def test_decoupled_residual_bytes_accounted():
    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["W"])
        return jnp.sum(h * h)

    params = {"W": jnp.ones((8, 8))}
    batch = {"x": jnp.ones((4, 8))}
    step = make_decoupled_step(loss_fn)
    nbytes = step.residual_bytes(params, batch)
    assert nbytes > 0
