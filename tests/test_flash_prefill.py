"""Flash-prefill: the banded online-softmax Pallas kernel vs the jnp oracle
(GQA ratios, windows, ragged lengths, chunk-boundary starts, verify widths),
the served token-identity guarantees (flash vs dense, chunked, speculative,
preemption), the recurrent-family pow2-segment prefill driver, and the
MegaServe/compile-cache precompile integration."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels.paged_attention import (
    paged_attention_ref,
    paged_prefill,
)
from repro.models import get_model
from repro.models.layers import apply_rope, rms_head_norm
from repro.serve import MegaServe, ServeConfig
from repro.serve.paged_cache import pow2_segments
from repro.serve.server import StaticRunner

# ------------------------------------------------------------- kernel ---


def _prefill_case(S, Q, H, K, dh, bs, M, kv_lens, *, window=None,
                  qk_norm=False, q_start=None, layered=False, seed=0):
    """Run one (xla oracle, interpret-mode pallas) pair and return
    (o_xla, o_pallas, o_fulltable) plus the scattered pools for comparison."""
    rng = np.random.default_rng(seed)
    n_blocks = 40
    shape = ((3,) if layered else ()) + (n_blocks, bs, K, dh)
    k_pool = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    tbl = np.zeros((S, M), np.int32)
    nxt = 1
    for s in range(S):  # distinct physical blocks per slot
        for j in range(min(-(-int(kv_lens[s]) // bs), M)):
            tbl[s, j] = nxt
            nxt += 1
    tables = jnp.asarray(tbl)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    q = jnp.asarray(rng.standard_normal((S, Q, H, dh)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((S, Q, K, dh)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((S, Q, K, dh)), jnp.float32)
    positions = kv_len[:, None] - Q + jnp.arange(Q)[None, :]
    qn = jnp.asarray(rng.standard_normal(dh), jnp.float32) if qk_norm else None
    kn = jnp.asarray(rng.standard_normal(dh), jnp.float32) if qk_norm else None
    layer = jnp.asarray(1, jnp.int32) if layered else None
    scale = 1.0 / np.sqrt(dh)
    kw = dict(tables=tables, positions=positions, block_size=bs, scale=scale,
              window=window, layer=layer, q_norm=qn, k_norm=kn,
              rope_theta=10000.0, q_start=q_start, q_block=8)
    o_x, c_x = paged_prefill(q, kk, vv, k_pool, v_pool, impl="xla", **kw)
    o_p, c_p = paged_prefill(q, kk, vv, k_pool, v_pool,
                             impl="pallas_interpret", **kw)
    # both impls must write identical K/V into the pool
    np.testing.assert_array_equal(np.asarray(c_x["k"]), np.asarray(c_p["k"]))
    np.testing.assert_array_equal(np.asarray(c_x["v"]), np.asarray(c_p["v"]))
    # unbanded full-table oracle over the *scattered* pool
    qq = q if qn is None else rms_head_norm(qn, q, 1e-6)
    qq = apply_rope(qq, positions, 10000.0)
    o_full = paged_attention_ref(qq, c_x["k"], c_x["v"], tables, kv_len,
                                 scale=scale, window=window, layer=layer)
    return o_x, o_p, o_full


def _check(o_x, o_p, o_full):
    # pallas (online softmax) vs banded oracle: fp32 accumulation noise
    assert float(jnp.abs(o_x - o_p).max()) < 2e-5
    # banded oracle vs full-table oracle: reduction-tree reassociation only
    assert float(jnp.abs(o_x - o_full).max()) < 2e-6


@pytest.mark.parametrize("gqa", [1, 2, 4])
def test_prefill_kernel_full_prompt_gqa(gqa):
    """Full prefill (q_start=0) across GQA ratios H/K in {1, 2, 4}."""
    _check(*_prefill_case(1, 64, 4, 4 // gqa, 16, 16, 6, [64], q_start=0))


def test_prefill_kernel_fused_qk_norm_rope():
    """The kernel's fused rmsnorm+rope q-prologue must match the unfused
    jnp chain bit-for-bit through the same dtype requantization."""
    _check(*_prefill_case(1, 64, 8, 2, 16, 16, 6, [64], q_start=0,
                          qk_norm=True))


def test_prefill_kernel_chunk_boundary_start():
    """Chunked prefill: queries land mid-sequence (cache_len=48 already
    filled, dynamic q_start) and must attend to the prior chunks' blocks."""
    _check(*_prefill_case(1, 32, 4, 2, 16, 16, 8, [32 + 48]))


def test_prefill_kernel_verify_width_ragged_layered():
    """The spec-verify shape: S slots, Q=spec_k+1=5, ragged kv_len across
    slots (7/33/100), layered pool indexing."""
    _check(*_prefill_case(3, 5, 4, 2, 16, 16, 8, [7, 33, 100], layered=True))


@pytest.mark.parametrize("case", [
    dict(S=1, Q=64, H=4, K=2, dh=16, bs=16, M=6, kv_lens=[64], window=24,
         q_start=0),
    dict(S=2, Q=5, H=4, K=2, dh=16, bs=16, M=8, kv_lens=[40, 90], window=16,
         layered=True),
])
def test_prefill_kernel_window_mask(case):
    """Sliding-window masking inside the causal band, both full-prefill and
    verify-width shapes."""
    kv_lens = case.pop("kv_lens")
    args = (case.pop("S"), case.pop("Q"), case.pop("H"), case.pop("K"),
            case.pop("dh"), case.pop("bs"), case.pop("M"), kv_lens)
    _check(*_prefill_case(*args, **case))


# ------------------------------------------------------ served identity ---


@pytest.fixture(scope="module")
def qwen_serve():
    cfg = get_config("qwen2-0.5b", smoke=True).replace(
        compute_dtype="float32", attn_kv_chunk=4096
    )
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drain(cfg, params, prompts, max_new=8, **scfg_kw):
    kw = dict(num_slots=4, block_size=16, num_blocks=40,
              max_blocks_per_slot=8, decode_path="paged")
    kw.update(scfg_kw)
    srv = MegaServe(cfg, params, ServeConfig(**kw))
    for p in prompts:
        srv.submit(p, max_new)
    return srv.drain(), srv


def test_flash_prefill_token_identity(qwen_serve):
    """Kernel on vs off: flash prefill must be greedy token-identical to the
    dense-prefill path on ragged prompt lengths (incl. non-block-multiples),
    and auto must resolve per backend: flash only where the Pallas kernel
    is real (TPU, or paged_attn_impl forcing it), dense on the CPU oracle
    path where one-shot dense prefill wins."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (5, 17, 33, 64)]
    dense, _ = _drain(cfg, params, prompts, prefill_path="dense")
    flash, srv = _drain(cfg, params, prompts, prefill_path="flash")
    assert flash == dense
    _, auto = _drain(cfg, params, prompts[:1], prefill_path="auto")
    expect = "flash" if jax.default_backend() == "tpu" else "dense"
    assert auto.prefill_path == expect
    _, forced = _drain(cfg, params, prompts[:1], prefill_path="auto",
                       paged_attn_impl="pallas_interpret")
    assert forced.prefill_path == "flash"


def test_flash_prefill_chunked_and_spec_identity(qwen_serve):
    """The one kernel serves all three entry shapes: full prefill, chunked
    prefill (q_start > 0), and the Q=spec_k+1 verify step — all greedy
    token-identical to the dense baseline."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (5, 17, 33, 64)]
    dense, _ = _drain(cfg, params, prompts, prefill_path="dense")
    chunked, _ = _drain(cfg, params, prompts, prefill_path="flash",
                        chunked_prefill=True)
    assert chunked == dense
    spec, srv = _drain(cfg, params, prompts, prefill_path="flash",
                       spec_decode=True)
    assert spec == dense
    assert srv.metrics()["spec_accepted"] > 0


def test_flash_prefill_preemption_identity(qwen_serve):
    """Preempt/recompute round trip through the flash path: recomputed
    prefills re-enter through the kernel and must preserve the stream."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).tolist()
               for _ in range(3)]
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 12, 0.0) for p in prompts], batch_size=3)
    # 8 usable blocks of 8 for three 16+12-token sequences -> must preempt
    outs, srv = _drain(cfg, params, prompts, max_new=12, num_slots=3,
                       block_size=8, num_blocks=9, max_blocks_per_slot=4,
                       prefill_path="flash")
    assert srv.metrics()["preemptions"] > 0
    assert outs == ref


def test_flash_requires_paged_pool(qwen_serve):
    """Explicit prefill_path=flash on the gathered decode path (no paged
    pool to walk) must fail loudly, not silently fall back."""
    cfg, params = qwen_serve
    with pytest.raises(ValueError, match="flash"):
        MegaServe(cfg, params, ServeConfig(
            num_slots=2, block_size=16, num_blocks=20, max_blocks_per_slot=4,
            decode_path="gathered", prefill_path="flash"))


# ------------------------------------------------- recurrent seg prefill ---


def test_pow2_segments():
    assert pow2_segments(13) == [8, 4, 1]
    assert pow2_segments(1) == [1]
    assert pow2_segments(64) == [64]
    assert sum(pow2_segments(100)) == 100
    with pytest.raises(ValueError):
        pow2_segments(0)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "recurrentgemma-9b"])
def test_recurrent_seg_prefill_identity(arch):
    """State families prefill through the descending pow2-segment driver;
    streams must match the exact one-shot prefill, and the compiled-driver
    key set must stay one-per-distinct-length (widths are shared)."""
    cfg = get_config(arch, smoke=True).replace(compute_dtype="float32")
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (5, 13, 17)]

    def run(seg_on):
        srv = MegaServe(cfg, params, ServeConfig(
            num_slots=2, block_size=8, num_blocks=24, max_blocks_per_slot=4))
        if seg_on:
            assert srv._seg_ok, "seg driver must be on for state families"
        else:  # exact one-shot dense prefill as the oracle
            srv._seg_ok = False
            srv._prefill_cache.clear()
        for p in prompts:
            srv.submit(p, 6)
        return srv.drain(), len(srv._prefill_cache)

    exact, _ = run(False)
    seg, nkeys = run(True)
    assert seg == exact
    assert nkeys == 3  # one driver per distinct prompt length


# ------------------------------------------- precompile + compile cache ---


def test_precompile_report_and_warm_cache(qwen_serve, tmp_path):
    """precompile() returns per-path {count, ms}; against a CompileCache a
    second engine replays every bucket as a hit (0 misses) and the served
    streams stay identical with and without the cache."""
    from repro.core.compile_cache import CompileCache

    cfg, params = qwen_serve
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
               for n in (5, 17, 33)]
    scfg = ServeConfig(num_slots=2, block_size=8, num_blocks=24,
                       max_blocks_per_slot=8, decode_path="paged",
                       chunked_prefill=True, chunk_len=16)

    def serve(cache):
        srv = MegaServe(cfg, params, scfg, compile_cache=cache)
        rep = srv.precompile()
        for p in prompts:
            srv.submit(p, 6)
        return srv.drain(), rep

    out_cold, rep_cold = serve(CompileCache(tmp_path))
    for path in ("decode", "prefill", "chunk"):
        assert rep_cold[path]["count"] > 0 and rep_cold[path]["ms"] > 0
    assert rep_cold["verify"]["count"] == 0  # spec off
    assert rep_cold["total"] == sum(
        rep_cold[p]["count"] for p in ("decode", "prefill", "chunk", "verify"))
    assert rep_cold["cache"]["puts"] > 0 and rep_cold["cache"]["hits"] == 0

    out_warm, rep_warm = serve(CompileCache(tmp_path))
    assert rep_warm["cache"]["hits"] == rep_cold["cache"]["puts"]
    assert rep_warm["cache"]["misses"] == 0
    out_ref, _ = serve(None)
    assert out_cold == out_warm == out_ref
