"""Flash (chunked custom-VJP) attention vs naive reference: values and grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention


def _run(impl, q, k, v, **kw):
    return attention(q, k, v, impl=impl, **kw)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("kv_len", [None, 37])
def test_flash_matches_naive(window, kv_len):
    key = jax.random.PRNGKey(0)
    B, S, H, K, D = 2, 48, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    kw = dict(
        scale=D**-0.5, positions_q=jnp.arange(S), causal=True, window=window,
        kv_len=None if kv_len is None else jnp.int32(kv_len),
    )
    out_naive = _run("naive", q, k, v, kv_chunk=S + 1, **kw)
    out_flash = _run("chunked", q, k, v, kv_chunk=16, **kw)
    np.testing.assert_allclose(out_flash, out_naive, rtol=2e-5, atol=2e-5)


def test_flash_grads_match_naive():
    key = jax.random.PRNGKey(1)
    B, S, H, K, D = 1, 32, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    kw = dict(scale=D**-0.5, positions_q=jnp.arange(S), causal=True)

    def loss(impl, chunk):
        def f(args):
            q, k, v = args
            o = _run(impl, q, k, v, kv_chunk=chunk, **kw)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        return f

    g_naive = jax.grad(loss("naive", S + 1))((q, k, v))
    g_flash = jax.grad(loss("chunked", 8))((q, k, v))
    for gn, gf, name in zip(g_naive, g_flash, "qkv"):
        np.testing.assert_allclose(gf, gn, rtol=3e-5, atol=3e-5, err_msg=name)


def test_flash_uneven_chunks_padding():
    key = jax.random.PRNGKey(2)
    B, S, H, K, D = 1, 40, 2, 1, 8  # 40 % 16 != 0 -> padding path
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    kw = dict(scale=D**-0.5, positions_q=jnp.arange(S), causal=True)
    out_naive = _run("naive", q, k, v, kv_chunk=S + 1, **kw)
    out_flash = _run("chunked", q, k, v, kv_chunk=16, **kw)
    np.testing.assert_allclose(out_flash, out_naive, rtol=2e-5, atol=2e-5)


def test_decode_path_matches_naive_row():
    key = jax.random.PRNGKey(3)
    B, T, H, K, D = 2, 24, 4, 4, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, K, D), jnp.float32)
    pos = jnp.array([10])
    out = attention(q, k, v, scale=D**-0.5, positions_q=pos, causal=True,
                    kv_len=jnp.int32(11), impl="chunked", kv_chunk=8)
    # reference: softmax over first 11 positions only
    s = jnp.einsum("bshd,bthd->bsht", q, k) * D**-0.5
    msk = jnp.arange(T) < 11
    s = jnp.where(msk[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bsht,bthd->bshd", p, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
