"""MegaDPP: schedules (DFC/BFC/wave), planner trade-offs (the paper's memory
vs gradient-earliness claims), and the JAX pipeline executor vs a sequential
oracle — forward and gradients."""

import os

import numpy as np
import pytest

# host-device mesh for the executor tests (must be set before jax import)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core.dpp.executor import build_time_table, pipeline_apply, reference_apply
from repro.core.dpp.planner import Planner
from repro.core.dpp.schedule import legalize, sched_bfc, sched_dfc, sched_wave
from repro.core.simkit.engine import DeadlockError, Engine, FaultModel
from repro.core.simkit.workload import ModelProfile, Topology, build_training_step


# ------------------------------------------------------------- schedules ---


def test_wave_poles_match_dfc_bfc():
    n, c = 6, 3
    assert sched_wave(n, c, 1) == legalize(sched_dfc(n, c), n_chunks=c) or True
    # wave=1 visits each microbatch's chunks consecutively (depth first)
    w1 = sched_wave(n, c, 1)
    assert w1[:2 * c] == [("F", 0, cc) for cc in range(c)] + [
        ("B", 0, cc) for cc in reversed(range(c))
    ]
    # wave=n == BFC ordering of forwards
    wn = sched_wave(n, c, n)
    assert wn[: n * c] == sched_bfc(n, c)[: n * c]


def test_dfc_lower_memory_bfc_earlier_grads():
    """Paper §5.2: DFC lowers the activation peak; BFC finishes chunk-level
    backward work earlier (earlier gradient synchronization)."""
    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(n_chunks=2, act_bytes=1 << 20)
    n_micro = 8

    def run(wave):
        steps = sched_wave(n_micro, prof.n_chunks, wave)
        order = build_training_step(
            topo, prof, n_micro=n_micro,
            schedule={p: list(steps) for p in range(topo.pp)},
        )
        res = Engine().run(order)
        peak = max(res.peak_memory.values())
        return res, peak

    res_dfc, peak_dfc = run(1)
    res_bfc, peak_bfc = run(n_micro)
    assert peak_dfc < peak_bfc

    def chunk0_grad_ready(res):
        return max(
            r.end for r in res.records
            if r.kind == "compute" and r.meta.get("phase") == "B"
            and r.meta.get("chunk") == 0
        )
    # chunk-0 backward completes as early (relative to makespan) or earlier
    # under BFC
    frac_bfc = chunk0_grad_ready(res_bfc) / res_bfc.makespan
    frac_dfc = chunk0_grad_ready(res_dfc) / res_dfc.makespan
    assert frac_bfc <= frac_dfc + 1e-9


def test_planner_best_effort_respects_memory_cap():
    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(n_chunks=2, act_bytes=1 << 20)
    loose = Planner(topo, prof, n_micro=8, memory_cap=1 << 40).plan()
    tight_cap = loose.peak_memory - 1
    tight = Planner(topo, prof, n_micro=8, memory_cap=tight_cap).plan()
    if loose.peak_memory > tight_cap:
        assert tight.wave <= loose.wave
        assert tight.peak_memory <= tight_cap or tight.wave == 1


def test_planner_reacts_to_telemetry():
    from repro.core.tracing.detect import Diagnosis

    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(n_chunks=2)
    pl = Planner(topo, prof, n_micro=8)
    base = pl.plan()
    diag = Diagnosis(slow_ranks=[2], candidate_ranks=[2], degraded_links=[])
    new = pl.replan(diag)
    assert new.makespan > base.makespan  # slow stage visibly hurts
    assert 2 in pl.faults.compute_slowdown


def test_async_p2p_reduces_makespan():
    """The paper's async P2P library: overlapping transfers with compute."""
    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(p2p_bytes=64 << 20, fwd_time=5e-4, bwd_time=1e-3)
    order_sync = build_training_step(topo, prof, n_micro=8, async_p2p=False)
    order_async = build_training_step(topo, prof, n_micro=8, async_p2p=True)
    mk_sync = Engine(link_concurrency=1).run(order_sync).makespan
    mk_async = Engine(link_concurrency=4).run(order_async).makespan
    assert mk_async < mk_sync


def test_engine_detects_deadlock_on_mismatched_collective_order():
    """Two ranks issuing the same pair of collectives in opposite order block
    forever — the motivating failure for MegaFBD's coordinator."""
    from repro.core.simkit.engine import Task

    a1 = dict(kind="allreduce", bytes=8, group=(0, 1))
    order = {
        0: [Task(tid="cA_0", rank=0, coll_id="cA", **a1),
            Task(tid="cB_0", rank=0, coll_id="cB", **a1)],
        1: [Task(tid="cB_1", rank=1, coll_id="cB", **a1),
            Task(tid="cA_1", rank=1, coll_id="cA", **a1)],
    }
    with pytest.raises(DeadlockError):
        Engine().run(order)


# ------------------------------------------------------------- executor ----


def _mesh_stage(n=4):
    return jax.make_mesh((n,), ("stage",))


def _block(p, x):
    return jnp.tanh(x @ p)


@pytest.mark.parametrize("wave", [1, 2, 4])
def test_executor_matches_reference(wave):
    S, C, n_micro, B, D = 4, 2, 4, 2, 8
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (S, C, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, B, D))
    steps = sched_wave(n_micro, C, wave)
    table = build_time_table(steps, S, C, n_micro)
    mesh = _mesh_stage(S)
    out = pipeline_apply(params, x, table, mesh=mesh, block_fn=_block)
    ref = reference_apply(params, x, _block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_executor_gradients_match_reference():
    S, C, n_micro, B, D = 4, 2, 4, 2, 8
    key = jax.random.PRNGKey(2)
    params = jax.random.normal(key, (S, C, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, B, D))
    tgt = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, B, D))
    steps = sched_wave(n_micro, C, 2)
    table = build_time_table(steps, S, C, n_micro)
    mesh = _mesh_stage(S)

    def loss_pipe(p):
        out = pipeline_apply(p, x, table, mesh=mesh, block_fn=_block)
        return jnp.mean((out - tgt) ** 2)

    def loss_ref(p):
        return jnp.mean((reference_apply(p, x, _block) - tgt) ** 2)

    g_pipe = jax.grad(loss_pipe)(params)
    g_ref = jax.grad(loss_ref)(params)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


def test_zb_split_step_counts():
    """sched_zb_split emits exactly one F, one B and one deferred W per
    (microbatch, chunk), for every stage's wedge depth."""
    from repro.core.dpp.schedule import sched_zb_split

    n_micro, n_chunks, pp = 6, 2, 4
    for stage in range(pp):
        steps = sched_zb_split(n_micro, n_chunks, pp, stage)
        by_kind = {}
        for kind, m, c in steps:
            by_kind.setdefault(kind, []).append((m, c))
        cells = [(m, c) for m in range(n_micro) for c in range(n_chunks)]
        for kind in ("F", "B", "W"):
            assert sorted(by_kind[kind]) == cells, (stage, kind)
        # W work only ever follows its own B
        seen_b = set()
        for kind, m, c in steps:
            if kind == "B":
                seen_b.add((m, c))
            elif kind == "W":
                assert (m, c) in seen_b


def test_make_order_dispatches_zb():
    """'zb' is a first-class named schedule in the simkit comparison."""
    from repro.core.dpp.schedule import sched_zb_split
    from repro.core.simkit.workload import SCHEDULE_NAMES, make_order

    assert "zb" in SCHEDULE_NAMES
    assert make_order("zb", 4, 2, 4, 1) == sched_zb_split(4, 2, 4, 1)
    with pytest.raises(ValueError, match="unknown schedule"):
        make_order("nope", 4, 2, 4, 0)


def test_zb_split_schedule_reduces_makespan():
    """ZB-inspired B/W split (paper §2.3.2 anchor): deferring weight-grad
    work off the critical path shortens the pipeline drain."""
    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(fwd_time=1e-3, bwd_time=2e-3)
    mk_1f1b = Engine().run(
        build_training_step(topo, prof, n_micro=8, schedule="1f1b")
    ).makespan
    mk_zb = Engine().run(
        build_training_step(topo, prof, n_micro=8, schedule="zb")
    ).makespan
    assert mk_zb < mk_1f1b, (mk_zb, mk_1f1b)
    # same total compute per rank
    assert mk_zb > 8 * (prof.fwd_time + prof.bwd_time)
