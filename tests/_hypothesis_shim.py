"""Minimal stand-in for ``hypothesis`` on bare environments.

When the real package is missing, ``@given`` tests run a handful of
deterministic pseudo-random examples instead of a shrinking search — enough
to keep the property suites collecting and exercising invariants without the
dependency.  Supports exactly the strategy surface this repo uses:
``integers / floats / sampled_from / sets / data``.
"""

from __future__ import annotations

import functools
import random

N_EXAMPLES = 5


class Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value) -> Strategy:
    return Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value) -> Strategy:
    return Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(xs) -> Strategy:
    xs = list(xs)
    return Strategy(lambda r: r.choice(xs))


def sets(elem: Strategy, min_size: int = 0, max_size: int = 8) -> Strategy:
    def sample(r):
        n = r.randint(min_size, max_size)
        out: set = set()
        for _ in range(64):
            if len(out) >= n:
                break
            out.add(elem.sample(r))
        return out

    return Strategy(sample)


class _Data:
    """Interactive-draw object mirroring ``st.data()``."""

    def __init__(self, r: random.Random):
        self._r = r

    def draw(self, strat: Strategy, label=None):
        return strat.sample(self._r)


def data() -> Strategy:
    return Strategy(lambda r: _Data(r))


def given(*gargs, **gkwargs):
    def deco(fn):
        # no functools.wraps: copying fn's signature would make pytest
        # treat the strategy parameters as fixtures
        def wrapper(*args, **kwargs):
            for i in range(N_EXAMPLES):
                r = random.Random(0xC0FFEE + i)
                pos = [s.sample(r) for s in gargs]
                kw = {k: s.sample(r) for k, s in gkwargs.items()}
                fn(*args, *pos, **kwargs, **kw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(*args, **kwargs):
    return lambda fn: fn
