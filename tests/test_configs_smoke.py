"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and finiteness (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import get_model, make_batch

BATCH, SEQ = 2, 64


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_loss_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init(cfg, rng)
    batch = make_batch(cfg, BATCH, SEQ, jax.random.fold_in(rng, 1))
    loss, metrics = jax.jit(lambda p, b: m.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_grads_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init(cfg, rng)
    batch = make_batch(cfg, BATCH, SEQ, jax.random.fold_in(rng, 2))

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: m.loss_fn(cfg, pp, b), has_aux=True
        )(p)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return loss, gnorm

    loss, gnorm = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: gnorm={gnorm}"


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode(arch, rng):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init(cfg, rng)
    cache_len = SEQ + 8
    cache = (
        m.init_cache(cfg, BATCH, cache_len, SEQ)
        if cfg.family == "encdec"
        else m.init_cache(cfg, BATCH, cache_len)
    )
    batch = make_batch(cfg, BATCH, SEQ, jax.random.fold_in(rng, 3))
    cache, logits = jax.jit(lambda p, b, c: m.prefill(cfg, p, b, c))(params, batch, cache)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    if cfg.input_kind == "tokens" or cfg.family == "encdec":
        tok = jnp.argmax(logits, -1)
    else:
        tok = jnp.zeros((BATCH, 1, cfg.d_model), jnp.float32)
    step = jax.jit(lambda p, c, t, pos: m.decode_step(cfg, p, c, t, pos))
    cache, logits2 = step(params, cache, tok, jnp.int32(SEQ))
    assert logits2.shape == (BATCH, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_decode_matches_forward_qwen2(rng):
    """Teacher-forced decode must reproduce the training forward's logits."""
    cfg = get_config("qwen2-0.5b", smoke=True).replace(remat="none")
    from repro.models import lm as lm_mod
    from repro.models import layers as L

    m = get_model(cfg)
    params = m.init(cfg, rng)
    S = 16
    tokens = jax.random.randint(jax.random.fold_in(rng, 4), (1, S), 0, cfg.vocab_size)
    hidden, _, _ = lm_mod.forward(cfg, params, {"tokens": tokens})
    full_logits = L.logits_fn(params, cfg, hidden)

    cache = m.init_cache(cfg, 1, S + 1)
    cache, logits_p = m.prefill(cfg, params, {"tokens": tokens[:, :8]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full_logits[:, 7], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    for i in range(8, S):
        cache, logits_d = m.decode_step(cfg, params, cache, tokens[:, i], jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"decode step {i}",
        )
