"""MegaServe: block-allocator invariants, paged gather/scatter roundtrips,
paged-attention kernel parity (interpret mode vs ref vs gathered-dense
oracle), scheduler admission/eviction/preemption on scripted traces,
continuous-vs-static greedy equivalence on both decode paths, prefill
compile-cache bucketing, simkit policy evaluation, and trace emission."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import (
    RequestSpec,
    poisson_requests,
    serving_throughput,
    serving_workload,
)
from repro.core.tracing.chrome import to_chrome
from repro.models import get_model, lm
from repro.serve import (
    BlockAllocator,
    MegaServe,
    PagedKVCache,
    PoolSpec,
    Request,
    RequestStatus,
    Scheduler,
    ServeConfig,
    blocks_for,
)
from repro.serve.server import StaticRunner

# ------------------------------------------------------------ allocator ---


def test_allocator_alloc_free_invariants():
    a = BlockAllocator(num_blocks=8, reserved=1)
    assert a.num_free == 7
    got = a.alloc(3)
    assert len(set(got)) == 3 and 0 not in got
    assert a.num_free == 4 and a.num_held == 3
    a.free(got[:2])
    assert a.num_free == 6 and a.num_held == 1
    # LIFO reuse: the most recently freed block comes back first
    assert a.alloc(1)[0] == got[1]


def test_allocator_oom_and_double_free():
    from repro.serve import PoolExhausted

    a = BlockAllocator(num_blocks=4)
    got = a.alloc(3)
    assert a.try_alloc(1) is None
    with pytest.raises(PoolExhausted):
        a.alloc(1)
    a.free([got[0]])
    with pytest.raises(ValueError):
        a.free([got[0]])          # double free
    with pytest.raises(ValueError):
        a.free([0])               # reserved null block was never handed out


def test_blocks_for():
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


# ---------------------------------------------------- paged gather/scatter ---


@pytest.fixture(scope="module")
def qwen_serve():
    cfg = get_config("qwen2-0.5b", smoke=True).replace(
        compute_dtype="float32", attn_kv_chunk=4096
    )
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_paged_prefill_gather_roundtrip(qwen_serve):
    cfg, _ = qwen_serve
    spec = PoolSpec(num_slots=2, num_blocks=9, block_size=8, max_blocks=4)
    kv = PagedKVCache(cfg, spec)
    assert any(jax.tree.leaves(kv.paged)), "qwen must have paged k/v leaves"

    # fill a B=1 dense cache (2 blocks worth) with random values
    template = jax.eval_shape(lambda: lm.init_cache(cfg, 1, 16))
    key = iter(jax.random.split(jax.random.PRNGKey(1), 64))
    filled = jax.tree.map(
        lambda s: jax.random.normal(next(key), s.shape).astype(s.dtype), template
    )
    phys = jnp.asarray([3, 5], jnp.int32)
    pool = kv.scatter_prefill(kv.pool, filled, jnp.int32(1), phys)

    tables = np.zeros((2, 4), np.int32)
    tables[1, :2] = [3, 5]
    dense = kv.gather(pool, jnp.asarray(tables))

    flat_d, _ = jax.tree_util.tree_flatten(dense)
    flat_f, _ = jax.tree_util.tree_flatten(filled)
    flat_p, _ = jax.tree_util.tree_flatten(kv.paged)
    for d, f, paged in zip(flat_d, flat_f, flat_p):
        if paged:  # slot 1, first 16 positions == the filled cache
            np.testing.assert_array_equal(np.asarray(d[:, 1, :16]),
                                          np.asarray(f[:, 0]))
        else:      # slot-state row
            np.testing.assert_array_equal(np.asarray(d[:, 1]),
                                          np.asarray(f[:, 0]))


def test_scatter_decode_touches_only_written_block(qwen_serve):
    cfg, _ = qwen_serve
    spec = PoolSpec(num_slots=2, num_blocks=9, block_size=8, max_blocks=4)
    kv = PagedKVCache(cfg, spec)
    tables = np.zeros((2, 4), np.int32)
    tables[0, :2] = [2, 4]
    tables[1, :2] = [6, 7]
    tables = jnp.asarray(tables)
    pos = jnp.asarray([9, 3], jnp.int32)   # slot0 writes block 1, slot1 block 0

    dense = kv.gather(kv.pool, tables)
    dense = jax.tree.map(lambda a: a + 1.0 if a.ndim > 2 else a, dense)
    pool = kv.scatter_decode(kv.pool, dense, tables, pos)
    for leaf, paged in zip(jax.tree.leaves(pool), jax.tree.leaves(kv.paged)):
        if not paged:
            continue
        arr = np.asarray(leaf)
        assert np.all(arr[:, 4] != 0)      # slot0's touched block written
        assert np.all(arr[:, 6] != 0)      # slot1's touched block written
        assert np.all(arr[:, 2] == 0)      # slot0's untouched block intact
        assert np.all(arr[:, 7] == 0)      # slot1's untouched block intact


# ------------------------------------------------- paged-attention kernel ---


def _rand_paged(seed, S, bs, K, G, dh, kv_lens):
    """Random pool + block tables + queries for ``S`` slots with ragged
    ``kv_lens``; every slot gets distinct physical blocks, padding entries
    point at the null block 0 (which holds garbage, as in live serving)."""
    rng = np.random.default_rng(seed)
    live = [blocks_for(int(l), bs) for l in kv_lens]
    M = max(live)
    nb = 1 + sum(live)
    H = K * G
    q = jnp.asarray(rng.standard_normal((S, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((nb, bs, K, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((nb, bs, K, dh)), jnp.float32)
    tables = np.zeros((S, M), np.int32)
    perm = rng.permutation(np.arange(1, nb))
    i = 0
    for s in range(S):
        tables[s, : live[s]] = perm[i : i + live[s]]
        i += live[s]
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(kv_lens, jnp.int32)


@pytest.mark.parametrize("bs,G", [(8, 1), (8, 4), (16, 2)])
@pytest.mark.parametrize("window", [None, 7])
def test_paged_kernel_interpret_matches_ref(bs, G, window):
    from repro.kernels.paged_attention import (
        paged_attention_pallas,
        paged_attention_ref,
    )

    q, kp, vp, tables, kv_len = _rand_paged(
        seed=bs * 10 + G, S=4, bs=bs, K=2, G=G, dh=16,
        kv_lens=[1, bs, 2 * bs + 3, 3 * bs - 1],
    )
    ref = paged_attention_ref(q, kp, vp, tables, kv_len, scale=0.25, window=window)
    ker = paged_attention_pallas(
        q, kp, vp, tables, kv_len, scale=0.25, window=window, interpret=True
    )
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=2e-6)


def test_paged_ref_matches_gathered_dense_oracle():
    """The paged ref must agree with dense decode attention over the
    materialized per-slot view — i.e. with what the gathered oracle path
    computes — for every slot's own kv_len."""
    from repro.kernels.paged_attention import paged_attention_ref
    from repro.models.layers import attention

    bs, K, G, dh = 8, 2, 3, 16
    q, kp, vp, tables, kv_len = _rand_paged(
        seed=7, S=3, bs=bs, K=K, G=G, dh=dh, kv_lens=[5, 11, 24]
    )
    out = paged_attention_ref(q, kp, vp, tables, kv_len, scale=0.3)
    M = tables.shape[1]
    for s in range(3):
        dense_k = np.asarray(kp)[np.asarray(tables[s])].reshape(M * bs, K, dh)
        dense_v = np.asarray(vp)[np.asarray(tables[s])].reshape(M * bs, K, dh)
        o = attention(
            q[s][None, None],                       # [1, 1, H, dh]
            jnp.asarray(dense_k)[None], jnp.asarray(dense_v)[None],
            scale=0.3,
            positions_q=jnp.asarray([int(kv_len[s]) - 1]),
            kv_len=kv_len[s],
        )
        np.testing.assert_allclose(
            np.asarray(o[0, 0]), np.asarray(out[s]), atol=1e-6
        )


def test_paged_kernel_layer_stacked_pool():
    """The 5-D layer-stacked pool layout (what the serving scan carries) must
    match slicing the layer out by hand, on both ref and interpret kernel."""
    from repro.kernels.paged_attention import (
        paged_attention_pallas,
        paged_attention_ref,
    )

    q, kp, vp, tables, kv_len = _rand_paged(
        seed=11, S=3, bs=8, K=2, G=2, dh=16, kv_lens=[4, 9, 17]
    )
    n_layers = 3
    rng = np.random.default_rng(12)
    kp5 = jnp.asarray(rng.standard_normal((n_layers, *kp.shape)), jnp.float32)
    vp5 = jnp.asarray(rng.standard_normal((n_layers, *vp.shape)), jnp.float32)
    for g in (0, 2):
        want = paged_attention_ref(q, kp5[g], vp5[g], tables, kv_len, scale=0.25)
        got_ref = paged_attention_ref(
            q, kp5, vp5, tables, kv_len, scale=0.25, layer=jnp.int32(g))
        got_ker = paged_attention_pallas(
            q, kp5, vp5, tables, kv_len, scale=0.25, layer=jnp.int32(g),
            interpret=True)
        np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want), atol=2e-6)
        np.testing.assert_allclose(np.asarray(got_ker), np.asarray(want), atol=2e-6)


def test_paged_kernel_output_invariant_to_table_width():
    """Slicing the tables to the live high-water mark (what the server does
    each step) must not change the result: dead entries are masked/skipped."""
    from repro.kernels.paged_attention import paged_attention_ref

    q, kp, vp, tables, kv_len = _rand_paged(
        seed=3, S=2, bs=8, K=2, G=2, dh=16, kv_lens=[6, 14]
    )
    wide = jnp.pad(tables, ((0, 0), (0, 5)))       # extra null-block entries
    a = paged_attention_ref(q, kp, vp, tables, kv_len, scale=0.25)
    b = paged_attention_ref(q, kp, vp, wide, kv_len, scale=0.25)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- scheduler ---


def _mk(rid, arrival=0.0, plen=8, max_new=4):
    return Request(rid=rid, prompt=list(range(plen)), max_new=max_new,
                   arrival=arrival)


def test_scheduler_admission_respects_arrival_and_slots():
    s = Scheduler(ServeConfig(num_slots=2, block_size=8, num_blocks=9,
                              max_blocks_per_slot=4, max_prefills_per_step=4))
    for rid, t in enumerate([0.0, 0.0, 0.0, 5.0]):
        s.submit(_mk(rid, arrival=t))
    adm = s.admit(now=1.0)
    assert [a.rid for a in adm] == [0, 1]          # FIFO, 2 slots
    assert s.allocator.num_held == 2
    # slot eviction refills from the arrived queue, not the future one
    s.requests[0].generated = [1] * 4
    assert s.evict_finished(now=1.5) == [0]
    assert [a.rid for a in s.admit(now=1.5)] == [2]
    s.requests[1].generated = [1] * 4
    assert s.evict_finished(now=2.0) == [1]
    assert s.admit(now=2.0) == []                  # rid 3 hasn't arrived yet
    assert [a.rid for a in s.admit(now=6.0)] == [3]


def test_scheduler_capacity_growth_and_preemption_recompute():
    cfg = ServeConfig(num_slots=2, block_size=4, num_blocks=5,
                      max_blocks_per_slot=4, max_prefills_per_step=4)
    s = Scheduler(cfg)
    s.submit(_mk(0, plen=4, max_new=8))
    s.submit(_mk(1, plen=4, max_new=8))
    adm = s.admit(now=0.0)
    assert len(adm) == 2 and s.allocator.num_free == 2
    for a in adm:
        s.record_token(a.slot, 100 + a.rid, now=0.0)
    # four decode steps take each slot from pos=4 to pos=8: the first step
    # grows both to 2 blocks (pool now empty), pos=8 then wants a third
    for _ in range(4):
        assert s.ensure_capacity() == []
        for slot in s.active_slots():
            s.advance(slot)
            s.record_token(slot, 7, now=0.1)
    assert s.allocator.num_free == 0
    preempted = s.ensure_capacity()
    assert preempted == [1]                        # youngest-admitted victim
    req = s.requests[1]
    assert req.status is RequestStatus.WAITING and req.n_preemptions == 1
    assert req.recompute_prompt == list(range(4)) + [101, 7, 7, 7, 7]
    assert s.waiting[0] == 1                       # requeued at the head
    assert s.allocator.num_held == sum(len(b) for b in s.blocks)
    # survivor kept its blocks and can now grow
    assert 0 in [s.slots[x] for x in s.active_slots()]


def test_preemption_victim_is_youngest_even_if_requesting():
    # rid 0 (older, mid-block, needs no growth) must keep its blocks when the
    # younger rid 1 hits a block boundary on a dry pool: rid 1 preempts itself
    cfg = ServeConfig(num_slots=2, block_size=4, num_blocks=4,
                      max_blocks_per_slot=3, max_prefills_per_step=4)
    s = Scheduler(cfg)
    s.submit(_mk(0, plen=6, max_new=2))    # 2 blocks, pos 6 (mid-block)
    s.submit(_mk(1, plen=4, max_new=4))    # 1 block, pos 4 (boundary)
    adm = s.admit(now=0.0)
    assert len(adm) == 2 and s.allocator.num_free == 0
    assert s.ensure_capacity() == [1]
    assert s.slots.count(None) == 1 and s.requests[0].status is RequestStatus.RUNNING
    assert s.waiting == [1]
    # with the pool freed, the preempted request re-admits and proceeds
    assert [a.rid for a in s.admit(now=0.1)] == [1]


def test_reset_restarts_injected_clock(qwen_serve):
    cfg, params = qwen_serve
    t = [0.0]
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4),
        clock=lambda: t[0])
    srv.submit(list(range(2, 10)), 2, arrival=0.0)
    t[0] = 1.5
    srv.drain()
    assert srv.metrics()["wall_s"] == 1.5
    srv.reset()                       # re-times from the injected clock's now
    assert srv.metrics()["wall_s"] == 0.0


def test_scheduler_rejects_infeasible_request():
    s = Scheduler(ServeConfig(num_slots=1, block_size=4, num_blocks=3,
                              max_blocks_per_slot=2))
    with pytest.raises(ValueError):
        s.submit(_mk(0, plen=8, max_new=8))        # needs 4 blocks, cap 2


# ------------------------------------------------ continuous vs static ---


@pytest.mark.parametrize("path", ["paged", "gathered"])
def test_continuous_greedy_matches_static(qwen_serve, path):
    cfg, params = qwen_serve
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (16, 16, 32, 16)]
    max_new = [6, 3, 5, 4]

    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=33, max_blocks_per_slot=6,
        decode_path=path))
    assert srv.decode_path == path
    for p, m in zip(prompts, max_new):
        srv.submit(p, m, arrival=0.0)
    outs = srv.drain()

    ref, ref_met = StaticRunner(cfg, params).run(
        [(p, m, 0.0) for p, m in zip(prompts, max_new)], batch_size=2)
    assert outs == ref
    met = srv.metrics()
    assert met["generated_tokens"] == sum(max_new) == ref_met["generated_tokens"]
    assert met["finished"] == 4 and met["preemptions"] == 0
    # slot refill: mixed budgets on 2 slots must take fewer engine steps than
    # the lockstep equivalent (sum of per-batch maxima)
    assert met["steps"] < 6 + 5 + 2  # static: max(6,3) + max(5,4) + prefills


def test_preemption_recompute_preserves_outputs(qwen_serve):
    """Preemption/refill round trip: the paged no-gather path and the
    gathered-dense oracle must both recompute to token-identical greedy
    streams through block reuse."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).tolist() for _ in range(3)]
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 12, 0.0) for p in prompts], batch_size=3)

    # 8 usable blocks of 8 for three 16+12-token sequences -> must preempt
    for path in ("paged", "gathered"):
        srv = MegaServe(cfg, params, ServeConfig(
            num_slots=3, block_size=8, num_blocks=9, max_blocks_per_slot=4,
            decode_path=path))
        for p in prompts:
            srv.submit(p, 12, arrival=0.0)
        outs = srv.drain()
        assert srv.metrics()["preemptions"] > 0, path
        assert outs == ref, path


def test_paged_kernel_end_to_end_greedy(qwen_serve):
    """Interpret-mode Pallas kernel wired through the full serving loop:
    greedy streams must match the static lockstep engine token-for-token."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(6)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in (8, 16)]
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4,
        decode_path="paged", paged_attn_impl="pallas_interpret"))
    for p in prompts:
        srv.submit(p, 4, arrival=0.0)
    outs = srv.drain()
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 4, 0.0) for p in prompts], batch_size=1)
    assert outs == ref


def test_prefill_bucketing_bounds_compile_cache(qwen_serve):
    """Attention-only families right-pad prompts to power-of-two block
    buckets: many distinct prompt lengths share a handful of prefill
    executables, with identical greedy outputs."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(8)
    lens = [3, 5, 9, 11, 14, 17, 23, 30]
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in lens]
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=33, max_blocks_per_slot=6))
    assert srv._pad_prefill
    for p in prompts:
        srv.submit(p, 3, arrival=0.0)
    outs = srv.drain()
    # 8 distinct lengths spanning 1-4 blocks -> buckets {1, 2, 4} only
    assert set(srv._prefill_cache) <= {1, 2, 4}
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 3, 0.0) for p in prompts], batch_size=1)
    assert outs == ref


def test_decode_path_auto_selection(qwen_serve):
    from repro.core.scope import ProbeSpec, ScopeCollector

    cfg, params = qwen_serve
    scfg = ServeConfig(num_slots=2, block_size=8, num_blocks=17,
                       max_blocks_per_slot=4)
    assert MegaServe(cfg, params, scfg).decode_path == "paged"
    # a live MegaScope collector needs the vmapped per-slot capture
    # semantics -> auto falls back to the gathered oracle
    scope = ScopeCollector(probes=[ProbeSpec("final_hidden", "stats")])
    assert MegaServe(cfg, params, scfg, collector=scope).decode_path == "gathered"
    with pytest.raises(ValueError):
        MegaServe(cfg, params, ServeConfig(
            num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4,
            decode_path="bogus"))


def test_continuous_window_family_griffin():
    """Griffin mixes windowed-attention blocks (paged leaves, window-masked
    kernel) with RG-LRU recurrent blocks (slot-state leaves) — the batched
    paged step must dispatch both correctly."""
    cfg = get_config("recurrentgemma-9b", smoke=True).replace(
        compute_dtype="float32")
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in (8, 16)]
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4))
    assert srv.decode_path == "paged" and not srv._pad_prefill
    kv = srv.kv
    flags = jax.tree.leaves(kv.paged)
    assert any(flags) and not all(flags)     # mixed paged + slot-state
    for p in prompts:
        srv.submit(p, 4, arrival=0.0)
    outs = srv.drain()
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 4, 0.0) for p in prompts], batch_size=1)
    assert outs == ref


def test_continuous_state_family_rwkv():
    cfg = get_config("rwkv6-3b", smoke=True).replace(compute_dtype="float32")
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist() for n in (8, 16)]
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4))
    kv = srv.kv
    assert not any(jax.tree.leaves(kv.paged))      # pure slot-state family
    for p in prompts:
        srv.submit(p, 4, arrival=0.0)
    outs = srv.drain()
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 4, 0.0) for p in prompts], batch_size=1)
    assert outs == ref


def test_budget_and_eos_respected_at_prefill(qwen_serve):
    cfg, params = qwen_serve
    rng = np.random.default_rng(4)
    prompt = rng.integers(2, cfg.vocab_size, size=8).tolist()

    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4))
    rid1 = srv.submit(prompt, 1, arrival=0.0)        # done at prefill
    outs = srv.drain()
    assert len(outs[rid1]) == 1

    # eos emitted by the prefill itself must stop generation immediately
    srv.reset()
    first = outs[rid1][0]
    rid2 = srv.submit(prompt, 10, arrival=0.0, eos_id=first)
    outs = srv.drain()
    assert outs[rid2] == [first]


# ----------------------------------------------------------- integration ---


def test_trace_events_and_scope_captures(qwen_serve):
    from repro.core.scope import ProbeSpec, ScopeCollector

    cfg, params = qwen_serve
    scope = ScopeCollector(probes=[ProbeSpec("final_hidden", "stats")])
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=2, block_size=8, num_blocks=17, max_blocks_per_slot=4),
        collector=scope)
    rng = np.random.default_rng(3)
    srv.submit(rng.integers(2, cfg.vocab_size, size=8).tolist(), 3, arrival=0.0)
    srv.drain()

    events = srv.trace_events()
    kinds = {e.name for e in events}
    assert {"prefill", "decode"} <= kinds
    for e in events:
        assert e.dur >= 0 and e.args.get("tokens", 0) >= 1
    doc = to_chrome(events)                         # MegaScan-compatible
    assert doc["traceEvents"]

    stream = srv.streams[0]
    assert len(stream) == 3
    for item in stream:
        caps = item.captures.get("top", item.captures)
        assert any("final_hidden" in k for k in caps), caps


# -------------------------------------------- speculative decoding ---


@pytest.mark.parametrize("window", [None, 7])
def test_paged_ref_multi_query_matches_per_row(window):
    """q_len > 1 (the spec-decode verify layout) must equal scoring each
    query row separately at its own causal kv_len — causal masking inside
    the query block, window shifted per query."""
    from repro.kernels.paged_attention import paged_attention_ref

    Q = 4
    rng = np.random.default_rng(9)
    q, kp, vp, tables, kv_len = _rand_paged(
        seed=9, S=3, bs=8, K=2, G=2, dh=16, kv_lens=[6, 17, 24]
    )
    q4 = jnp.asarray(rng.standard_normal((3, Q, q.shape[1], 16)), jnp.float32)
    out = paged_attention_ref(q4, kp, vp, tables, kv_len, scale=0.3,
                              window=window)
    assert out.shape == (3, Q, q.shape[1], 16)
    for qi in range(Q):
        row = paged_attention_ref(
            q4[:, qi], kp, vp, tables, kv_len - (Q - 1 - qi), scale=0.3,
            window=window,
        )
        np.testing.assert_allclose(
            np.asarray(out[:, qi]), np.asarray(row), atol=1e-6
        )


@pytest.mark.parametrize("window", [None, 7])
def test_paged_kernel_interpret_multi_query(window):
    from repro.kernels.paged_attention import (
        paged_attention_pallas,
        paged_attention_ref,
    )

    rng = np.random.default_rng(13)
    q, kp, vp, tables, kv_len = _rand_paged(
        seed=13, S=4, bs=8, K=2, G=2, dh=16, kv_lens=[5, 8, 19, 23]
    )
    q4 = jnp.asarray(rng.standard_normal((4, 3, q.shape[1], 16)), jnp.float32)
    ref = paged_attention_ref(q4, kp, vp, tables, kv_len, scale=0.25,
                              window=window)
    ker = paged_attention_pallas(q4, kp, vp, tables, kv_len, scale=0.25,
                                 window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=2e-6)


def test_ngram_drafter_prompt_lookup():
    from repro.serve import NGramDrafter

    d = NGramDrafter(max_ngram=3, min_ngram=1)
    # suffix [7, 8] occurred earlier -> propose what followed it
    assert d.propose([7, 8, 9, 1, 7, 8], 2) == [9, 1]
    assert d.propose([7, 8, 9, 1, 7, 8], 4) == [9, 1, 7, 8]
    # no earlier occurrence of any suffix n-gram -> no proposal
    assert d.propose([1, 2, 3, 4, 5], 3) == []
    assert d.propose([5], 3) == []
    assert d.propose([7, 8, 9], 0) == []
    # the most recent match wins over an older one
    assert d.propose([2, 5, 1, 2, 6, 1, 2], 1) == [6]


def test_scheduler_spec_capacity_and_trim():
    cfg = ServeConfig(num_slots=1, block_size=4, num_blocks=8,
                      max_blocks_per_slot=6, max_prefills_per_step=1)
    s = Scheduler(cfg)
    s.submit(_mk(0, plen=4, max_new=12))
    s.admit(now=0.0)
    assert len(s.blocks[0]) == 1 and s.pos[0] == 4
    # a 4-draft verify writes positions 4..8 -> needs 3 blocks total
    assert s.ensure_capacity({0: 5}) == []
    assert len(s.blocks[0]) == 3
    # only 1 draft accepted (pos -> 6): trim rewinds the high-water mark
    s.advance(0, 2)
    s.trim_blocks()
    assert len(s.blocks[0]) == 2
    assert s.allocator.num_held == 2
    assert list(s.tables[0, 2:]) == [0] * 4


def test_spec_greedy_matches_nonspec_paged(qwen_serve):
    """Speculative greedy streams must be token-identical to plain paged
    decode, while emitting more than one token per accepted verify step."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(21)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (16, 16, 32, 16)]
    max_new = [12, 6, 10, 8]
    base = dict(num_slots=2, block_size=8, num_blocks=33, max_blocks_per_slot=8)

    srv = MegaServe(cfg, params, ServeConfig(**base))
    for p, m in zip(prompts, max_new):
        srv.submit(p, m, arrival=0.0)
    ref = srv.drain()

    spec = MegaServe(cfg, params, ServeConfig(
        **base, spec_decode=True, spec_k=4))
    assert spec.decode_path == "paged"
    for p, m in zip(prompts, max_new):
        spec.submit(p, m, arrival=0.0)
    outs = spec.drain()
    assert outs == ref
    met = spec.metrics()
    assert met["spec_proposed"] > 0 and met["spec_accepted"] > 0
    # accepted drafts compress engine steps below one-token-per-step
    assert met["steps"] < srv.metrics()["steps"]
    names = {e.name for e in spec.trace_events()}
    assert {"draft", "verify", "accept"} <= names


def test_spec_preemption_roundtrip_preserves_outputs(qwen_serve):
    """Preemption-by-recompute under speculation: the drafter is stateless
    given history, so the recompute path must land on identical streams."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).tolist()
               for _ in range(3)]
    ref, _ = StaticRunner(cfg, params).run(
        [(p, 12, 0.0) for p in prompts], batch_size=3)
    srv = MegaServe(cfg, params, ServeConfig(
        num_slots=3, block_size=8, num_blocks=9, max_blocks_per_slot=4,
        spec_decode=True, spec_k=3))
    for p in prompts:
        srv.submit(p, 12, arrival=0.0)
    outs = srv.drain()
    assert srv.metrics()["preemptions"] > 0
    assert outs == ref


def test_spec_griffin_window_family():
    """Windowed-attention griffin (pattern reduced to attn-only: every cache
    leaf is paged) must speculate through the window-masked multi-query
    kernel path with token-identical greedy streams."""
    from dataclasses import replace as dc_replace

    cfg = get_config("recurrentgemma-9b", smoke=True).replace(
        compute_dtype="float32")
    cfg = cfg.replace(griffin=dc_replace(cfg.griffin, pattern=("attn",)))
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).tolist()
               for n in (8, 16)]
    base = dict(num_slots=2, block_size=8, num_blocks=17,
                max_blocks_per_slot=4)
    srv = MegaServe(cfg, params, ServeConfig(**base))
    assert all(jax.tree.leaves(srv.kv.paged))
    for p in prompts:
        srv.submit(p, 8, arrival=0.0)
    ref = srv.drain()
    spec = MegaServe(cfg, params, ServeConfig(
        **base, spec_decode=True, spec_k=3))
    for p in prompts:
        spec.submit(p, 8, arrival=0.0)
    assert spec.drain() == ref


def test_spec_rejects_state_family_and_gathered(qwen_serve):
    cfg, params = qwen_serve
    rcfg = get_config("rwkv6-3b", smoke=True).replace(compute_dtype="float32")
    rparams = get_model(rcfg).init(rcfg, jax.random.PRNGKey(0))
    scfg = dict(num_slots=2, block_size=8, num_blocks=17,
                max_blocks_per_slot=4, spec_decode=True)
    with pytest.raises(ValueError, match="attention-only"):
        MegaServe(rcfg, rparams, ServeConfig(**scfg))
    with pytest.raises(ValueError, match="paged"):
        MegaServe(cfg, params, ServeConfig(**scfg, decode_path="gathered"))


def test_spec_adversarial_drafter_adapts_off(qwen_serve):
    """A drafter that is always wrong must not change outputs, and the
    per-request draft-length adaptation must shut speculation off (plain
    decode steps resume) instead of burning a verify every tick."""
    from repro.serve import RandomDrafter

    cfg, params = qwen_serve
    rng = np.random.default_rng(11)
    prompt = rng.integers(2, cfg.vocab_size, size=16).tolist()
    base = dict(num_slots=2, block_size=8, num_blocks=33, max_blocks_per_slot=8)
    srv = MegaServe(cfg, params, ServeConfig(**base))
    srv.submit(prompt, 24, arrival=0.0)
    ref = srv.drain()

    spec = MegaServe(cfg, params, ServeConfig(
        **base, spec_decode=True, spec_k=4, spec_retry=64),
        drafter=RandomDrafter(cfg.vocab_size, seed=0))
    rid = spec.submit(prompt, 24, arrival=0.0)
    outs = spec.drain()
    assert outs == {rid: ref[0]}
    req = spec.sched.requests[rid]
    assert req.draft_len == 0                   # adapted off
    met = spec.metrics()
    assert met["spec_accept_rate"] < 0.2
    # after adaptation the engine falls back to plain decode ticks
    assert any(e.name == "decode" for e in spec.trace_events())


def test_spec_eos_mid_acceptance_stops_stream(qwen_serve):
    """An eos inside an accepted draft run must cut the stream exactly at
    the eos, matching the non-speculative path."""
    cfg, params = qwen_serve
    rng = np.random.default_rng(4)
    prompt = rng.integers(2, cfg.vocab_size, size=16).tolist()
    base = dict(num_slots=2, block_size=8, num_blocks=33, max_blocks_per_slot=8)
    srv = MegaServe(cfg, params, ServeConfig(**base))
    rid = srv.submit(prompt, 16, arrival=0.0)
    ref = srv.drain()[rid]
    eos = ref[7]
    want = ref[: ref.index(eos) + 1]

    for spec_on in (False, True):
        s = MegaServe(cfg, params, ServeConfig(
            **base, spec_decode=spec_on, spec_k=4))
        r = s.submit(prompt, 16, arrival=0.0, eos_id=eos)
        assert s.drain()[r] == want, f"spec_decode={spec_on}"


def test_poisson_requests_inclusive_budget_range():
    reqs = poisson_requests(64, rate=100.0, max_new_range=(1, 1), seed=0)
    assert {r.max_new for r in reqs} == {1}
    reqs = poisson_requests(256, rate=100.0, max_new_range=(4, 8), seed=0)
    assert min(r.max_new for r in reqs) >= 4
    assert max(r.max_new for r in reqs) == 8     # upper bound reachable


def test_simkit_serving_policy_comparison():
    reqs = poisson_requests(24, rate=200.0, seed=3)
    eng = Engine()
    cont = serving_throughput(eng.run(
        serving_workload(reqs, policy="continuous", num_slots=4)))
    stat = serving_throughput(eng.run(
        serving_workload(reqs, policy="static", num_slots=4, batch_size=4)))
    assert cont["tokens"] == stat["tokens"] == sum(r.max_new for r in reqs)
    assert cont["tokens_per_s"] > stat["tokens_per_s"]


def test_simkit_serving_respects_arrivals():
    reqs = [RequestSpec(rid=0, arrival=0.5, prompt_len=8, max_new=2),
            RequestSpec(rid=1, arrival=1.0, prompt_len=8, max_new=2)]
    res = Engine().run(serving_workload(reqs, policy="continuous", num_slots=2))
    starts = {r.tid: r.start for r in res.records}
    assert starts["prefill_r0"] >= 0.5
    assert starts["prefill_r1"] >= 1.0
