"""Sampler: seeded determinism, temperature/top-k/top-p support + distribution
sanity, and speculative-decoding acceptance (greedy + rejection sampling)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serve.sampler import greedy_verify, rejection_verify, sample

# ---------------------------------------------------------------- sample ---


def _logits(probs):
    return jnp.log(jnp.asarray(probs, jnp.float32))[None, :]


def test_greedy_is_argmax_and_needs_no_key():
    logits = _logits([0.1, 0.2, 0.6, 0.1])
    assert int(sample(logits)[0]) == 2
    assert int(sample(logits, temperature=0.0)[0]) == 2
    # a key without temperature still decodes greedily
    assert int(sample(logits, jax.random.PRNGKey(0))[0]) == 2


def test_seeded_determinism():
    logits = jnp.asarray(
        np.random.default_rng(0).standard_normal((4, 64)), jnp.float32
    )
    a = sample(logits, jax.random.PRNGKey(7), temperature=1.0)
    b = sample(logits, jax.random.PRNGKey(7), temperature=1.0)
    c = sample(logits, jax.random.PRNGKey(8), temperature=1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_low_temperature_approaches_greedy():
    logits = _logits([0.05, 0.9, 0.05])
    toks = [
        int(sample(logits, jax.random.PRNGKey(i), temperature=0.01)[0])
        for i in range(16)
    ]
    assert set(toks) == {1}


def test_top_k_restricts_support():
    logits = _logits([0.4, 0.3, 0.2, 0.1])
    seen = {
        int(sample(logits, jax.random.PRNGKey(i), temperature=2.0, top_k=2)[0])
        for i in range(64)
    }
    assert seen <= {0, 1} and len(seen) == 2


def test_top_p_restricts_support_to_minimal_nucleus():
    # cumulative mass before each sorted token: 0, .5, .8, .95 -> top_p=0.6
    # keeps exactly {0, 1} (the smallest covering set includes token 1)
    logits = _logits([0.5, 0.3, 0.15, 0.05])
    seen = {
        int(sample(logits, jax.random.PRNGKey(i), temperature=1.0, top_p=0.6)[0])
        for i in range(128)
    }
    assert seen == {0, 1}
    # a tiny top_p still keeps the argmax
    seen = {
        int(sample(logits, jax.random.PRNGKey(i), temperature=1.0, top_p=1e-6)[0])
        for i in range(16)
    }
    assert seen == {0}


def test_temperature_sampling_matches_softmax_distribution():
    probs = np.asarray([0.45, 0.35, 0.15, 0.05])
    logits = jnp.log(jnp.asarray(probs, jnp.float32))
    n = 4000
    toks = np.asarray(
        jax.random.categorical(jax.random.PRNGKey(0), logits, shape=(n,))
    )
    # the sampler must agree with the same categorical draw
    toks2 = np.asarray(
        sample(jnp.tile(logits[None], (n, 1)), jax.random.PRNGKey(0),
               temperature=1.0)
    )
    freq = np.bincount(toks2, minlength=4) / n
    np.testing.assert_allclose(freq, probs, atol=0.03)
    assert toks.shape == toks2.shape


# --------------------------------------------------------- greedy_verify ---


def test_greedy_verify_full_acceptance_emits_bonus():
    n, emitted = greedy_verify(np.asarray([5, 6, 7, 9]), [5, 6, 7])
    assert n == 3 and emitted == [5, 6, 7, 9]


def test_greedy_verify_first_mismatch_corrects():
    n, emitted = greedy_verify(np.asarray([5, 8, 7, 9]), [5, 6, 7])
    assert n == 1 and emitted == [5, 8]
    n, emitted = greedy_verify(np.asarray([4, 8, 7, 9]), [5, 6, 7])
    assert n == 0 and emitted == [4]


def test_greedy_verify_empty_draft_is_plain_decode():
    n, emitted = greedy_verify(np.asarray([3, 0, 0]), [])
    assert n == 0 and emitted == [3]


# ------------------------------------------------------ rejection_verify ---


def test_rejection_verify_deterministic_extremes():
    V = 4
    # target puts all mass on the drafts -> always accepted + bonus from row k
    p = np.zeros((3, V))
    p[0, 1] = p[1, 2] = 1.0
    p[2, 3] = 1.0
    n, emitted = rejection_verify(p, [1, 2], np.random.default_rng(0))
    assert n == 2 and emitted == [1, 2, 3]
    # target puts zero mass on the draft -> rejected at row 0, correction
    # drawn from the residual (= target with the draft token zeroed)
    p = np.zeros((2, V))
    p[0, 2] = 1.0
    for seed in range(8):
        n, emitted = rejection_verify(p, [1], np.random.default_rng(seed))
        assert n == 0 and emitted == [2]


def test_rejection_verify_preserves_target_marginal():
    """The emitted first token must be distributed exactly like the target
    distribution, whatever (deterministic) token the drafter proposed."""
    V = 4
    target = np.asarray([0.5, 0.25, 0.15, 0.1])
    p = np.zeros((2, V))
    p[0] = target
    p[1] = 1.0 / V
    rng = np.random.default_rng(42)
    n_trials = 6000
    for draft_tok in (0, 2):
        counts = np.zeros(V)
        for _ in range(n_trials):
            _, emitted = rejection_verify(p, [draft_tok], rng)
            counts[emitted[0]] += 1
        np.testing.assert_allclose(counts / n_trials, target, atol=0.03)


def test_rejection_verify_emits_between_1_and_k_plus_1():
    rng = np.random.default_rng(3)
    p = np.full((4, 8), 1.0 / 8)
    for _ in range(32):
        n, emitted = rejection_verify(p, [1, 2, 3], rng)
        assert 0 <= n <= 3 and len(emitted) == n + 1
