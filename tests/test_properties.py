"""Hypothesis property tests on system invariants (deliverable c)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic few-example fallback
    from _hypothesis_shim import given, settings
    import _hypothesis_shim as st

import jax

from repro.core.dpp.executor import build_time_table
from repro.core.dpp.schedule import sched_wave
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import ModelProfile, Topology, build_training_step
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.parallel.sharding import DEFAULT_RULES, logical_to_spec


# ----------------------------------------------------------- scheduling ----


@settings(max_examples=40, deadline=None)
@given(n_micro=st.integers(1, 12), n_chunks=st.integers(1, 4),
       wave=st.integers(1, 12))
def test_wave_schedule_is_complete_and_unique(n_micro, n_chunks, wave):
    steps = sched_wave(n_micro, n_chunks, wave)
    fwd = [(m, c) for k, m, c in steps if k == "F"]
    bwd = [(m, c) for k, m, c in steps if k == "B"]
    assert sorted(fwd) == sorted(bwd)
    assert len(set(fwd)) == n_micro * n_chunks == len(fwd)
    # B(m, c) never precedes F(m, c)
    seen = set()
    for k, m, c in steps:
        if k == "F":
            seen.add((m, c))
        else:
            assert (m, c) in seen


@settings(max_examples=20, deadline=None)
@given(n_micro=st.integers(1, 6), n_chunks=st.integers(1, 3),
       n_stages=st.integers(1, 4), wave=st.integers(1, 6))
def test_time_table_legalizes_any_wave(n_micro, n_chunks, n_stages, wave):
    table = build_time_table(
        sched_wave(n_micro, n_chunks, wave), n_stages, n_chunks, n_micro
    )
    # every stage runs every (m, c) exactly once
    run = np.asarray(table.run_act)
    m = np.asarray(table.run_m)
    c = np.asarray(table.run_c)
    for s in range(n_stages):
        done = {(int(m[t, s]), int(c[t, s])) for t in range(table.steps) if run[t, s]}
        assert len(done) == n_micro * n_chunks


@settings(max_examples=15, deadline=None)
@given(dp=st.integers(1, 2), pp=st.integers(1, 3), tp=st.integers(1, 2),
       n_micro=st.integers(1, 4))
def test_1f1b_workload_never_deadlocks(dp, pp, tp, n_micro):
    topo = Topology(dp=dp, pp=pp, tp=tp)
    order = build_training_step(topo, ModelProfile(), n_micro=n_micro)
    res = Engine().run(order)  # raises DeadlockError on schedule bugs
    assert res.makespan > 0
    # conservation: forward+backward compute tasks on every rank
    per_rank = res.by_rank()
    for r, recs in per_rank.items():
        n_comp = sum(1 for t in recs if t.kind == "compute")
        assert n_comp == 2 * n_micro * ModelProfile().n_chunks


# ------------------------------------------------------------- sharding ----


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_logical_spec_axes_never_collide_or_overdivide(data):
    from jax.sharding import AbstractMesh

    # abstract mesh: shape-only, no physical devices required
    try:
        mesh = AbstractMesh((2, 2, 2), ("pod", "data", "model"))
    except TypeError:  # jax 0.4.x signature: AbstractMesh(((name, size), ...))
        mesh = AbstractMesh((("pod", 2), ("data", 2), ("model", 2)))
    names = list(DEFAULT_RULES)
    k = data.draw(st.integers(1, 4))
    axes = tuple(data.draw(st.sampled_from(names)) for _ in range(k))
    shape = tuple(data.draw(st.sampled_from([1, 2, 3, 4, 6, 8, 128])) for _ in range(k))
    spec = logical_to_spec(axes, shape, mesh, DEFAULT_RULES)
    used: list[str] = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        total = 1
        for ax in parts:
            assert ax not in used, "mesh axis used twice"
            used.append(ax)
            total *= mesh.shape[ax]
        assert shape[i] % total == 0, "sharding must divide the dim"


# ------------------------------------------------------------------ data ---


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 1000))
def test_data_determinism_property(seed, step):
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=2, seed=seed)
    a = SyntheticTokens(cfg).batch_at(step)
    b = SyntheticTokens(cfg).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 512
    # targets are tokens shifted by one position
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


# ------------------------------------------------------------ compression --


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-4, 1e3))
def test_grad_compression_relative_error_bound(seed, scale):
    from repro.ft.compress import GradCompressor

    import jax.numpy as jnp

    comp = GradCompressor(block=64, bits=8)
    g = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * scale
    deq, _ = comp.apply({"g": g}, {"g": jnp.zeros_like(g)})
    num = float(jnp.linalg.norm(deq["g"] - g))
    den = float(jnp.linalg.norm(g)) + 1e-30
    assert num / den < 0.02


# ----------------------------------------------- composed-plan invariants --


@settings(max_examples=30, deadline=None)
@given(S=st.integers(1, 4), C=st.integers(1, 3), g=st.integers(1, 3))
def test_restack_params_is_a_permutation_roundtrip(S, C, g):
    """restack_params is a pure permutation of the stacked-group axis: cell
    (s, c) holds global groups (c*S + s)*g + j, and the inverse
    swapaxes/reshape recovers the canonical [G, ...] stacking exactly."""
    import jax.numpy as jnp

    from repro.models import pipeline as pl

    G = S * C * g
    layout = pl.PipelineLayout("seg0", ("dense",), G, S, C, g)
    leaf = jnp.arange(float(G * 2)).reshape(G, 2)
    tree = {"w": leaf, "b": leaf[:, :1] + 100.0}
    stacked = pl.restack_params(tree, layout)
    w = np.asarray(stacked["w"])
    assert w.shape == (S, C, g, 2)
    for s in range(S):
        for c in range(C):
            for j in range(g):
                np.testing.assert_array_equal(
                    w[s, c, j], np.asarray(leaf[(c * S + s) * g + j])
                )
    back = jnp.swapaxes(stacked["w"], 0, 1).reshape(G, 2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(leaf))
    assert np.asarray(stacked["b"]).shape == (S, C, g, 1)


@settings(max_examples=40, deadline=None)
@given(dp=st.sampled_from([1, 2]), tp=st.sampled_from([1, 2]),
       pp=st.integers(1, 4), mult=st.integers(1, 3),
       n_chunks=st.sampled_from([1, 2]),
       schedule=st.sampled_from(["1f1b", "dfc", "bfc", "wave"]))
def test_forward_order_is_dp_local_and_complete(dp, tp, pp, mult, n_chunks, schedule):
    """Under any composed plan the forward order visits every *dp-local*
    (microbatch, chunk) pair exactly once — dp shards the microbatch axis,
    tp never changes the traversal."""
    from repro.parallel.plan import ParallelPlan, forward_order

    plan = ParallelPlan(
        dp=dp, tp=tp, pp=pp, n_micro=dp * mult, n_chunks=n_chunks,
        schedule=schedule, wave=max(1, mult // 2),
    ).validate()
    fwd = [(m, c) for k, m, c in forward_order(plan) if k == "F"]
    want = {(m, c) for m in range(mult) for c in range(n_chunks)}
    assert len(fwd) == len(want) and set(fwd) == want
    # tp is orthogonal to the traversal
    base = ParallelPlan(dp=dp, tp=1, pp=pp, n_micro=dp * mult,
                        n_chunks=n_chunks, schedule=schedule,
                        wave=max(1, mult // 2))
    assert forward_order(base) == forward_order(plan)


@settings(max_examples=25, deadline=None)
@given(dp=st.sampled_from([1, 2]), pp=st.integers(1, 3),
       mult=st.integers(1, 3), n_chunks=st.sampled_from([1, 2]),
       schedule=st.sampled_from(["1f1b", "dfc", "bfc", "wave"]))
def test_time_table_dispatch_and_dataflow_under_composed_plans(
    dp, pp, mult, n_chunks, schedule
):
    """The legalized table for a composed plan (a) dispatches every
    (microbatch, chunk) on every stage exactly once, and (b) never runs a
    consumer cell before its producer: stage s needs stage s-1's (m, c),
    and chunk c's entry stage needs the last stage's (m, c-1)."""
    from repro.parallel.plan import ParallelPlan, forward_order

    plan = ParallelPlan(
        dp=dp, pp=pp, n_micro=dp * mult, n_chunks=n_chunks,
        schedule=schedule, wave=max(1, mult // 2),
    ).validate()
    nm = plan.n_micro_local
    table = build_time_table(forward_order(plan), pp, n_chunks, nm)
    run = np.asarray(table.run_act)
    ms = np.asarray(table.run_m)
    cs = np.asarray(table.run_c)
    times: dict[tuple[int, int, int], int] = {}
    for t in range(table.steps):
        for s in range(pp):
            if run[t, s]:
                key = (int(ms[t, s]), int(cs[t, s]), s)
                assert key not in times, f"duplicate dispatch {key}"
                times[key] = t
    assert len(times) == pp * nm * n_chunks
    for (m, c, s), t in times.items():
        if s > 0:
            assert times[(m, c, s - 1)] < t
        elif c > 0:
            assert times[(m, c - 1, pp - 1)] < t
