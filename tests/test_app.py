"""repro.app: RunConfig layering, Session plugins, CLI subcommands, shims.

Covers the acceptance surface of the unified entry point:
  * RunConfig layering (defaults -> workload -> JSON -> --set -> flags) with
    typed coercion and loud failure on typos;
  * CLI smoke runs for every subcommand on CPU smoke configs;
  * plugin on/off equivalence: module plugins must not perturb numerics —
    train-loss trajectories and greedy serve tokens are identical with
    modules disabled vs the seed code paths (and with passive modules on);
  * the deprecation shims (`repro.launch.train/serve`) still run and defer
    to the same implementation.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.app import (
    PLUGIN_REGISTRY,
    ModulePlugin,
    RunConfig,
    Session,
    build_run_config,
)
from repro.app.cli import run as cli_run
from repro.app.config import apply_sets, set_by_path

ROOT = Path(__file__).resolve().parent.parent
ARCH = "qwen2-0.5b"

# keep jitted-step compiles tiny: the equivalence/CLI tests only care about
# wiring, not model scale
TINY_TRAIN = ["--set", "train.seq_len=32", "--set", "train.global_batch=2"]


# ---------------------------------------------------------------------------
# RunConfig layering
# ---------------------------------------------------------------------------


class TestRunConfig:
    def test_defaults_and_workload_layer(self):
        cfg = RunConfig.for_workload("train")
        assert cfg.workload == "train"
        assert cfg.modules == ("scan", "metrics")  # observability on by default
        assert cfg.train.steps == 100
        cfg = RunConfig.for_workload("dryrun")
        assert cfg.modules == ()             # nothing to attach to

    def test_set_by_path_coerces_types(self):
        cfg = RunConfig.for_workload("serve")
        set_by_path(cfg, "serve.spec_k", "6")
        set_by_path(cfg, "serve.rate", "2.5")
        set_by_path(cfg, "serve.continuous", "true")
        set_by_path(cfg, "serve.prompt_lens", "8,16")
        assert cfg.serve.spec_k == 6
        assert cfg.serve.rate == 2.5
        assert cfg.serve.continuous is True
        assert cfg.serve.prompt_lens == (8, 16)

    def test_unknown_key_fails_loudly(self):
        cfg = RunConfig.for_workload("train")
        with pytest.raises(KeyError):
            set_by_path(cfg, "train.bogus", "1")
        with pytest.raises(KeyError):
            set_by_path(cfg, "nosection.x", "1")
        with pytest.raises(KeyError):
            set_by_path(cfg, "train", "1")   # a section, not a field

    def test_apply_sets_parses_key_value(self):
        cfg = RunConfig.for_workload("train")
        apply_sets(cfg, ["train.lr=1e-3", "smoke=1"])
        assert cfg.train.lr == pytest.approx(1e-3)
        assert cfg.smoke is True
        with pytest.raises(ValueError):
            apply_sets(cfg, ["no_equals_sign"])

    def test_json_then_sets_then_flags_layering(self, tmp_path):
        p = tmp_path / "run.json"
        p.write_text(json.dumps({
            "arch": ARCH,
            "train": {"steps": 7, "lr": 9e-4},
            "modules": ["scan", "scope"],
        }))
        cfg = build_run_config(
            "train", config_json=str(p),
            sets=["train.lr=5e-4"],          # --set overrides JSON
            train__steps=3,                   # explicit flag overrides both
        )
        assert cfg.arch == ARCH
        assert cfg.train.steps == 3
        assert cfg.train.lr == pytest.approx(5e-4)
        assert cfg.modules == ("scan", "scope")

    def test_modules_none_and_validation(self):
        cfg = build_run_config("train", sets=["modules=none"])
        assert cfg.modules == ()
        with pytest.raises(ValueError):
            build_run_config("train", sets=["modules=scan,notamodule"])

    def test_registry_has_all_four_modules(self):
        assert set(PLUGIN_REGISTRY) >= {"scan", "scope", "fbd", "dpp"}


# ---------------------------------------------------------------------------
# CLI smoke: every subcommand on a CPU smoke config
# ---------------------------------------------------------------------------


class TestCLISmoke:
    def test_train_subcommand(self):
        res = cli_run(["train", "--arch", ARCH, "--smoke", "--steps", "2",
                       "--modules", "scan,scope,dpp,fbd", *TINY_TRAIN])
        assert len(res["history"]) >= 1
        assert res["scan"]["events"] >= 3           # init + 2 steps
        assert res["dpp"]["schedule"]
        assert res["fbd"]["speedup"] > 0
        assert any("mlp_hidden" in k for k in res["scope"]["captured"])

    def test_serve_subcommand_continuous(self):
        res = cli_run(["serve", "--arch", ARCH, "--smoke", "--continuous",
                       "--requests", "4", "--max-new", "4", "--rate", "1000"])
        assert res["serve_metrics"]["generated_tokens"] > 0
        assert res["scan"]["events"] > 0            # serving traces via scan

    def test_serve_scope_captures_surface(self):
        """MegaServe attaches captures per generated token; the scope plugin
        must see them like training captures."""
        res = cli_run(["serve", "--arch", ARCH, "--smoke", "--continuous",
                       "--requests", "3", "--max-new", "4", "--rate", "1000",
                       "--modules", "scan,scope"])
        assert any("mlp_hidden" in k for k in res["scope"]["captured"])

    def test_serve_subcommand_static(self):
        res = cli_run(["serve", "--arch", ARCH, "--smoke",
                       "--batch", "2", "--prompt-len", "8", "--max-new", "4"])
        assert res["serve_metrics"]["decode_s"] >= 0

    def test_trace_subcommand(self, tmp_path):
        out = tmp_path / "scan"
        res = cli_run(["trace", "--out", str(out), "--slow-rank", "3",
                       "--iters", "2"])
        assert res["truth"]["detected"] is True
        assert (out / "trace.json").exists()
        assert (out / "diagnosis.json").exists()

    def test_trace_out_shared_across_workloads(self, tmp_path):
        """--trace-out works for serving too (satellite: chrome export is
        hoisted out of the train launcher into the shared CLI)."""
        t = tmp_path / "serve_trace.json"
        cli_run(["serve", "--arch", ARCH, "--smoke", "--continuous",
                 "--requests", "4", "--max-new", "6", "--rate", "1000",
                 "--trace-out", str(t)])
        doc = json.loads(t.read_text())
        names = {e["name"] for e in doc["traceEvents"]}
        assert "prefill" in names and "decode" in names

    def test_dryrun_subcommand_subprocess(self, tmp_path):
        """dryrun must run from a fresh process (XLA_FLAGS ordering); the
        host-mesh smoke path lowers+compiles a real cell on CPU."""
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env["REPRO_DRYRUN_DEVICES"] = "8"
        proc = subprocess.run(
            [sys.executable, "-m", "repro", "dryrun", "--arch", ARCH,
             "--shape", "train_4k", "--smoke", "--host-mesh",
             "--out", str(tmp_path)],
            cwd=ROOT, env=env, capture_output=True, text=True, timeout=560,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        (cell,) = tmp_path.glob("*.json")
        res = json.loads(cell.read_text())
        assert res["flops_per_device"] > 0
        assert res["memory"]["peak_est_bytes"] > 0


# ---------------------------------------------------------------------------
# plugin on/off equivalence vs the seed code paths
# ---------------------------------------------------------------------------


def _session_train_losses(modules, steps=3):
    cfg = RunConfig.for_workload("train", arch=ARCH, smoke=True,
                                 modules=modules)
    cfg.train.steps = steps
    cfg.train.seq_len = 32
    cfg.train.global_batch = 2
    cfg.train.log_every = 1
    _, history = Session(cfg).run()
    return [h["loss"] for h in history]


class TestEquivalence:
    def test_train_loss_identical_modules_on_off_and_seed(self):
        from repro.app.session import pick_mesh
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig
        from repro.parallel.profiles import rules_for
        from repro.parallel.sharding import axis_rules
        from repro.train.loop import LoopConfig, train
        from repro.train.optim import OptimizerConfig

        steps = 3
        off = _session_train_losses((), steps)
        on = _session_train_losses(("scan", "scope", "dpp", "fbd"), steps)

        # the seed path: hand-wire what the old launcher did — the same
        # mesh + sharding rules, the loop called directly (sharding changes
        # reduction order, so the mesh context must match to compare)
        mcfg = get_config(ARCH, smoke=True)
        mesh = pick_mesh("auto")
        with mesh, axis_rules(mesh, rules_for(mcfg, "train")):
            _, hist = train(
                mcfg,
                OptimizerConfig(lr=3e-4, warmup_steps=5, total_steps=steps),
                DataConfig(vocab_size=mcfg.vocab_size, seq_len=32,
                           global_batch=2),
                LoopConfig(n_steps=steps, log_every=1),
            )
        seed = [h["loss"] for h in hist]

        # modules disabled must be bit-identical to the seed path
        np.testing.assert_array_equal(off, seed)
        # passive modules must not perturb training (probe capture outputs
        # may legally alter XLA fusion, so allow float-noise tolerance)
        np.testing.assert_allclose(on, seed, rtol=1e-5, atol=1e-6)

    def test_serve_tokens_identical_modules_on_off_and_seed(self):
        import jax

        from repro.configs import get_config
        from repro.models import get_model
        from repro.serve import MegaServe
        from repro.serve.server import make_poisson_workload

        def run_session(modules):
            cfg = RunConfig.for_workload("serve", arch=ARCH, smoke=True,
                                         modules=modules)
            cfg.serve.continuous = True
            cfg.serve.requests = 4
            cfg.serve.max_new = 6
            cfg.serve.rate = 1000.0
            outs, _ = Session(cfg).run()
            return outs

        off = run_session(())
        on = run_session(("scan", "dpp", "fbd"))

        # seed path: hand-wired MegaServe over the same workload
        mcfg = get_config(ARCH, smoke=True)
        m = get_model(mcfg)
        params = m.init(mcfg, jax.random.PRNGKey(0))
        specs, prompts, serve_cfg = make_poisson_workload(
            mcfg, n=4, rate=1000.0, prompt_lens=(16, 32, 64, 128, 256),
            max_new_range=(1, 6), num_slots=4, block_size=16,
            num_blocks=0, seed=0,
        )
        srv = MegaServe(mcfg, params, serve_cfg)
        for s in specs:
            srv.submit(prompts[s.rid], s.max_new, arrival=s.arrival)
        seed = srv.drain()

        assert off == seed
        assert on == seed


# ---------------------------------------------------------------------------
# Session plumbing: hooks, from_session, custom plugins
# ---------------------------------------------------------------------------


class TestSessionPlumbing:
    def test_step_hooks_fire_per_step(self):
        calls = []

        class Spy(ModulePlugin):
            name = "spy"

            def wrap_step(self, fn):
                calls.append("wrap")
                return fn

            def on_step(self, session, events, metrics):
                calls.append(("step", [e.name for e in events]))

            def finalize(self, session):
                return {"steps_seen": sum(1 for c in calls if c != "wrap")}

        cfg = RunConfig.for_workload("train", arch=ARCH, smoke=True)
        cfg.train.steps = 2
        cfg.train.seq_len = 32
        cfg.train.global_batch = 2
        s = Session(cfg, plugins=[Spy(cfg)])
        s.run()
        assert calls.count("wrap") == 1
        step_calls = [c for c in calls if c != "wrap"]
        assert len(step_calls) == 2
        # tracer disabled without the scan plugin -> no events observed,
        # but the hook still fires uniformly
        assert s.results["spy"]["steps_seen"] == 2

    def test_scan_plugin_owns_tracer_and_from_session(self):
        import jax

        from repro.models import get_model
        from repro.serve.scheduler import ServeConfig

        cfg = RunConfig.for_workload("serve", arch=ARCH, smoke=True)
        s = Session(cfg)
        assert s.tracer.enabled        # scan is in the default module set
        mcfg = s.model_cfg
        params = get_model(mcfg).init(mcfg, jax.random.PRNGKey(0))
        srv_cfg = ServeConfig(num_slots=2, num_blocks=17, block_size=16,
                              max_blocks_per_slot=8)
        from repro.serve import MegaServe

        srv = MegaServe.from_session(s, params, srv_cfg)
        assert srv.tracer is s.tracer
        assert srv.collector is s.collector

    def test_train_tracer_default_unified(self):
        """Satellite: train() no longer silently disables tracing — its
        default matches MegaServe's (enabled)."""
        import inspect

        from repro.train.loop import train as train_fn

        src = inspect.getsource(train_fn)
        assert "enabled=True" in src and "enabled=False" not in src


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


class TestShims:
    def test_launch_train_shim(self, capsys):
        from repro.launch.train import main as legacy_train

        with pytest.warns(DeprecationWarning, match="python -m repro train"):
            legacy_train(["--arch", ARCH, "--smoke", "--steps", "2",
                          "--seq-len", "32", "--global-batch", "2"])
        out = capsys.readouterr().out
        assert "loss" in out

    def test_launch_serve_shim(self, capsys):
        from repro.launch.serve import main as legacy_serve

        with pytest.warns(DeprecationWarning, match="python -m repro serve"):
            legacy_serve(["--arch", ARCH, "--smoke", "--continuous",
                          "--requests", "2", "--max-new", "2",
                          "--rate", "1000"])
        out = capsys.readouterr().out
        assert "tokens_per_s" in out
