"""Cross-axis parallelism parity matrix (PR-8 headline tests).

Systematic sweep of ``(dp, tp, pp) in {1,2}^3 x grad_accum in {1,2} x
schedule in {1f1b, wave}`` on the forced-host-device mesh: every *runnable*
cell must match the fused single-device train step (same grad_accum) to fp32
tolerance over a short loss trajectory, pipelined ga=1 cells additionally
gate on explicit per-leaf gradient parity, and every *must-refuse* cell must
assert its guard instead of silently replicating or miscomputing.

pp=1 cells run the fused step under a (data, model) host mesh — the sharded
DP/TP path — so the matrix covers both executors with one reference.
"""

import os

# host-device mesh (must be set before jax import)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.dpp.executor import build_time_table
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_pipeline_mesh
from repro.models import lm
from repro.models import pipeline as pl
from repro.parallel.plan import ParallelPlan, forward_order, resolve_plan
from repro.parallel.sharding import DEFAULT_RULES, axis_rules
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

TINY = ModelConfig(
    name="pp-tiny", family="dense", num_layers=4, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128, attn_kv_chunk=16,
    logits_chunk=16, vocab_pad_to=64,
    param_dtype="float32", compute_dtype="float32", remat="none",
)
OCFG = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)
BATCH, SEQ, N_STEPS = 8, 32, 2   # seq > attn_kv_chunk: chunked-flash path


def _dataset():
    return SyntheticTokens(DataConfig(
        vocab_size=TINY.vocab_size, seq_len=SEQ, global_batch=BATCH,
    ))


@functools.lru_cache(maxsize=None)
def _state0():
    return init_train_state(TINY, jax.random.PRNGKey(0))


def _run(step_fn, n_steps=N_STEPS):
    ds = _dataset()
    state = jax.tree.map(lambda x: x, _state0())
    losses = []
    for i in range(n_steps):
        state, m = step_fn(state, ds.batch_at(i))
        losses.append(float(m["loss"]))
    return losses


@functools.lru_cache(maxsize=None)
def _reference(ga: int):
    """Fused single-device trajectory at grad_accum=ga (computed once)."""
    return tuple(_run(jax.jit(make_train_step(TINY, OCFG, grad_accum=ga))))


def _cells():
    out = []
    for dp in (1, 2):
        for tp in (1, 2):
            for pp in (1, 2):
                for ga in (1, 2):
                    for sched in ("1f1b", "wave"):
                        if pp == 1 and sched != "1f1b":
                            continue  # schedule is a pipeline knob
                        out.append(pytest.param(
                            dp, tp, pp, ga, sched,
                            id=f"dp{dp}-tp{tp}-pp{pp}-ga{ga}-{sched}",
                        ))
    return out


@pytest.mark.parametrize("dp,tp,pp,ga,sched", _cells())
def test_matrix_cell_loss_parity(dp, tp, pp, ga, sched):
    if dp * tp * pp > len(jax.devices()):
        pytest.skip(f"needs {dp * tp * pp} devices")
    ref = _reference(ga)
    if pp == 1:
        if dp == tp == 1:
            # the reference itself; nothing to shard
            got = _run(jax.jit(make_train_step(TINY, OCFG, grad_accum=ga)))
        else:
            # sharded DP/TP path: fused step under a (data, model) mesh
            mesh = jax.make_mesh((dp, tp), ("data", "model"))
            with mesh, axis_rules(mesh, DEFAULT_RULES):
                got = _run(jax.jit(make_train_step(TINY, OCFG, grad_accum=ga)))
    else:
        plan = resolve_plan(ParallelPlan(
            dp=dp, tp=tp, pp=pp, n_micro=2 * dp, schedule=sched,
        ))
        mesh = make_pipeline_mesh(pp, dp, tp)
        with mesh, axis_rules(mesh, DEFAULT_RULES):
            step = jax.jit(make_train_step(
                TINY, OCFG, plan=plan, mesh=mesh, grad_accum=ga,
            ))
            got = _run(step)
    np.testing.assert_allclose(got, ref, rtol=2e-5)


@pytest.mark.parametrize("dp,tp", [(2, 1), (1, 2), (2, 2)])
def test_matrix_composed_grad_parity(dp, tp):
    """Explicit per-leaf gradient parity for composed pp=2 cells: the
    pipelined-sharded gradient must match the fused single-device gradient,
    leaf by leaf — dp cotangent psum, tp slice reassembly, and the ppermute
    transpose all checked in one gate."""
    pp = 2
    if dp * tp * pp > len(jax.devices()):
        pytest.skip(f"needs {dp * tp * pp} devices")
    plan = resolve_plan(ParallelPlan(dp=dp, tp=tp, pp=pp, n_micro=2 * dp))
    layout = pl.pipeline_layout(TINY, pp, plan.n_chunks, tp=tp)
    table = build_time_table(
        forward_order(plan), pp, plan.n_chunks, plan.n_micro_local,
    )
    mesh = make_pipeline_mesh(pp, dp, tp)
    params = lm.init(TINY, jax.random.PRNGKey(0))
    batch = _dataset().batch_at(0)

    g_ref = jax.grad(lambda p: lm.loss_fn(TINY, p, batch)[0])(params)
    with mesh, axis_rules(None):
        g_pp = jax.jit(jax.grad(lambda p: pl.pipeline_loss(
            TINY, p, batch, layout=layout, table=table, mesh=mesh,
            n_micro=plan.n_micro, dp=dp)[0]))(params)
    flat_ref, flat_pp = jax.tree.leaves(g_ref), jax.tree.leaves(g_pp)
    assert len(flat_ref) == len(flat_pp)
    for a, b in zip(flat_ref, flat_pp):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=5e-4, atol=1e-5,
        )


def test_seq64_pipeline_regression():
    """Regression: pp=2 at seq_len=64 (4 chunked-flash KV chunks) used to
    crash with a manual-axes tracing error because the flash custom_vjp's
    backward traces lazily during the gradient pull-back, *after* the
    forward's ``axis_rules(None)`` scope had exited.  The pipelined train
    step now keeps the whole grad computation inside that scope; this cell
    must match the fused step exactly."""
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    seq = 64
    ds = SyntheticTokens(DataConfig(
        vocab_size=TINY.vocab_size, seq_len=seq, global_batch=4,
    ))

    def losses(plan=None, mesh=None):
        state = init_train_state(TINY, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(TINY, OCFG, plan=plan, mesh=mesh))
        out = []
        for i in range(2):
            state, m = step(state, ds.batch_at(i))
            out.append(float(m["loss"]))
        return out

    ref = losses()
    plan = resolve_plan(ParallelPlan(pp=2, n_micro=2))
    got = losses(plan=plan, mesh=make_pipeline_mesh(2))
    np.testing.assert_allclose(got, ref, rtol=2e-5)


# ------------------------------------------------------- must-refuse cells --


def test_refuses_indivisible_micro_over_dp():
    with pytest.raises(ValueError, match="not divisible by dp"):
        resolve_plan(ParallelPlan(pp=2, dp=2, n_micro=3))


def test_refuses_mismatched_mesh_shape():
    plan = resolve_plan(ParallelPlan(pp=2, dp=2, n_micro=4))
    with pytest.raises(ValueError, match="mesh shaped"):
        make_train_step(TINY, OCFG, plan=plan, mesh=make_pipeline_mesh(2))


def test_refuses_tp_on_non_dense_family():
    rwkv = get_config("rwkv6-3b", smoke=True)
    with pytest.raises(ValueError, match="dense GQA"):
        pl.pipeline_layout(rwkv, pp=2, tp=2)


def test_refuses_tp_on_indivisible_widths():
    with pytest.raises(ValueError, match="divide"):
        pl.pipeline_layout(TINY.replace(num_kv_heads=1), pp=2, tp=2)


def test_refuses_layers_indivisible_by_cells():
    with pytest.raises(ValueError, match="not divisible"):
        pl.pipeline_layout(TINY.replace(num_layers=6), pp=2, n_chunks=2)


def test_refuses_batch_indivisible_by_micro():
    plan = resolve_plan(ParallelPlan(pp=2, n_micro=3))
    mesh = make_pipeline_mesh(2)
    layout = pl.pipeline_layout(TINY, 2, 1)
    table = build_time_table(forward_order(plan), 2, 1, plan.n_micro_local)
    batch = _dataset().batch_at(0)   # global batch 8, n_micro 3
    with pytest.raises(ValueError, match="not divisible by n_micro"):
        pl.pipeline_loss(TINY, lm.init(TINY, jax.random.PRNGKey(0)), batch,
                         layout=layout, table=table, mesh=mesh, n_micro=3)


def test_refuses_compressor_without_data_axis():
    from repro.ft.compress import GradCompressor

    plan = resolve_plan(ParallelPlan(pp=2))
    with pytest.raises(ValueError, match="no data axis"):
        make_train_step(TINY, OCFG, plan=plan, mesh=make_pipeline_mesh(2),
                        compressor=GradCompressor())
