"""MegaRoute: traffic generators (bursty MMPP / diurnal), placement +
SLO-admission policies shared between the offline ``router_workload``
evaluator and the live ``Router``, disaggregated prefill/decode KV
migration, chunked prefill, and the router-vs-single-engine greedy
token-identity oracles."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import (
    PlacementView,
    ServeProfile,
    admission_decision,
    bursty_requests,
    diurnal_requests,
    place,
    poisson_requests,
    router_summary,
    router_workload,
)
from repro.models import get_model
from repro.serve import (
    MegaServe,
    PagedKVCache,
    PoolSpec,
    Request,
    Router,
    RouterConfig,
    Scheduler,
    ServeConfig,
)

# ----------------------------------------------------- traffic generators ---


def test_bursty_requests_deterministic_and_overdispersed():
    a = bursty_requests(300, 40.0, prompt_lens=(16, 32), seed=7)
    b = bursty_requests(300, 40.0, prompt_lens=(16, 32), seed=7)
    assert [(r.rid, r.arrival, r.prompt_len, r.max_new) for r in a] == \
           [(r.rid, r.arrival, r.prompt_len, r.max_new) for r in b]
    assert len(a) == 300
    arr = np.array([r.arrival for r in a])
    assert (np.diff(arr) >= 0).all()
    # MMPP interarrivals are overdispersed vs Poisson: CV > 1
    gaps = np.diff(arr)
    cv = gaps.std() / gaps.mean()
    assert cv > 1.1, cv
    # and a different seed moves the arrivals
    c = bursty_requests(300, 40.0, prompt_lens=(16, 32), seed=8)
    assert [r.arrival for r in c] != [r.arrival for r in a]


def test_bursty_requests_validates_burst_shape():
    with pytest.raises(ValueError):
        bursty_requests(10, 10.0, burst_mult=1.0)
    with pytest.raises(ValueError):
        bursty_requests(10, 10.0, burst_frac=0.0)
    with pytest.raises(ValueError):
        bursty_requests(10, 10.0, burst_frac=1.5)


def test_diurnal_requests_follow_sinusoid_envelope():
    period = 4.0
    reqs = diurnal_requests(
        2000, 50.0, period_s=period, depth=0.9, prompt_lens=(16,), seed=3
    )
    assert len(reqs) == 2000
    arr = np.array([r.arrival for r in reqs])
    assert (np.diff(arr) >= 0).all()
    # phase-fold: sin > 0 on the first half-period, so the peak half must
    # hold well over half the arrivals
    phase = (arr % period) / period
    peak = (phase < 0.5).mean()
    assert peak > 0.6, peak
    again = diurnal_requests(
        2000, 50.0, period_s=period, depth=0.9, prompt_lens=(16,), seed=3
    )
    assert [r.arrival for r in again] == [r.arrival for r in reqs]
    with pytest.raises(ValueError):
        diurnal_requests(10, 10.0, depth=0.0)
    with pytest.raises(ValueError):
        diurnal_requests(10, 10.0, depth=1.2)


# ------------------------------------------- placement + admission policies ---


def _views():
    return [
        PlacementView(queued=4, queued_prefill_tokens=256, active=4,
                      kv_used_frac=0.9),
        PlacementView(queued=0, queued_prefill_tokens=0, active=1,
                      kv_used_frac=0.1),
    ]


def test_placement_policies_pick_expected_replica():
    views = _views()
    assert place("round_robin", views, rr=0) == 0
    assert place("round_robin", views, rr=3) == 1
    assert place("least_kv", views) == 1
    assert place("jsq", views) == 1
    with pytest.raises(ValueError):
        place("warmest", views)


def test_admission_decision_admit_redirect_shed():
    views = _views()
    prof = ServeProfile()
    # no SLO: the policy's pick stands even when loaded
    act, rep, _ = admission_decision("round_robin", views, 64, rr=0,
                                     prof=prof, slo_ttft_s=0.0)
    assert (act, rep) == ("admit", 0)
    # tight SLO: replica 0 busts it, replica 1 does not -> redirect
    est1 = admission_decision("jsq", views, 64, prof=prof)[2]
    act, rep, _ = admission_decision("round_robin", views, 64, rr=0,
                                     prof=prof, slo_ttft_s=est1 * 1.5)
    assert (act, rep) == ("redirect", 1)
    # impossible SLO: shed (or least-bad admit with shed=False)
    act, _, _ = admission_decision("round_robin", views, 64, rr=0,
                                   prof=prof, slo_ttft_s=1e-12)
    assert act == "shed"
    act, rep, _ = admission_decision("round_robin", views, 64, rr=0,
                                     prof=prof, slo_ttft_s=1e-12, shed=False)
    assert (act, rep) == ("admit", 1)


def test_router_config_validation():
    with pytest.raises(ValueError):
        RouterConfig(replicas=0)
    with pytest.raises(ValueError):
        RouterConfig(policy="warmest")
    with pytest.raises(ValueError):
        RouterConfig(replicas=2, prefill_replicas=2)
    with pytest.raises(ValueError):
        RouterConfig(replicas=2, prefill_replicas=-1)
    with pytest.raises(ValueError):
        RouterConfig(slo_ttft_s=-1.0)
    assert RouterConfig(replicas=3, prefill_replicas=1).disaggregated


def test_router_set_typo_and_chunk_len_fail_loudly():
    from repro.app.config import RunConfig, set_by_path

    cfg = RunConfig.for_workload("serve")
    set_by_path(cfg, "router.policy", "jsq")       # valid
    assert cfg.router.policy == "jsq"
    with pytest.raises(KeyError):
        set_by_path(cfg, "router.polcy", "jsq")    # typo
    with pytest.raises(KeyError):
        set_by_path(cfg, "router.replica_count", "2")
    with pytest.raises(ValueError):
        ServeConfig(block_size=16, chunk_len=12)   # not a block multiple
    with pytest.raises(ValueError):
        ServeConfig(chunk_len=-16)
    assert ServeConfig(block_size=16).resolved_chunk_len == 32


# --------------------------------------------- offline router evaluation ---


@pytest.mark.parametrize("policy", ["round_robin", "least_kv", "jsq"])
def test_router_workload_conserves_requests(policy):
    reqs = bursty_requests(
        60, 30.0, prompt_lens=(16, 32, 64), max_new_range=(4, 16), seed=5
    )
    tasks = router_workload(
        reqs, policy=policy, n_replicas=2, num_slots=3,
        kv_capacity_tokens=512,
    )
    res = Engine().run(tasks)
    summ = router_summary(res, n_replicas=2)
    assert summ["submitted"] == 60
    assert summ["finished"] + summ["shed"] == 60
    assert summ["shed"] == 0          # no SLO configured -> nothing sheds
    assert summ["ttft_p99_s"] > 0
    assert len(summ["replica_tokens"]) == 2
    assert sum(summ["replica_tokens"]) > 0


def test_router_workload_validates_inputs():
    reqs = poisson_requests(8, 10.0, seed=0)
    with pytest.raises(ValueError):
        router_workload(reqs, n_replicas=0)
    with pytest.raises(ValueError):
        router_workload(reqs, policy="warmest", n_replicas=2)
    with pytest.raises(ValueError):
        router_workload(reqs, n_replicas=2, replica_speeds=(1.0,))


def test_router_workload_slo_sheds_offline():
    reqs = bursty_requests(40, 50.0, prompt_lens=(64,), seed=1)
    tasks = router_workload(
        reqs, policy="jsq", n_replicas=2, num_slots=2,
        slo_ttft_s=1e-9, kv_capacity_tokens=512,
    )
    summ = router_summary(Engine().run(tasks), n_replicas=2)
    assert summ["shed"] == 40 and summ["finished"] == 0


def test_degraded_replica_rewards_load_aware_placement():
    """The regime MegaRoute targets (the paper's straggler theme): one
    replica at a fraction of fleet speed.  Count-balanced round-robin keeps
    feeding the slow replica; queue-aware jsq diverts and wins on tail TTFT
    — and this offline ranking is what the live bench gate must agree with."""
    reqs = bursty_requests(
        120, 40.0, burst_mult=10.0, burst_frac=0.2, burst_dwell_s=0.3,
        prompt_lens=(16, 32, 256), max_new_range=(4, 48), seed=0,
    )
    p99 = {}
    for policy in ("round_robin", "jsq"):
        tasks = router_workload(
            reqs, policy=policy, n_replicas=2, num_slots=4,
            kv_capacity_tokens=600, replica_speeds=(1.0, 0.35),
        )
        summ = router_summary(Engine().run(tasks), n_replicas=2)
        assert summ["finished"] == 120
        p99[policy] = summ["ttft_p99_s"]
    assert p99["round_robin"] / p99["jsq"] > 1.2, p99


# ----------------------------------------------- scheduler migration units ---


def _sched(num_slots=2, num_blocks=9, block_size=8):
    return Scheduler(ServeConfig(
        num_slots=num_slots, num_blocks=num_blocks, block_size=block_size,
        max_blocks_per_slot=4,
    ))


def test_scheduler_adopt_claims_slot_and_blocks():
    s = _sched()
    req = Request(rid=7, prompt=list(range(10)), max_new=4)
    got = s.adopt(req, pos=10, last_tok=3)
    assert got is not None
    slot, phys = got
    assert s.slots[slot] == 7
    assert len(phys) == 2                      # ceil(10 / 8)
    assert s.pos[slot] == 10 and s.last_tok[slot] == 3
    assert list(s.tables[slot, :2]) == phys
    with pytest.raises(ValueError):
        s.adopt(req, pos=10, last_tok=3)       # duplicate rid


def test_scheduler_adopt_returns_none_when_full():
    s = _sched(num_slots=1)
    assert s.adopt(Request(rid=0, prompt=[1] * 8, max_new=2), 8, 1) is not None
    assert s.adopt(Request(rid=1, prompt=[1] * 8, max_new=2), 8, 1) is None


def test_scheduler_release_request_frees_everything():
    s = _sched()
    s.adopt(Request(rid=5, prompt=[1] * 8, max_new=4), 8, 2)
    held = s.allocator.num_held
    assert held > 0
    s.release_request(5)
    assert s.allocator.num_held == 0
    assert 5 not in s.requests and s.active_slots() == []
    with pytest.raises(ValueError):
        s.release_request(5)


# ------------------------------------------------------ live-engine oracles ---


@pytest.fixture(scope="module")
def qwen_router():
    cfg = get_config("qwen2-0.5b", smoke=True).replace(
        compute_dtype="float32", attn_kv_chunk=4096
    )
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(seed, n, lo=4, hi=20, new_lo=3, new_hi=9, vocab=1000):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(2, vocab, size=int(rng.integers(lo, hi))).tolist(),
         int(rng.integers(new_lo, new_hi)), i * 0.001)
        for i in range(n)
    ]


def _drain_single(cfg, params, scfg, reqs):
    srv = MegaServe(cfg, params, scfg)
    for p, mn, a in reqs:
        srv.submit(p, mn, arrival=a)
    return srv.drain(), srv


@pytest.mark.parametrize("policy", ["round_robin", "least_kv", "jsq"])
def test_router_matches_single_engine_greedy(qwen_router, policy):
    cfg, params = qwen_router
    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=40,
                       max_blocks_per_slot=8)
    reqs = _requests(0, 8, vocab=cfg.vocab_size)
    ref, _ = _drain_single(cfg, params, scfg, reqs)

    router = Router(cfg, params, scfg,
                    RouterConfig(replicas=2, policy=policy))
    for p, mn, a in reqs:
        router.submit(p, mn, arrival=a)
    outs = router.drain()
    assert outs == ref
    met = router.metrics()
    assert met["finished"] == len(reqs) and met["shed"] == 0
    assert sum(met["placed_per_replica"]) == len(reqs)
    # both replicas actually served (the whole point of a router)
    assert all(n > 0 for n in met["placed_per_replica"])
    assert met["queue_wait_p99_s"] >= 0


def test_router_disaggregated_matches_colocated(qwen_router):
    cfg, params = qwen_router
    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=40,
                       max_blocks_per_slot=8)
    reqs = _requests(1, 8, vocab=cfg.vocab_size)
    ref, _ = _drain_single(cfg, params, scfg, reqs)

    router = Router(cfg, params, scfg,
                    RouterConfig(replicas=2, prefill_replicas=1))
    for p, mn, a in reqs:
        router.submit(p, mn, arrival=a)
    outs = router.drain()
    assert outs == ref
    met = router.metrics()
    # every multi-token request crossed the prefill -> decode boundary
    assert met["migrations"] > 0
    names = {e.name for e in router.trace_events()}
    assert {"kv_export", "kv_import", "migrate", "route"} <= names
    # decode happened only on the decode tier
    prefill_reqs = router.replicas[0].sched.requests
    assert not prefill_reqs or all(
        len(r.generated) <= 1 for r in prefill_reqs.values()
    )


def test_router_slo_sheds_live(qwen_router):
    cfg, params = qwen_router
    scfg = ServeConfig(num_slots=2, block_size=8, num_blocks=20,
                       max_blocks_per_slot=8)
    reqs = _requests(2, 5, vocab=cfg.vocab_size)
    router = Router(cfg, params, scfg,
                    RouterConfig(replicas=2, policy="jsq", slo_ttft_s=1e-12))
    for p, mn, a in reqs:
        router.submit(p, mn, arrival=a)
    outs = router.drain()
    met = router.metrics()
    assert outs == {} and met["shed"] == len(reqs)
    assert met["shed_rate"] == 1.0
    assert set(router.shed_rids) == set(range(len(reqs)))


def test_kv_export_import_roundtrip_bit_identical(qwen_router):
    cfg, _ = qwen_router
    spec = PoolSpec(num_slots=2, num_blocks=9, block_size=8, max_blocks=4)
    kv = PagedKVCache(cfg, spec)
    key = iter(jax.random.split(jax.random.PRNGKey(3), 256))
    pool = jax.tree.map(
        lambda p: jax.random.normal(next(key), p.shape).astype(p.dtype),
        kv.pool,
    )
    import jax.numpy as jnp

    phys = jnp.asarray([3, 5, 0, 0], jnp.int32)
    bundle = kv.export_slot(pool, phys, jnp.int32(1))
    # import into a different slot/blocks of a different pool
    pool2 = jax.tree.map(
        lambda p: jax.random.normal(next(key), p.shape).astype(p.dtype),
        kv.pool,
    )
    phys2 = jnp.asarray([7, 2, 0, 0], jnp.int32)
    pool2 = kv.import_slot(pool2, bundle, phys2, jnp.int32(0))
    back = kv.export_slot(pool2, phys2, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(bundle), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_prefill_matches_unchunked(qwen_router):
    cfg, params = qwen_router
    from dataclasses import replace as dreplace

    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=40,
                       max_blocks_per_slot=8)
    reqs = _requests(3, 5, lo=30, hi=60, vocab=cfg.vocab_size)
    ref, _ = _drain_single(cfg, params, scfg, reqs)
    out, srv = _drain_single(
        cfg, params, dreplace(scfg, chunked_prefill=True), reqs)
    assert out == ref
    # long prompts really took the chunked path
    assert any(e.name == "prefill_chunk" for e in srv.trace_events())


def test_chunked_prefill_survives_preemption(qwen_router):
    cfg, params = qwen_router
    from dataclasses import replace as dreplace

    # tight pool: decode growth forces preemption-by-recompute mid-workload
    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=13,
                       max_blocks_per_slot=8)
    reqs = _requests(2, 6, lo=20, hi=40, new_lo=12, new_hi=24,
                     vocab=cfg.vocab_size)
    ref, a = _drain_single(cfg, params, scfg, reqs)
    out, b = _drain_single(
        cfg, params, dreplace(scfg, chunked_prefill=True), reqs)
    assert out == ref
    assert a.metrics()["preemptions"] > 0   # the oracle actually preempted


def test_queue_wait_split_from_ttft(qwen_router):
    cfg, params = qwen_router
    # 1 slot: later arrivals must queue, so waits are nonzero and ordered
    scfg = ServeConfig(num_slots=1, block_size=8, num_blocks=20,
                       max_blocks_per_slot=8)
    reqs = _requests(4, 4, vocab=cfg.vocab_size)
    _, srv = _drain_single(cfg, params, scfg, reqs)
    met = srv.metrics()
    assert "queue_wait_p50_s" in met and "queue_wait_p99_s" in met
    for r in srv.sched.requests.values():
        assert r.queue_wait is not None and r.ttft is not None
        assert r.queue_wait <= r.ttft + 1e-9
    # with one slot the last request queued behind whole earlier streams
    assert met["queue_wait_p99_s"] > 0


def test_make_workload_traffic_selection():
    from repro.serve.server import make_poisson_workload

    cfg = get_config("qwen2-0.5b", smoke=True)
    kw = dict(n=16, rate=50.0, prompt_lens=(16, 32),
              max_new_range=(2, 8), num_slots=2, seed=0)
    specs_p, _, _ = make_poisson_workload(cfg, **kw)
    specs_b, _, _ = make_poisson_workload(cfg, traffic="bursty", **kw)
    assert [s.arrival for s in specs_p] != [s.arrival for s in specs_b]
    with pytest.raises(ValueError):
        make_poisson_workload(cfg, traffic="weekly", **kw)


def test_router_step_thinning_matches_single_engine(qwen_router):
    cfg, params = qwen_router
    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=40,
                       max_blocks_per_slot=8)
    reqs = _requests(7, 8, vocab=cfg.vocab_size)
    ref, _ = _drain_single(cfg, params, scfg, reqs)

    # replica 1 stepped every 3rd tick: slower, but greedy streams identical
    router = Router(cfg, params, scfg,
                    RouterConfig(replicas=2, policy="least_kv"),
                    replica_step_every=[1, 3])
    for p, mn, a in reqs:
        router.submit(p, mn, arrival=a)
    outs = router.drain()
    assert outs == ref
    met = router.metrics()
    assert met["finished"] == len(reqs) and met["shed"] == 0

    with pytest.raises(ValueError):
        Router(cfg, params, scfg, RouterConfig(replicas=2),
               replica_step_every=[1])
    with pytest.raises(ValueError):
        Router(cfg, params, scfg, RouterConfig(replicas=2),
               replica_step_every=[1, 0])


def test_precompile_walks_width_ladder_and_stays_exact(qwen_router):
    cfg, params = qwen_router
    scfg = ServeConfig(num_slots=3, block_size=8, num_blocks=40,
                       max_blocks_per_slot=8)
    ref, _ = _drain_single(cfg, params, scfg, _requests(11, 5, vocab=cfg.vocab_size))

    srv = MegaServe(cfg, params, scfg)
    # paged path: one decode variant per pow2 table-width bucket up to the
    # cap, plus the prefill prompt-bucket ladder; counts/ms tally per path
    rep = srv.precompile()
    assert rep["decode"]["count"] == 4
    assert rep["prefill"]["count"] == 4
    assert rep["verify"]["count"] == rep["chunk"]["count"] == 0
    assert rep["total"] == 8
    assert rep["decode"]["ms"] > 0 and rep["prefill"]["ms"] > 0
    for p, mn, a in _requests(11, 5, vocab=cfg.vocab_size):
        srv.submit(p, mn, arrival=a)
    assert srv.drain() == ref
