"""MegaScan trace analytics (the Perfetto-SQL equivalent queries)."""

import numpy as np

from repro.core.simkit.engine import FaultModel
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing import ClockModel, simulate_trace
from repro.core.tracing.analytics import (
    bandwidth_by_edge,
    iteration_breakdown,
    slow_ops,
    to_table,
    utilization_by_rank,
)

TOPO = Topology(dp=1, pp=4, tp=1)


def _table(faults=None):
    events, _ = simulate_trace(
        TOPO, ModelProfile(), n_micro=6, faults=faults, clocks=ClockModel(seed=0)
    )
    return to_table(events)


def test_bandwidth_query_flags_degraded_edge():
    t = _table(FaultModel(link_slowdown={(1, 2): 0.25, (2, 1): 0.25}))
    bw = bandwidth_by_edge(t)
    assert bw, "pipeline must have p2p edges"
    med = np.median([v["median_bps"] for v in bw.values()])
    bad = {e for e, v in bw.items() if v["median_bps"] < med / 2}
    assert any(set(e) == {1, 2} for e in bad), bad


def test_utilization_accounts_all_ranks():
    t = _table()
    util = utilization_by_rank(t)
    assert set(util) == set(range(TOPO.world))
    for v in util.values():
        assert 0 <= v["compute_frac"] <= 1
        assert abs(v["compute_frac"] + v["comm_frac"] + v["idle_frac"] - 1.0) < 1e-6


def test_slow_ops_surfaces_downclocked_rank():
    t = _table(FaultModel(compute_slowdown={2: 0.5}))
    rows = slow_ops(t, ratio=1.5)
    assert rows and all(r["rank"] == 2 for r in rows[:4])


def test_iteration_breakdown_covers_phases():
    t = _table()
    br = iteration_breakdown(t)
    assert br["compute_F"] > 0 and br["compute_B"] > 0
    assert br["compute_B"] > br["compute_F"]  # bwd ~2x fwd in the profile
    assert br["p2p"] > 0
