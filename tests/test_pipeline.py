"""Real pipeline-parallel training: MegaDPP's executor on actual model
weights — params restacking, schedule-controlled train-step parity vs the
fused reference, ParallelPlan threading (Session/CLI), MegaFBD's decoupled
backward attach, MegaScan bubble events — plus the schedule/table/mesh
satellite guards."""

import os

# host-device mesh for the pipeline tests (must be set before jax import)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.dpp.executor import (
    build_time_table,
    bubble_fraction,
    emit_pipeline_events,
)
from repro.core.dpp.schedule import sched_bfc, sched_dfc, sched_wave
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh, make_pipeline_mesh
from repro.models import lm
from repro.models import pipeline as pl
from repro.models.model import make_batch
from repro.parallel.plan import ParallelPlan, forward_order, resolve_plan
from repro.parallel.sharding import axis_rules
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

TINY = ModelConfig(
    name="pp-tiny", family="dense", num_layers=4, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128, attn_kv_chunk=16,
    logits_chunk=16, vocab_pad_to=64,
    param_dtype="float32", compute_dtype="float32", remat="none",
)
OCFG = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)


def _train_losses(cfg, plan=None, mesh=None, n_steps=3, batch=4, seq=16):
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch)
    ds = SyntheticTokens(data)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, OCFG, plan=plan, mesh=mesh))
    losses = []
    for i in range(n_steps):
        state, m = step(state, ds.batch_at(i))
        losses.append(float(m["loss"]))
    return losses, state


# ------------------------------------------------------------- restacking ---


def test_restack_params_is_chunk_major():
    layout = pl.pipeline_layout(TINY, pp=2, n_chunks=2)
    assert layout.groups_per_cell == 1
    seg = {"w": jnp.arange(4.0)[:, None] * jnp.ones((4, 3))}
    out = pl.restack_params(seg, layout)
    assert out["w"].shape == (2, 2, 1, 3)
    # cell (s, c) holds global group c*S + s (execution order of the ring)
    for s in range(2):
        for c in range(2):
            assert float(out["w"][s, c, 0, 0]) == c * 2 + s


def test_restack_groups_per_cell():
    cfg = TINY.replace(num_layers=8)
    layout = pl.pipeline_layout(cfg, pp=2, n_chunks=2)
    assert layout.groups_per_cell == 2
    seg = jnp.arange(8.0)
    out = pl.restack_params(seg, layout)
    # cell (s, c) covers consecutive groups [(c*S + s)*gpc, ...)
    assert out.tolist() == [[[0.0, 1.0], [4.0, 5.0]], [[2.0, 3.0], [6.0, 7.0]]]


def test_pipeline_layout_rejections():
    with pytest.raises(ValueError, match="not divisible"):
        pl.pipeline_layout(TINY.replace(num_layers=5), pp=2, n_chunks=2)
    moe = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    with pytest.raises(ValueError, match="MoE"):
        pl.pipeline_layout(moe, pp=2)
    mrope = get_config("qwen2-vl-7b", smoke=True)
    with pytest.raises(ValueError, match="mrope"):
        pl.pipeline_layout(mrope, pp=2)


# ------------------------------------------------- loss / forward parity ----


@pytest.mark.parametrize("family_cfg", [
    TINY,
    pytest.param(
        get_config("rwkv6-3b", smoke=True).replace(
            param_dtype="float32", compute_dtype="float32", remat="none"),
        id="rwkv"),
])
def test_pipeline_loss_matches_fused_forward(family_cfg):
    cfg = family_cfg
    pp = 2
    n_chunks = 2 if cfg.num_layers % 4 == 0 else 1
    layout = pl.pipeline_layout(cfg, pp, n_chunks)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, jax.random.PRNGKey(1))
    n_micro = 4
    table = build_time_table(
        sched_wave(n_micro, n_chunks, 2), pp, n_chunks, n_micro
    )
    mesh = make_pipeline_mesh(pp)

    loss_ref, _ = lm.loss_fn(cfg, params, batch)
    loss_pp, metrics = jax.jit(
        lambda p, b: pl.pipeline_loss(
            cfg, p, b, layout=layout, table=table, mesh=mesh, n_micro=n_micro)
    )(params, batch)
    np.testing.assert_allclose(
        float(loss_pp), float(loss_ref), rtol=2e-6, atol=1e-6
    )

    g_ref = jax.grad(lambda p: lm.loss_fn(cfg, p, batch)[0])(params)
    g_pp = jax.jit(jax.grad(lambda p: pl.pipeline_loss(
        cfg, p, batch, layout=layout, table=table, mesh=mesh,
        n_micro=n_micro)[0]))(params)
    flat_ref, flat_pp = jax.tree.leaves(g_ref), jax.tree.leaves(g_pp)
    assert len(flat_ref) == len(flat_pp)
    for a, b in zip(flat_ref, flat_pp):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=5e-4, atol=1e-5
        )


# --------------------------------------------------- train-step parity ------


def test_pp1_plan_is_bitwise_identical_to_plain_step():
    ref, ref_state = _train_losses(TINY)
    p1, p1_state = _train_losses(TINY, plan=ParallelPlan(pp=1, n_micro=1))
    assert p1 == ref
    for a, b in zip(jax.tree.leaves(p1_state.master),
                    jax.tree.leaves(ref_state.master)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("schedule", ["1f1b", "wave"])
def test_pp2_train_parity_three_steps(schedule):
    """Acceptance bar: pp=2 on the host mesh matches the reference loss to
    fp32 tolerance across >= 3 steps for 1f1b and wave."""
    ref, _ = _train_losses(TINY)
    plan = resolve_plan(ParallelPlan(
        pp=2, n_micro=4, n_chunks=2, schedule=schedule,
    ))
    pp, _ = _train_losses(TINY, plan=plan, mesh=make_pipeline_mesh(2))
    np.testing.assert_allclose(pp, ref, rtol=2e-5)


def test_fbd_backward_attach_matches():
    """MegaFBD's decoupled backward (vjp split) is numerically the fused
    grad: same 3-step loss trajectory through the pipelined step."""
    plan = resolve_plan(ParallelPlan(pp=2, n_micro=4, n_chunks=2))
    fused, _ = _train_losses(TINY, plan=plan, mesh=make_pipeline_mesh(2))
    dec, _ = _train_losses(
        TINY,
        plan=resolve_plan(ParallelPlan(
            pp=2, n_micro=4, n_chunks=2, fbd_backward=True)),
        mesh=make_pipeline_mesh(2),
    )
    np.testing.assert_allclose(dec, fused, rtol=1e-6)


def test_wave_zero_resolves_via_planner():
    plan = resolve_plan(ParallelPlan(pp=2, n_micro=8, n_chunks=2,
                                     schedule="wave", wave=0))
    assert 1 <= plan.wave <= 8
    # default n_micro fills in
    plan2 = resolve_plan(ParallelPlan(pp=4))
    assert plan2.n_micro == 8


def test_pipeline_step_needs_stage_mesh():
    plan = resolve_plan(ParallelPlan(pp=2, n_micro=2))
    with pytest.raises(ValueError, match="stage"):
        make_train_step(TINY, OCFG, plan=plan, mesh=None)


def test_pipeline_composition_guards():
    # composed axes are allowed now, but the microbatch axis must still
    # shard evenly across dp groups
    with pytest.raises(ValueError, match="not divisible by dp"):
        resolve_plan(ParallelPlan(pp=2, dp=2, n_micro=3))
    # a dp=2 plan resolves (and defaults n_micro to 2*pp*dp)
    plan = resolve_plan(ParallelPlan(pp=2, dp=2))
    assert plan.n_micro == 8 and plan.n_micro_local == 4
    # the composed step demands the matching per-axis mesh shape
    with pytest.raises(ValueError, match="mesh shaped"):
        make_train_step(TINY, OCFG, plan=plan, mesh=make_pipeline_mesh(2))
    # compression without a data axis still has nothing to compress
    from repro.ft.compress import GradCompressor

    with pytest.raises(ValueError, match="no data axis"):
        make_train_step(TINY, OCFG, plan=resolve_plan(ParallelPlan(pp=2)),
                        mesh=make_pipeline_mesh(2),
                        compressor=GradCompressor())
    # tp inside the pipeline is dense-GQA only, and widths must divide
    with pytest.raises(ValueError, match="dense GQA"):
        rwkv = get_config("rwkv6-3b", smoke=True)
        pl.pipeline_layout(rwkv, pp=2, tp=2)
    with pytest.raises(ValueError, match="divide"):
        pl.pipeline_layout(TINY.replace(num_kv_heads=1), pp=2, tp=2)


# ----------------------------------------------- MegaScan bubble events -----


def test_pipeline_emits_megascan_events():
    from repro.core.tracing.tracer import Tracer
    from repro.train.loop import LoopConfig, train

    plan = resolve_plan(ParallelPlan(pp=2, n_micro=2, n_chunks=2))
    mesh = make_pipeline_mesh(2)
    tracer = Tracer(rank=0, enabled=True)
    data = DataConfig(vocab_size=TINY.vocab_size, seq_len=16, global_batch=4)
    with mesh, axis_rules(mesh):
        train(TINY, OCFG, data, LoopConfig(n_steps=2, log_every=1),
              tracer=tracer, plan=plan)
    f_ev = [e for e in tracer.events if e.name == "pp_F"]
    b_ev = [e for e in tracer.events if e.name == "pp_B"]
    # every (microbatch, chunk) runs once per stage, per step
    assert len(f_ev) == 2 * plan.n_micro * plan.n_chunks * plan.pp
    assert len(b_ev) == len(f_ev)
    assert {e.rank for e in f_ev} == {0, 1}          # one chrome row per stage
    steps = [e for e in tracer.events if e.name == "train_step"]
    for e in f_ev + b_ev:
        assert {"mb", "chunk", "stage", "phase", "step"} <= set(e.args)
        anchor = steps[e.args["step"]]
        assert anchor.ts <= e.ts and e.end <= anchor.end + 1e-9
    # forward events strictly precede their mirrored backward per step
    for s in range(2):
        fs = [e for e in f_ev if e.args["step"] == s]
        bs = [e for e in b_ev if e.args["step"] == s]
        assert max(e.end for e in fs) <= min(e.ts for e in bs) + 1e-12


# -------------------------------------------------- Session / CLI thread ----


def test_cli_train_pp2_smoke():
    from repro.app.cli import run as cli_run

    res = cli_run([
        "train", "--arch", "qwen2-0.5b", "--smoke", "--steps", "2",
        "--set", "train.seq_len=32", "--set", "train.global_batch=4",
        "--set", "parallel.pp=2", "--set", "parallel.n_micro=2",
        "--set", "parallel.schedule=wave",
    ])
    par = res["parallel"]
    assert par["pp"] == 2 and par["n_micro"] == 2
    assert par["wave"] >= 1                  # planner filled the wave in
    assert par["mesh"] == {"stage": 2, "data": 1, "model": 1}
    assert len(res["history"]) >= 1
    assert all(np.isfinite(h["loss"]) for h in res["history"])


def test_session_rejects_indivisible_micro():
    from repro.app.cli import run as cli_run

    with pytest.raises(SystemExit, match="not divisible"):
        cli_run([
            "train", "--arch", "qwen2-0.5b", "--smoke", "--steps", "1",
            "--set", "train.global_batch=4", "--set", "parallel.pp=2",
            "--set", "parallel.n_micro=3",
        ])


# ------------------------------------------------- schedule satellites ------


def test_sched_wave_edge_cases():
    # wave > n_micro clamps to BFC
    assert sched_wave(4, 2, 9) == sched_wave(4, 2, 4) == sched_bfc(4, 2)
    # single microbatch: every wave width degenerates to DFC
    assert sched_wave(1, 3, 1) == sched_wave(1, 3, 7) == sched_dfc(1, 3)
    # non-dividing wave: trailing partial wave, full coverage exactly once
    steps = sched_wave(5, 2, 2)
    assert len(steps) == 2 * 5 * 2
    for kind in ("F", "B"):
        seen = [(m, c) for k, m, c in steps if k == kind]
        assert sorted(seen) == [(m, c) for m in range(5) for c in range(2)]
    # last (partial) wave is microbatch 4 alone, depth-first
    assert steps[-4:] == [("F", 4, 0), ("F", 4, 1), ("B", 4, 1), ("B", 4, 0)]


@pytest.mark.parametrize("order_fn,n_micro,n_chunks,S", [
    (lambda: sched_dfc(3, 2), 3, 2, 4),
    (lambda: sched_bfc(4, 2), 4, 2, 2),
    (lambda: sched_wave(5, 2, 2), 5, 2, 3),
    (lambda: sched_wave(4, 3, 4), 4, 3, 2),
])
def test_build_time_table_legality(order_fn, n_micro, n_chunks, S):
    table = build_time_table(order_fn(), S, n_chunks, n_micro)
    run_act = np.asarray(table.run_act)
    run_m = np.asarray(table.run_m)
    run_c = np.asarray(table.run_c)
    T = run_act.shape[0]
    when = {}
    for t in range(T):
        for s in range(S):
            if run_act[t, s]:
                key = (int(run_m[t, s]), int(run_c[t, s]), s)
                assert key not in when, f"{key} ran twice"
                when[key] = t
    # every (m, c) runs exactly once per stage
    assert len(when) == n_micro * n_chunks * S
    # a block runs only after its producer ran (receive precedes run)
    for (m, c, s), t in when.items():
        if s > 0:
            assert when[(m, c, s - 1)] < t
        elif c > 0:
            assert when[(m, c - 1, S - 1)] < t
    assert 0.0 <= bubble_fraction(table) < 1.0


def test_emit_pipeline_events_matches_table_occupancy():
    table = build_time_table(sched_dfc(3, 2), 2, 2, 3)
    events = []
    emit_pipeline_events(events, table, ts=10.0, wall=1.0)
    f = [e for e in events if e.name == "pp_F"]
    assert len(f) == int(np.asarray(table.run_act).sum())
    assert all(10.0 <= e.ts and e.end <= 11.0 + 1e-9 for e in events)


# ------------------------------------------------------- mesh satellite -----


def test_host_mesh_guard_rejects_segfaulting_shape():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    with pytest.raises(ValueError, match="segfault"):
        make_host_mesh(data=2, model=4)
    # the default transposed shape still builds
    m = make_host_mesh()
    assert dict(m.shape) == {"data": 4, "model": 2}


def test_pipeline_mesh_too_few_devices():
    with pytest.raises(ValueError, match="devices"):
        make_pipeline_mesh(len(jax.devices()) + 1)
