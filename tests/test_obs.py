"""Observability subsystem: metrics registry + P² quantiles, exporters
(JSONL / Prometheus / chrome counters), the streaming OnlineDetector, and
the end-to-end live-straggler acceptance path through ``repro.app``."""

import json
import math
import time

import numpy as np
import pytest

from repro.core.simkit.workload import Topology
from repro.core.tracing import (
    Tracer,
    from_chrome,
    load_jsonl,
    load_trace,
    to_chrome,
)
from repro.core.tracing.events import TraceEvent
from repro.core.tracing.tracer import AsyncTraceWriter
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    JsonlExporter,
    MetricsRegistry,
    OnlineDetector,
    P2Quantile,
    RankEventSpec,
    counter_events,
    emit_rank_events,
    flatten_snapshot,
    prometheus_text,
)

TINY_TRAIN = ["--arch", "qwen2-0.5b", "--smoke", "--steps", "2",
              "--seq-len", "32", "--global-batch", "2"]


# ------------------------------------------------------------- primitives ---


class TestP2Quantile:
    def test_exact_below_five_observations(self):
        est = P2Quantile(0.5)
        for x in (5.0, 1.0, 3.0):
            est.observe(x)
        assert est.value == 3.0  # exact median of {1, 3, 5}

    @pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
    def test_tracks_numpy_quantile_on_lognormal(self, q):
        rng = np.random.default_rng(0)
        xs = rng.lognormal(0.0, 0.5, size=5000)
        est = P2Quantile(q)
        for x in xs:
            est.observe(x)
        truth = float(np.quantile(xs, q))
        assert abs(est.value - truth) / truth < 0.05, (q, est.value, truth)

    def test_rejects_degenerate_quantile(self):
        with pytest.raises(ValueError):
            P2Quantile(1.0)

    def test_empty_is_nan(self):
        assert math.isnan(P2Quantile(0.5).value)


class TestRegistry:
    def test_counter_monotonic(self):
        c = Counter()
        c.inc()
        c.inc(9.0)
        assert c.value == 10.0
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_last_write_wins(self):
        g = Gauge()
        g.set(3.0)
        g.set(1.5)
        assert g.value == 1.5

    def test_histogram_stats(self):
        h = Histogram()
        for v in range(1, 101):
            h.observe(float(v))
        s = h.stats()
        assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
        assert s["mean"] == pytest.approx(50.5)
        assert abs(s["p50"] - 50.5) < 3
        assert s["p95"] > s["p50"]
        assert Histogram().stats() == {"count": 0}

    def test_get_or_create_and_type_guard(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        reg.histogram("h").observe(1.0)
        with pytest.raises(TypeError):
            reg.gauge("a")  # "a" is already a Counter
        assert "a" in reg and len(reg) == 2
        assert reg.kind_of("h") == "histogram"

    def test_snapshot_sorted_and_flatten(self):
        reg = MetricsRegistry()
        reg.gauge("z").set(1.0)
        reg.counter("a").inc()
        reg.histogram("m").observe(2.0)
        snap = reg.snapshot()
        assert list(snap) == ["a", "m", "z"]
        flat = flatten_snapshot(snap)
        assert flat["a"] == 1.0 and flat["m.p50"] == 2.0 and flat["z"] == 1.0


# -------------------------------------------------------------- exporters ---


def _toy_registry():
    reg = MetricsRegistry()
    reg.counter("train.tokens").inc(512)
    reg.gauge("train.tokens_per_s").set(100.0)
    h = reg.histogram("train.step_time_s")
    for v in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6):
        h.observe(v)
    return reg


def test_jsonl_exporter_crash_usable(tmp_path):
    path = tmp_path / "metrics.jsonl"
    exp = JsonlExporter(path)
    exp.write({"step": 1, "loss": 2.0})
    exp.write({"step": 2, "loss": 1.5})
    # rows readable BEFORE close — flushed per write
    rows = [json.loads(l) for l in path.read_text().splitlines()]
    assert rows == [{"step": 1, "loss": 2.0}, {"step": 2, "loss": 1.5}]
    exp.close()
    assert exp.rows == 2


def test_prometheus_text_format():
    text = prometheus_text(_toy_registry())
    assert "# TYPE repro_train_tokens counter" in text
    assert "# TYPE repro_train_tokens_per_s gauge" in text
    assert "# TYPE repro_train_step_time_s summary" in text
    assert 'repro_train_step_time_s{quantile="0.5"}' in text
    assert "repro_train_step_time_s_count 6" in text
    assert "repro_train_tokens 512.0" in text


def test_counter_events_skip_bookkeeping_stats():
    evs = counter_events(_toy_registry().snapshot(), ts=1.5)
    names = {e.name for e in evs}
    # scalar series + histogram mean/quantiles; count/sum/min/max skipped
    assert "train.tokens" in names and "train.step_time_s.p95" in names
    assert not any(n.endswith((".count", ".sum", ".min", ".max")) for n in names)
    assert all(e.kind == "counter" and e.ts == 1.5 for e in evs)


def test_chrome_counter_roundtrip():
    evs = [
        TraceEvent("loss", 0, 1.0, 0.02, "compute", {"op": "fwd"}),
        TraceEvent("train.loss", 0, 1.5, 0.0, "counter", {"value": 2.5}),
    ]
    doc = to_chrome(evs)
    counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
    assert len(counters) == 1
    assert counters[0]["name"] == "train.loss"
    assert counters[0]["args"]["value"] == 2.5
    assert "dur" not in counters[0] and "tid" not in counters[0]
    back = from_chrome(doc)
    assert len(back) == 2
    c = next(e for e in back if e.kind == "counter")
    assert c.name == "train.loss" and c.args["value"] == 2.5
    assert abs(c.ts - 1.5) < 1e-9


# ---------------------------------------------------------- async writer ---


def test_async_writer_streams_mid_run(tmp_path):
    path = tmp_path / "stream.jsonl"
    w = AsyncTraceWriter(path, mode="w", flush_every=4, idle_s=0.02)
    evs = [TraceEvent(f"e{i}", 0, float(i), 0.1, "compute", {}) for i in range(10)]
    w.submit(evs)
    # crash-usability: rows land on disk WITHOUT close (idle flush)
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        if path.exists() and len(path.read_text().splitlines()) >= 10:
            break
        time.sleep(0.02)
    assert len(load_jsonl(path)) == 10
    w.close()
    assert [e.name for e in load_jsonl(path)] == [f"e{i}" for i in range(10)]


def test_load_trace_sniffs_both_formats(tmp_path):
    evs = [TraceEvent("fwd", 0, 1.0, 0.5, "compute", {"op": "fwd"}),
           TraceEvent("bwd", 1, 1.5, 0.5, "compute", {"op": "bwd"})]
    chrome = tmp_path / "t.json"
    chrome.write_text(json.dumps(to_chrome(evs)))
    jsonl = tmp_path / "t.jsonl"
    jsonl.write_text("".join(json.dumps(e.to_json()) + "\n" for e in evs))
    for p in (chrome, jsonl):
        back = load_trace(p)
        assert [e.name for e in back] == ["fwd", "bwd"], p
    # single-row JSONL is ambiguous with a chrome doc; still resolves
    single = tmp_path / "one.jsonl"
    single.write_text(json.dumps(evs[0].to_json()) + "\n")
    assert load_trace(single)[0].name == "fwd"


# -------------------------------------------------------- online detector ---


def _healthy_stream(detector, spec, steps, wall=0.1):
    updates = []
    for step in range(steps):
        evs = []
        emit_rank_events(evs, spec, ts=step * wall, wall=wall, step=step)
        u = detector.push(evs)
        if u is not None:
            updates.append(u)
    return updates


def test_online_detector_flags_slow_rank_streaming():
    spec = RankEventSpec(dp=2, slow_rank=1, slow_factor=0.5)
    det = OnlineDetector(spec.topology(), every=4, window=16)
    wall, updates = 0.2, []
    for step in range(8):
        evs = []
        # slow rank doubles the step: half of the wall is induced excess
        emit_rank_events(evs, spec, ts=step * wall, wall=wall,
                         extra=wall / 2, step=step)
        u = det.push(evs)
        if u is not None:
            updates.append(u)
    assert updates, "no detection pass ran"
    first = updates[0]
    assert first.diagnosis.slow_ranks == [1]
    assert first.new_slow_ranks == [1] and first.changed
    # verdict is steady after the first pass -> later deltas are empty
    assert all(not u.changed for u in updates[1:])
    assert det.history and det.history[-1]["slow_ranks"] == [1]


def test_online_detector_healthy_run_no_false_positives():
    spec = RankEventSpec(dp=2)
    det = OnlineDetector(spec.topology(), every=4, window=16)
    updates = _healthy_stream(det, spec, steps=12)
    assert updates
    assert all(u.diagnosis.slow_ranks == [] and not u.changed for u in updates)


def test_online_detector_recovery_clears_rank():
    slow = RankEventSpec(dp=2, slow_rank=1, slow_factor=0.5)
    det = OnlineDetector(slow.topology(), every=4, window=4)
    wall = 0.2
    for step in range(4):
        evs = []
        emit_rank_events(evs, slow, ts=step * wall, wall=wall,
                         extra=wall / 2, step=step)
        det.push(evs)
    assert det.history[-1]["slow_ranks"] == [1]
    # rank recovers; window (4 steps) rolls over entirely to healthy ones
    healthy = RankEventSpec(dp=2)
    updates = _healthy_stream(det, healthy, steps=4, wall=wall)
    assert updates[-1].cleared_slow_ranks == [1]
    assert updates[-1].diagnosis.slow_ranks == []


def test_online_detector_guards():
    topo = Topology(dp=2, pp=1, tp=1)
    with pytest.raises(ValueError):
        OnlineDetector(topo, every=0)
    det = OnlineDetector(topo, every=1, min_events=10_000)
    assert det.push([TraceEvent("x", 0, 0.0, 1.0, "compute", {})]) is None


# ------------------------------------- detect() branch coverage (offline) ---


def _dp1_iter_events(ts, slow_rank=1, slow=0.6, fast=0.3):
    """One iteration on a dp=1 tp=2 topology: stage 1 has per-key groups of
    size 1 (no cross-DP peer), so only stage 2's start-skew can testify."""
    evs = []
    for r in (0, 1):
        dur = slow if r == slow_rank else fast
        evs.append(TraceEvent("fwd", r, ts, dur, "compute",
                              {"op": "fwd", "mb": 0, "phase": "F"}))
        evs.append(TraceEvent("allreduce", r, ts + dur, 1.0 - dur, "coll",
                              {"op": "allreduce", "group": (0, 1), "mb": 0,
                               "phase": "G"}))
    return evs


def test_detect_dp1_stage2_only_fallback():
    from repro.core.tracing import detect

    topo = Topology(dp=1, pp=1, tp=2)
    events = []
    for i in range(6):
        events.extend(_dp1_iter_events(float(i)))
    diag = detect(events, topo)
    # stage 1 cannot vote (all peer groups are singletons)...
    assert diag.candidate_ranks == []
    # ...yet the consistently-late starter is still confirmed via stage 2
    assert diag.slow_ranks == [1], diag.summary()


def test_detect_stage3_degraded_link_dp1():
    from repro.core.tracing import detect

    topo = Topology(dp=1, pp=3, tp=1)
    events, mb = [], 1 << 20
    for i in range(8):
        ts = float(i)
        # edge (1, 2) moves the same megabyte 10x slower than (0, 1)
        for (src, dst), dur in {(0, 1): 0.01, (1, 2): 0.1}.items():
            events.append(TraceEvent(
                f"send{src}{dst}", src, ts, dur, "p2p",
                {"dir": "send", "peer": dst, "bytes": mb, "mb": i},
            ))
    diag = detect(events, topo)
    assert (1, 2) in {tuple(l) for l in diag.degraded_links}, diag.summary()
    assert diag.link_bandwidth[(1, 2)] < diag.link_bandwidth[(0, 1)]


# ----------------------------------------------- app threading + CLI path ---


class TestAppWiring:
    def test_scan_thresholds_thread_through_set(self):
        from repro.app.config import build_run_config

        cfg = build_run_config("train", sets=[
            "scan.detect_online=true", "scan.detect_every=2",
            "scan.slow_ratio=2.0", "scan.late_frac=0.7",
        ])
        sc = cfg.scan
        assert sc.detect_online and sc.detect_every == 2
        assert sc.slow_ratio == 2.0 and sc.late_frac == 0.7
        # obs section defaults + override
        cfg = build_run_config("train", sets=["obs.slow_rank=1", "obs.dp=4"])
        assert cfg.obs.slow_rank == 1 and cfg.obs.dp == 4
        assert cfg.modules == ("scan", "metrics")

    def test_metrics_plugin_reports_series(self):
        from repro.app.cli import run

        res = run(["train", *TINY_TRAIN])
        series = res["metrics"]["series"]
        assert series["train.steps"] == 2.0
        assert series["train.step_time_s.count"] == 2
        assert "train.loss" in series and "train.tokens_per_s" in series

    def test_metrics_out_and_prom_out(self, tmp_path):
        from repro.app.cli import run

        mpath = tmp_path / "m.jsonl"
        ppath = tmp_path / "prom.txt"
        res = run(["train", *TINY_TRAIN, "--metrics-out", str(mpath),
                   "--set", f"obs.prom_out={ppath}"])
        rows = [json.loads(l) for l in mpath.read_text().splitlines()]
        assert len(rows) == 2 and rows[-1]["step"] == 2
        assert "train.loss" in rows[-1]
        assert res["metrics"]["rows"] == 2
        assert "# TYPE repro_train_step_time_s summary" in ppath.read_text()

    def test_serve_metrics_series(self):
        from repro.app.cli import run

        res = run(["serve", "--arch", "qwen2-0.5b", "--smoke", "--continuous",
                   "--requests", "3", "--max-new", "4"])
        series = res["metrics"]["series"]
        assert series["serve.ttft_s.count"] == 3
        assert series["serve.tokens"] > 0
        assert "serve.kv_occupancy" in series
        assert "serve.queue_depth" in series

    def test_trace_detect_cli_on_chrome_and_jsonl(self, tmp_path):
        from repro.app.cli import run

        spec = RankEventSpec(dp=2, slow_rank=1, slow_factor=0.5)
        events = []
        for step in range(6):
            emit_rank_events(events, spec, ts=step * 0.2, wall=0.2,
                             extra=0.1, step=step)
        chrome = tmp_path / "t.json"
        chrome.write_text(json.dumps(to_chrome(events)))
        jsonl = tmp_path / "t.jsonl"
        jsonl.write_text("".join(json.dumps(e.to_json()) + "\n"
                                 for e in events))
        for p in (chrome, jsonl):
            res = run(["trace", "--detect", str(p),
                       "--dp", "2", "--pp", "1", "--tp", "1"])
            assert res["diagnosis"]["slow_ranks"] == [1], p


# ------------------------------------------------ acceptance: live detect ---


class TestLiveStragglerAcceptance:
    """The ISSUE acceptance path: a host-mesh train run with an induced
    straggler produces an OnlineDetector diagnosis naming that rank DURING
    the run, metrics render as chrome counter tracks, and the streamed
    sidecar supports offline re-detection."""

    @pytest.fixture(scope="class")
    def live_run(self, tmp_path_factory):
        from repro.app.config import build_run_config
        from repro.app.session import Session

        out = tmp_path_factory.mktemp("obs") / "trace.json"
        cfg = build_run_config(
            "train",
            sets=["obs.slow_rank=1", "obs.dp=2", "obs.slow_factor=0.5",
                  "scan.detect_online=true", "scan.detect_every=4",
                  "train.steps=12", "train.seq_len=32",
                  "train.global_batch=2", "obs.peak_tflops=0.001"],
            arch="qwen2-0.5b", smoke=True, trace_out=str(out),
        )
        session = Session(cfg)
        session.run()
        return session, out

    def test_online_diagnosis_names_slow_rank_during_run(self, live_run):
        session, _ = live_run
        online = session.results["scan"]["online"]
        assert online["slow_ranks"] == [1]
        # "during the run": the first hit lands before the last pass,
        # well inside the 12-step run
        assert online["first_detect_step"] is not None
        assert online["first_detect_step"] <= 8
        assert online["passes"] >= 2

    def test_diagnosis_instant_event_in_trace(self, live_run):
        session, out = live_run
        doc = json.loads(out.read_text())
        marks = [e for e in doc["traceEvents"]
                 if e.get("ph") == "i" and e["name"] == "diagnosis"]
        assert marks and marks[0]["args"]["slow_ranks"] == [1]
        assert marks[0]["args"]["new"] == [1]

    def test_metrics_render_as_counter_tracks(self, live_run):
        _, out = live_run
        doc = json.loads(out.read_text())
        counters = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
        names = {e["name"] for e in counters}
        assert "train.loss" in names
        assert "train.step_time_s.p50" in names
        assert all("value" in e["args"] for e in counters)

    def test_mfu_estimate_reported(self, live_run):
        session, _ = live_run
        assert session.results["metrics"].get("mfu_est", 0) > 0

    def test_streamed_sidecar_redetects_offline(self, live_run):
        from repro.app.cli import run

        session, out = live_run
        side = out.with_suffix(".jsonl")
        assert str(side) == session.results["scan"]["stream"]
        assert side.exists() and len(load_jsonl(side)) > 0
        res = run(["trace", "--detect", str(side),
                   "--dp", "2", "--pp", "1", "--tp", "1"])
        assert res["diagnosis"]["slow_ranks"] == [1]
