"""Fault-tolerance loop: chaos injection, mitigation decisions and their
execution in the supervised train loop, checkpoint atomicity/elasticity,
in-band guards, and the end-to-end chaos acceptance run."""

import json
import math
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore, save
from repro.core.tracing.detect import Diagnosis
from repro.ft import (
    ChaosInjector,
    ChaosSpec,
    FtController,
    FtOptions,
    MitigationAction,
    MitigationPolicy,
    TrainSupervisor,
    parse_link,
    simulate_policy,
)
from repro.obs.detector import DetectionUpdate

TINY = ["--arch", "qwen2-0.5b", "--smoke",
        "--seq-len", "32", "--global-batch", "2"]


# ------------------------------------------------------------ chaos spec ---


class TestChaosSpec:
    def test_parse_link(self):
        assert parse_link("0-1") == (0, 1)
        assert parse_link("12-3") == (12, 3)
        with pytest.raises(ValueError, match="src-dst"):
            parse_link("nope")

    def test_active_and_needs_restore(self):
        assert not ChaosSpec().active
        assert ChaosSpec(nan_at_step=2).active
        assert ChaosSpec(slow_rank_from=0).active
        assert ChaosSpec(degrade_link="0-1").active
        assert ChaosSpec(crash_at_step=5).needs_restore
        assert not ChaosSpec(nan_at_step=5).needs_restore

    def test_to_fault_model(self):
        fm = ChaosSpec(slow_rank_from=0, slow_rank=2, slow_factor=0.4,
                       degrade_link="1-0", degrade_factor=0.2).to_fault_model()
        assert fm.compute_slowdown == {2: 0.4}
        assert fm.link_slowdown == {(1, 0): 0.2}
        # crash/NaN are recovery faults: no offline timeline analogue
        assert ChaosSpec(crash_at_step=3).to_fault_model().compute_slowdown == {}

    def test_injector_crash_fires_once(self):
        inj = ChaosInjector(ChaosSpec(crash_at_step=5))
        assert not inj.crash_due(4)
        assert inj.crash_due(5)
        assert not inj.crash_due(5)  # replay after restore: no re-fire

    def test_injector_nan_poisons_batch_once(self):
        inj = ChaosInjector(ChaosSpec(nan_at_step=3))
        batch = {"tokens": np.zeros((2, 4), np.int32),
                 "loss_mask": np.ones((2, 4), np.float32)}
        clean = inj.poison_batch(batch, 2)
        assert clean is batch
        poisoned = inj.poison_batch(batch, 3)
        assert np.isnan(poisoned["loss_mask"]).all()
        assert not np.isnan(batch["loss_mask"]).any()  # original untouched
        assert inj.poison_batch(batch, 3) is batch  # one-shot

    def test_slow_active_window(self):
        inj = ChaosInjector(ChaosSpec(slow_rank_from=4))
        assert not inj.slow_active(3)
        assert inj.slow_active(4) and inj.slow_active(100)
        assert not ChaosInjector(ChaosSpec()).slow_active(0)


# ---------------------------------------------- offline policy evaluation ---


class TestSimulatePolicy:
    def test_healthy_run_decides_none(self):
        _, action, info = simulate_policy(ChaosSpec())
        assert action is MitigationAction.NONE
        assert info["reason"] == "healthy"

    def test_hard_straggler_decides_exclude(self):
        diag, action, info = simulate_policy(
            ChaosSpec(slow_rank_from=0, slow_rank=1, slow_factor=0.5))
        assert action is MitigationAction.EXCLUDE_RESTART
        assert 1 in diag.slow_ranks
        assert info["severity"] >= 0.7

    def test_degraded_link_decides_replan(self):
        diag, action, _ = simulate_policy(ChaosSpec(degrade_link="0-1"))
        assert action is MitigationAction.REPLAN
        assert (0, 1) in {tuple(l) for l in diag.degraded_links}


# ------------------------------------------------------------ controller ---


def _update(step, *, ranks=(), links=(), frac=0.9, n_inst=50):
    diag = Diagnosis(
        slow_ranks=list(ranks), candidate_ranks=list(ranks),
        degraded_links=[tuple(l) for l in links],
        rank_scores={r: {"slow_op_frac": frac} for r in ranks},
        evidence={"n_instances": n_inst},
    )
    return DetectionUpdate(step=step, diagnosis=diag)


class TestFtController:
    def test_decision_lands_once_per_signature(self):
        c = FtController()
        c.on_detection(_update(8, ranks=(1,)))
        c.on_detection(_update(12, ranks=(1,)))  # standing diagnosis re-confirmed
        assert len(c.poll()) == 1
        assert c.poll() == []  # drained
        events = [t["event"] for t in c.timeline]
        assert events == ["decide:exclude"]

    def test_excluded_ranks_not_redecided(self):
        c = FtController()
        c.excluded.add(1)
        c.on_detection(_update(8, ranks=(1,)))  # stale sliding window
        assert c.poll() == []
        c.on_detection(_update(12, ranks=(1, 3)))  # but a NEW rank still acts
        (act,) = c.poll()
        assert act.slow_ranks == (3,)

    def test_insufficient_evidence_is_none(self):
        c = FtController()
        c.on_detection(_update(4, ranks=(1,), n_inst=3))
        assert c.poll() == [] and c.detections == 1

    def test_soft_straggler_and_link_decide_replan(self):
        c = FtController()
        c.on_detection(_update(8, ranks=(2,), frac=0.4))
        (act,) = c.poll()
        assert act.kind == "replan" and act.slow_ranks == (2,)
        c.on_detection(_update(8, links=((0, 1),)))
        (act,) = c.poll()
        assert act.kind == "replan" and act.degraded_links == ((0, 1),)

    def test_nan_guard(self):
        c = FtController(options=FtOptions(guard_action="rollback"))
        assert c.check_guards(3, 1.0, 0.5) is None
        assert c.check_guards(4, float("nan"), 0.5) == "rollback"
        assert c.guard_trips == 1
        assert c.timeline[-1]["event"] == "guard:rollback"

    def test_spike_guard_needs_history(self):
        c = FtController(options=FtOptions(guard_spike=10.0, guard_action="skip"))
        for s in range(8):
            assert c.check_guards(s, 1.0, 1.0) is None
        assert c.check_guards(8, 1.0, 50.0) == "skip"
        assert c.guard_trips == 1

    def test_report_shape(self):
        c = FtController()
        c.record_restart(6, 3, "InjectedCrash")
        c.record_rollback(9, 6)
        rep = c.report()
        assert rep["restarts"] == 1 and rep["rollbacks"] == 1
        assert [t["event"] for t in rep["timeline"]] == ["restart", "rollback"]
        assert rep["timeline"][0]["details"]["resumed_step"] == 3


# ------------------------------------- checkpoint atomicity + elasticity ---


def _toy_state(v=1.0):
    return {"params": {"w": jnp.full((4, 4), v, jnp.float32)},
            "step": jnp.int32(3)}


class TestCheckpointFailureModes:
    def test_crash_mid_save_leaves_only_tmp(self, tmp_path, monkeypatch):
        save(_toy_state(1.0), 2, tmp_path)
        import repro.checkpoint.checkpointer as ckpt_mod

        calls = {"n": 0}
        real_save = np.save

        def dying_save(path, arr):
            calls["n"] += 1
            if calls["n"] == 2:  # die mid-way through the leaf files
                raise OSError("disk gone")
            real_save(path, arr)

        monkeypatch.setattr(ckpt_mod.np, "save", dying_save)
        with pytest.raises(OSError):
            save(_toy_state(9.0), 5, tmp_path)
        monkeypatch.undo()
        # the half-written attempt is still a .tmp dir — never visible
        assert (tmp_path / "step_00000005.tmp").exists()
        assert not (tmp_path / "step_00000005").exists()
        assert latest_step(tmp_path) == 2
        restored, _ = restore(tmp_path, _toy_state())
        assert float(restored["params"]["w"][0, 0]) == 1.0
        # a retry over the stale .tmp succeeds
        save(_toy_state(9.0), 5, tmp_path)
        assert latest_step(tmp_path) == 5

    def test_bf16_elastic_restore_is_bit_identical(self, tmp_path):
        from jax.sharding import NamedSharding, PartitionSpec as P

        key = jax.random.PRNGKey(0)
        st = {"w": jax.random.normal(key, (8, 16)).astype(jnp.bfloat16),
              "b": jax.random.normal(key, (16,)).astype(jnp.bfloat16)}
        save(st, 1, tmp_path)

        def bits(tree):
            return {k: np.asarray(v).view(np.uint16) for k, v in tree.items()}

        want = bits(st)
        # restore onto a replicated 1-device mesh and, when the host mesh
        # has more devices, onto a data-sharded one: same bits both ways
        meshes = [(jax.make_mesh((1,), ("data",)), P())]
        if len(jax.devices()) >= 2:
            meshes.append((jax.make_mesh((2,), ("data",)), P("data")))
        for mesh, pspec in meshes:
            sh = jax.tree.map(lambda _: NamedSharding(mesh, pspec), st)
            restored, _ = restore(tmp_path, st, shardings=sh)
            got = bits(restored)
            for k in want:
                np.testing.assert_array_equal(want[k], got[k])
            assert restored["w"].sharding == NamedSharding(mesh, pspec)

    def test_drain_returns_background_error_wait_raises(self, tmp_path, monkeypatch):
        import repro.checkpoint.checkpointer as ckpt_mod

        ck = Checkpointer(tmp_path)
        monkeypatch.setattr(
            ckpt_mod, "save",
            lambda *a, **k: (_ for _ in ()).throw(OSError("bg boom")))
        ck.save_async(_toy_state(), 1)
        err = ck.drain()
        assert isinstance(err, OSError)
        assert ck.drain() is None  # cleared, not sticky
        monkeypatch.undo()
        ck.save_async(_toy_state(), 2)
        ck.wait()  # healthy save: no raise
        assert latest_step(tmp_path) == 2


# -------------------------------------------------- supervisor satellites ---


class TestSupervisorRecovery:
    def test_history_truncated_after_rollback(self, tmp_path):
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            if calls["n"] == 7:  # dies at step 6, after the ckpt at step 4
                raise RuntimeError("device loss")
            return {"w": state["w"] + batch["x"]}, {"loss": jnp.float32(0.0)}

        sup = TrainSupervisor(
            step_fn=step_fn, make_batch=lambda s: {"x": jnp.float32(s)},
            ckpt_dir=str(tmp_path), ckpt_every=4, max_restarts=2,
        )
        state, step = sup.run({"w": jnp.float32(0.0)}, n_steps=10)
        assert step == 10
        steps = [h["step"] for h in sup.history]
        # replayed rows replace the pre-rollback ones — no duplicates
        assert steps == sorted(set(steps)) == list(range(10))
        assert float(state["w"]) == sum(range(10))

    def test_background_save_error_does_not_mask_step_failure(
            self, tmp_path, monkeypatch):
        import repro.checkpoint.checkpointer as ckpt_mod

        save({"w": jnp.float32(0.0)}, 0, tmp_path)
        real_save = ckpt_mod.save
        fails = {"left": 1}

        def flaky_save(*a, **k):
            if fails["left"]:
                fails["left"] -= 1
                raise OSError("save died")
            return real_save(*a, **k)

        monkeypatch.setattr(ckpt_mod, "save", flaky_save)
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            if calls["n"] == 6:  # after the (failed) background save at 4
                raise RuntimeError("step boom")
            return {"w": state["w"] + batch["x"]}, {}

        sup = TrainSupervisor(
            step_fn=step_fn, make_batch=lambda s: {"x": jnp.float32(s)},
            ckpt_dir=str(tmp_path), ckpt_every=4, max_restarts=2,
        )
        # the failed save is drained + logged, recovery proceeds from the
        # previous checkpoint (step 0) and the run still completes
        state, step = sup.run({"w": jnp.float32(0.0)}, n_steps=8)
        assert step == 8 and float(state["w"]) == sum(range(8))


# ----------------------------------------------- live guards + mitigation ---


def _run(extra):
    from repro.app.cli import run

    return run(["train", *TINY, *extra])


class TestGuards:
    def test_nan_rollback_recovers_exact_trajectory(self, tmp_path):
        clean = _run(["--steps", "8", "--modules", "metrics"])
        chaotic = _run([
            "--steps", "8", "--modules", "metrics,ft",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--set", "ft.chaos.nan_at_step=4",
        ])
        ft = chaotic["ft"]
        assert ft["guard_trips"] == 1 and ft["rollbacks"] == 1
        events = [t["event"] for t in ft["timeline"]]
        assert events == ["guard:rollback", "rollback"]
        # rollback + step-indexed replay = the fault-free trajectory
        assert chaotic["history"][-1]["step"] == 8
        np.testing.assert_allclose(
            chaotic["history"][-1]["loss"], clean["history"][-1]["loss"],
            rtol=1e-5)

    def test_nan_skip_discards_update_without_restart(self):
        res = _run([
            "--steps", "6", "--modules", "metrics,ft",
            "--set", "ft.chaos.nan_at_step=3",
            "--set", "ft.guard_action=skip",
        ])
        ft = res["ft"]
        assert ft["guard_trips"] == 1
        assert ft["rollbacks"] == 0 and ft["restarts"] == 0
        assert res["history"][-1]["step"] == 6
        assert math.isfinite(res["history"][-1]["loss"])

    def test_guard_off_lets_nan_poison_the_run(self):
        res = _run([
            "--steps", "5", "--modules", "metrics,ft",
            "--set", "ft.chaos.nan_at_step=2",
            "--set", "ft.guard_nan=false", "--set", "ft.guard_action=skip",
        ])
        assert res["ft"]["guard_trips"] == 0
        assert math.isnan(res["history"][-1]["loss"])  # why the guard exists


class TestMitigationExecution:
    def test_insufficient_evidence_decides_none_via_plugin(self):
        # one detection pass at step 4: ~4 collective instances, below
        # ft.min_evidence=8 -> the policy verdict is NONE, nothing executes
        res = _run([
            "--steps", "6", "--modules", "scan,metrics,ft",
            "--detect-online", "--set", "scan.detect_every=4",
            "--set", "ft.chaos.slow_rank_from=0",
            "--set", "ft.chaos.slow_rank=1",
            "--set", "ft.chaos.slow_factor=0.5",
        ])
        ft = res["ft"]
        assert ft["detections"] >= 1
        assert not any(t["event"].startswith(("decide", "mitigate"))
                       for t in ft["timeline"]), ft["timeline"]
        assert ft["restarts"] == 0 and ft["excluded_ranks"] == []

    def test_degraded_link_switches_on_compression(self):
        res = _run([
            "--steps", "12", "--modules", "scan,metrics,ft",
            "--detect-online", "--set", "scan.detect_every=4",
            "--set", "ft.chaos.degrade_link=0-1",
        ])
        ft = res["ft"]
        assert ft["compression_on"] and ft["replans"] == 1
        events = [t["event"] for t in ft["timeline"]]
        assert "decide:replan" in events and "mitigate:compress_on" in events
        on = next(t for t in ft["timeline"]
                  if t["event"] == "mitigate:compress_on")
        d = on["details"]
        assert d["links"] == [[0, 1]]
        assert 0 < d["wire_bytes_per_sync"] < d["baseline_bytes_per_sync"]
        series = res["metrics"]["series"]
        assert 0 < series["ft.wire_bytes_compressed"] < series["ft.wire_bytes_baseline"]
        # compressed-sync steps still train (finite, decreasing-ish loss)
        assert math.isfinite(res["history"][-1]["loss"])

    def test_hard_straggler_excluded_via_restart(self, tmp_path):
        res = _run([
            "--steps", "14", "--modules", "scan,metrics,ft",
            "--detect-online", "--set", "scan.detect_every=4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--set", "ft.chaos.slow_rank_from=0",
            "--set", "ft.chaos.slow_rank=1",
            "--set", "ft.chaos.slow_factor=0.5",
        ])
        ft = res["ft"]
        assert ft["excluded_ranks"] == [1]
        assert ft["restarts"] == 1
        events = [t["event"] for t in ft["timeline"]]
        for e in ("decide:exclude", "mitigate:exclude", "restart"):
            assert e in events, ft["timeline"]
        assert res["history"][-1]["step"] == 14
        # detection happened online, before the run ended
        assert res["scan"]["online"]["first_detect_step"] <= 8

    def test_slow_stage_replans_pipeline_schedule(self):
        if len(jax.devices()) < 2:
            pytest.skip("pipeline replan needs >= 2 host devices")
        res = _run([
            "--steps", "14", "--global-batch", "4",
            "--pp", "2", "--n-micro", "2",
            "--modules", "scan,metrics,ft",
            "--detect-online", "--set", "scan.detect_every=4",
            "--set", "obs.rank_events=true", "--set", "obs.slow_rank=1",
            "--set", "obs.slow_factor=0.5",
            # soften the exclude threshold so a confirmed straggler REPLANs
            "--set", "ft.slow_frac_hard=1.1",
        ])
        ft = res["ft"]
        assert ft["replans"] == 1 and ft["restarts"] == 0
        rp = next(t for t in ft["timeline"]
                  if t["event"] == "mitigate:replan_schedule")
        assert rp["details"]["slow_ranks"] == [1]
        assert rp["details"]["wave"] >= 1
        assert res["history"][-1]["step"] == 14
        assert math.isfinite(res["history"][-1]["loss"])


# ------------------------------------------------- acceptance: full chaos ---


class TestChaosAcceptance:
    """ISSUE acceptance: crash at step k AND an induced straggler — the run
    completes all n steps, matches the fault-free final loss, and the
    mitigation timeline lands in results["ft"]."""

    @pytest.fixture(scope="class")
    def runs(self, tmp_path_factory):
        d = tmp_path_factory.mktemp("ft") / "ckpt"
        clean = _run(["--steps", "14", "--modules", "metrics"])
        chaotic = _run([
            "--steps", "14", "--modules", "scan,metrics,ft",
            "--detect-online", "--set", "scan.detect_every=4",
            "--ckpt-dir", str(d), "--ckpt-every", "3",
            "--set", "ft.chaos.crash_at_step=5",
            "--set", "ft.chaos.slow_rank_from=0",
            "--set", "ft.chaos.slow_rank=1",
            "--set", "ft.chaos.slow_factor=0.5",
        ])
        return clean, chaotic

    def test_completes_all_steps(self, runs):
        _, chaotic = runs
        assert chaotic["history"][-1]["step"] == 14

    def test_final_loss_matches_fault_free(self, runs):
        clean, chaotic = runs
        np.testing.assert_allclose(
            chaotic["history"][-1]["loss"], clean["history"][-1]["loss"],
            rtol=1e-5)

    def test_timeline_records_crash_restart_and_exclusion(self, runs):
        _, chaotic = runs
        ft = chaotic["ft"]
        assert ft["restarts"] >= 2  # the crash + the exclusion restart
        events = [t["event"] for t in ft["timeline"]]
        for e in ("restart", "decide:exclude", "mitigate:exclude"):
            assert e in events, ft["timeline"]
        crash = next(t for t in ft["timeline"] if t["event"] == "restart")
        assert crash["details"]["reason"] == "InjectedCrash"
        assert ft["excluded_ranks"] == [1]
        assert ft["detections"] > 0

    def test_counters_in_metrics_series(self, runs):
        _, chaotic = runs
        series = chaotic["metrics"]["series"]
        assert series["ft.restarts"] >= 2


class TestCliFlags:
    def test_chaos_crash_flag(self, tmp_path):
        res = _run([
            "--steps", "6", "--modules", "metrics,ft",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--chaos-crash-at", "3", "--max-restarts", "2",
        ])
        ft = res["ft"]
        assert ft["restarts"] == 1
        assert ft["timeline"][-1]["details"]["reason"] == "InjectedCrash"
        assert res["history"][-1]["step"] == 6

    def test_crash_without_ckpt_dir_rejected(self):
        with pytest.raises(SystemExit, match="ckpt_dir"):
            _run(["--steps", "4", "--modules", "ft",
                  "--set", "ft.chaos.crash_at_step=2"])

    def test_max_restarts_bounds_recovery(self, tmp_path, monkeypatch):
        from repro.ft.chaos import InjectedCrash

        # every restart re-crashes (fired-set cleared) -> budget exhausts
        monkeypatch.setattr(ChaosInjector, "crash_due",
                            lambda self, step: step == 3)
        with pytest.raises(InjectedCrash):
            _run(["--steps", "6", "--modules", "ft",
                  "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                  "--chaos-crash-at", "3", "--max-restarts", "2"])
