"""Deterministic synthetic token pipeline with background prefetch.

Step-indexed determinism (batch content is a pure function of (seed, step,
host)) makes restarts reproducible: after failover the pipeline resumes at an
arbitrary step with identical data — a requirement for elastic restart
(repro/ft) at cluster scale.  Documents are packed with BOS boundaries and a
loss mask, mimicking a packed-LM pipeline.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bos_id: int = 1
    mean_doc_len: int = 256
    n_hosts: int = 1
    host_id: int = 0


class SyntheticTokens:
    """Markov-ish synthetic LM stream (learnable structure, not pure noise)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = cfg.global_batch // cfg.n_hosts
        # the generative rule is a *dataset-level* constant (learnable);
        # per-step randomness only drives starts and noise
        rule_rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 977]))
        self._a = int(rule_rng.integers(2, 64))
        self._b = int(rule_rng.integers(0, cfg.vocab_size))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        B, S = self.host_batch, cfg.seq_len
        # next-token structure: x_{t+1} = (a * x_t + b) % V with noise
        x = np.empty((B, S + 1), np.int64)
        x[:, 0] = rng.integers(0, cfg.vocab_size, B)
        for t in range(S):
            x[:, t + 1] = (self._a * x[:, t] + self._b) % cfg.vocab_size
        noise = rng.random((B, S + 1)) < 0.05
        x[noise] = rng.integers(0, cfg.vocab_size, noise.sum())
        # pack pseudo-documents: BOS resets + mask
        mask = np.ones((B, S), np.float32)
        doc_break = rng.random((B, S)) < (1.0 / cfg.mean_doc_len)
        x[:, 1:][doc_break] = cfg.bos_id
        tokens = x[:, :S].astype(np.int32)
        targets = x[:, 1 : S + 1].astype(np.int32)
        return {"tokens": tokens, "targets": targets, "loss_mask": mask}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded), start offset for resume."""

    def __init__(self, source: SyntheticTokens, depth: int = 2, start_step: int = 0):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.source.batch_at(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self) -> dict[str, np.ndarray]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)


def make_pipeline(cfg: DataConfig, *, prefetch: int = 2, start_step: int = 0):
    return Prefetcher(SyntheticTokens(cfg), depth=prefetch, start_step=start_step)
