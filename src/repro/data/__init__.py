from repro.data.pipeline import DataConfig, SyntheticTokens, Prefetcher, make_pipeline

__all__ = ["DataConfig", "SyntheticTokens", "Prefetcher", "make_pipeline"]
