"""Checkpoint/restart supervisor with elastic recovery.

Runs the user's step function; on failure restores the latest checkpoint and
resumes (optionally on a reconfigured mesh — elastic scale-down after node
exclusion).  Data-pipeline determinism (step-indexed batches) makes resumed
runs bitwise-reproducible modulo excluded hardware.
"""

from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore

log = logging.getLogger("repro.ft")


@dataclass
class TrainSupervisor:
    step_fn: Callable[[Any, dict], tuple[Any, dict]]
    make_batch: Callable[[int], dict]
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    on_restore: Callable[[Any, int], Any] | None = None  # resharding hook
    history: list[dict] = field(default_factory=list)

    def run(self, state: Any, n_steps: int, start_step: int = 0) -> tuple[Any, int]:
        ckpt = Checkpointer(self.ckpt_dir)
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                batch = self.make_batch(step)
                state, metrics = self.step_fn(state, batch)
                self.history.append({"step": step, **{
                    k: float(v) for k, v in metrics.items()
                    if hasattr(v, "ndim") and v.ndim == 0
                }})
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    ckpt.save_async(state, step)
            except Exception as e:  # noqa: BLE001 (injected device failures)
                restarts += 1
                log.warning("step %d failed (%s); restart %d", step, e, restarts)
                if restarts > self.max_restarts:
                    raise
                # drain (not wait): a background save error here must not
                # mask the step failure we are recovering from — log it and
                # continue to the restore attempt
                bg = ckpt.drain()
                if bg is not None:
                    log.warning("background checkpoint save failed (%s); "
                                "restoring from the previous one", bg)
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                state, _ = restore(self.ckpt_dir, state)
                step = last
                # drop history rows past the restored step — the replayed
                # steps re-append them; keeping both double-counts
                self.history = [h for h in self.history if h["step"] < last]
                if self.on_restore is not None:
                    state = self.on_restore(state, step)
        ckpt.wait()
        return state, step
