"""Gradient compression with error feedback (distributed-optimization trick).

int8 block quantization applied to gradients before the (simulated) DP
all-reduce: 4x fewer gradient-sync bytes at bf16 baseline.  The quantization
residual is carried in an error-feedback buffer and re-added next step, which
keeps SGD/Adam convergence (Karimireddy et al.); without feedback the bias
accumulates — ``tests/test_substrate.py`` demonstrates both.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GradCompressor:
    block: int = 256
    bits: int = 8

    def init(self, params: Any) -> Any:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def _qdq(self, g: jax.Array) -> jax.Array:
        """Quantize-dequantize one tensor with per-block scales."""
        levels = 2 ** (self.bits - 1) - 1
        flat = g.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % self.block
        flat = jnp.pad(flat, (0, pad))
        blk = flat.reshape(-1, self.block)
        scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / levels
        scale = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(blk / scale), -levels, levels)
        deq = (q * scale).reshape(-1)[: g.size].reshape(g.shape)
        return deq.astype(g.dtype)

    def apply(self, grads: Any, err: Any) -> tuple[Any, Any]:
        """Returns (compressed grads to all-reduce, new error buffers)."""
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            deq = self._qdq(gf)
            return deq.astype(g.dtype), gf - deq.astype(jnp.float32)

        out = jax.tree.map(one, grads, err)
        comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return comp, new_err

    def wire_bytes(self, grads: Any) -> tuple[int, int]:
        """(compressed, bf16-baseline) gradient-sync byte volumes."""
        n = sum(g.size for g in jax.tree.leaves(grads))
        n_scales = sum(
            -(-g.size // self.block) for g in jax.tree.leaves(grads)
        )
        return n * self.bits // 8 + n_scales * 4, n * 2
