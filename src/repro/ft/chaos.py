"""Declarative chaos injection for fault-tolerance runs.

A :class:`ChaosSpec` names the anomalies a run should survive — a process
crash at a step, a NaN batch, a rank downclocked from some step on, a
degraded DP link — in one declarative object that works at **both** fidelity
levels:

* **simkit** (offline): :meth:`ChaosSpec.to_fault_model` turns the spec into
  an engine :class:`~repro.core.simkit.engine.FaultModel`, and
  :func:`simulate_policy` runs the full simulate -> align -> detect ->
  :class:`~repro.ft.mitigation.MitigationPolicy` pipeline without touching a
  device — policy evaluation in milliseconds;
* **host mesh** (live): a :class:`ChaosInjector` is consumed by the real
  train loop (``--set ft.chaos.crash_at_step=5``): the crash raises a real
  :class:`InjectedCrash` out of the step, the NaN corrupts the real batch's
  ``loss_mask`` so the loss goes NaN through the actual forward pass, and
  the straggler/link faults drive ``repro.obs.inject`` event synthesis plus
  genuine in-step sleeps.

Crash and NaN injections fire **once**: after the supervisor restores and
replays the step, the injector remembers it already fired — exactly like a
real transient fault — which is what makes the recovered run's final loss
comparable to a fault-free run (step-indexed batch determinism).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.simkit.engine import FaultModel


class InjectedCrash(RuntimeError):
    """A chaos-injected process failure (raised out of the train step)."""


def parse_link(spec: str) -> tuple[int, int]:
    """Parse a directed link spec ``"src-dst"`` -> ``(src, dst)``."""
    try:
        src, _, dst = spec.partition("-")
        return int(src), int(dst)
    except ValueError as e:
        raise ValueError(
            f"degrade_link wants 'src-dst' (e.g. '0-1'), got {spec!r}"
        ) from e


@dataclass(frozen=True)
class ChaosSpec:
    """What goes wrong, declaratively.  All fields default to "nothing"."""

    crash_at_step: int = -1        # raise InjectedCrash at this step (< 0 off)
    nan_at_step: int = -1          # poison this step's batch to a NaN loss
    slow_rank_from: int = -1       # downclock ``slow_rank`` from this step on
    slow_rank: int = 1
    slow_factor: float = 0.5       # its relative speed (0.5 = half)
    degrade_link: str = ""         # directed "src-dst" DP link ("" = healthy)
    degrade_factor: float = 0.25   # its relative bandwidth

    @property
    def active(self) -> bool:
        return (
            self.crash_at_step >= 0 or self.nan_at_step >= 0
            or self.slow_rank_from >= 0 or bool(self.degrade_link)
        )

    @property
    def needs_restore(self) -> bool:
        """True when this chaos can only be survived via checkpoint restore."""
        return self.crash_at_step >= 0

    def to_fault_model(self, *, jitter: float = 0.01, seed: int = 0) -> FaultModel:
        """The simkit view of this spec (crash/NaN have no offline analogue —
        they are recovery faults, not timeline faults)."""
        compute = (
            {self.slow_rank: self.slow_factor} if self.slow_rank_from >= 0 else {}
        )
        links = (
            {parse_link(self.degrade_link): self.degrade_factor}
            if self.degrade_link else {}
        )
        return FaultModel(
            compute_slowdown=compute, link_slowdown=links,
            jitter=jitter, seed=seed,
        )


@dataclass
class ChaosInjector:
    """Stateful live-run driver for a :class:`ChaosSpec`.

    One-shot faults (crash, NaN) track whether they already fired so a
    restore-and-replay of the same step does not re-fire them.
    """

    spec: ChaosSpec
    fired: set[str] = field(default_factory=set)

    def crash_due(self, step: int) -> bool:
        if self.spec.crash_at_step == step and "crash" not in self.fired:
            self.fired.add("crash")
            return True
        return False

    def poison_batch(self, batch: dict, step: int) -> dict:
        """NaN the loss mask once at ``nan_at_step``: the loss goes NaN
        through the real masked-CE forward, grads follow, and without a
        guard the optimizer state is corrupted — the failure mode the
        in-band guards exist to catch."""
        if self.spec.nan_at_step != step or "nan" in self.fired:
            return batch
        self.fired.add("nan")
        import numpy as np

        poisoned = dict(batch)
        mask = np.asarray(batch["loss_mask"], dtype=np.float32)
        poisoned["loss_mask"] = np.full_like(mask, np.nan)
        return poisoned

    def slow_active(self, step: int) -> bool:
        return 0 <= self.spec.slow_rank_from <= step

    def link(self) -> tuple[int, int] | None:
        return parse_link(self.spec.degrade_link) if self.spec.degrade_link else None


def simulate_policy(
    spec: ChaosSpec,
    topo=None,
    *,
    n_micro: int = 8,
    n_iters: int = 10,  # 1 gradient sync per iter: >= MitigationPolicy.min_evidence
    policy=None,
    seed: int = 0,
    thresholds: dict | None = None,
):
    """Offline what-if: simulate a trace under ``spec``, run the 3-stage
    detector, and ask the :class:`MitigationPolicy` what it would do.

    Returns ``(diagnosis, action, info)`` — the same triple the live
    ``FtController`` acts on, at simkit speed.  The default topology is
    ``dp=2, pp=2, tp=1`` (the smallest shape with both DP peers and a
    pipeline to degrade links on).
    """
    from repro.core.simkit.workload import ModelProfile, Topology
    from repro.core.tracing import (
        ClockModel,
        align_clocks,
        apply_alignment,
        detect,
        simulate_trace,
    )
    from repro.ft.mitigation import MitigationPolicy

    topo = topo or Topology(dp=2, pp=2, tp=1)
    events, _truth = simulate_trace(
        topo, ModelProfile(), n_micro=n_micro, n_iters=n_iters,
        faults=spec.to_fault_model(seed=seed), clocks=ClockModel(seed=seed),
    )
    aligned = apply_alignment(events, align_clocks(events))
    diag = detect(aligned, topo, **(thresholds or {}))
    action, info = (policy or MitigationPolicy()).decide(diag)
    return diag, action, info
