"""FtController: the decide half of detect -> decide -> mitigate -> recover.

The :class:`~repro.app.plugins.ScanPlugin`'s online detector emits
:class:`~repro.obs.detector.DetectionUpdate`s; the controller runs
:class:`~repro.ft.mitigation.MitigationPolicy` over each diagnosis and turns
decisions into *pending actions* the train loop executes at the next step
boundary:

* ``REPLAN`` with degraded DP links -> switch on
  :class:`~repro.ft.compress.GradCompressor` int8 gradient sync (less wire
  traffic over the sick link);
* ``REPLAN`` with slow ranks on a pipeline run -> re-resolve the MegaDPP
  schedule around the slow stage (``Planner.replan``);
* ``EXCLUDE_RESTART`` -> mark the rank excluded and roll back through the
  ``Checkpointer`` elastic-restore path.

Each distinct decision executes once (the detector keeps re-confirming a
standing diagnosis every pass; acting on every pass would restart forever).
The controller also owns the in-band guards (NaN/inf loss, grad-norm spike)
and the :class:`~repro.ft.chaos.ChaosInjector` driving the faults it is
proving recovery from, plus the mitigation **timeline** that lands in
``results["ft"]``.
"""

from __future__ import annotations

import logging
from collections import deque
from dataclasses import dataclass, field, replace

from repro.ft.chaos import ChaosInjector
from repro.ft.mitigation import MitigationAction, MitigationPolicy

log = logging.getLogger("repro.ft")


@dataclass
class FtOptions:
    """Supervision + guard knobs (mirrors ``RunConfig.ft``)."""

    max_restarts: int = 3
    backoff_s: float = 0.0         # base restart backoff (doubles per restart)
    guard_nan: bool = True         # nonfinite loss -> guard_action
    guard_spike: float = 0.0       # >0: grad_norm > spike * running median
    guard_action: str = "rollback"  # rollback | skip


@dataclass(frozen=True)
class PendingAction:
    """One decided-but-not-yet-executed mitigation."""

    kind: str                      # "replan" | "exclude"
    detect_step: int               # detector pass (push count) that decided it
    slow_ranks: tuple[int, ...] = ()
    degraded_links: tuple[tuple[int, int], ...] = ()
    severity: float = 0.0


class FtController:
    """Session-lifetime fault-tolerance state machine.

    Built by the ``ft`` plugin, registered as a detection listener, and
    threaded into ``train.loop.train`` which polls it every step.
    """

    def __init__(
        self,
        policy: MitigationPolicy | None = None,
        chaos: ChaosInjector | None = None,
        options: FtOptions | None = None,
    ):
        self.policy = policy or MitigationPolicy()
        self.chaos = chaos
        self.options = options or FtOptions()
        self.registry = None           # set by the loop (MetricsRegistry)
        self.timeline: list[dict] = []
        self.restarts = 0
        self.rollbacks = 0
        self.replans = 0
        self.guard_trips = 0
        self.detections = 0
        self.excluded: set[int] = set()
        self.compression_on = False
        self._pending: list[PendingAction] = []
        self._acted: set[tuple] = set()
        self._gnorms: deque[float] = deque(maxlen=64)

    # ------------------------------------------------------------ detection
    def on_detection(self, update) -> None:
        """Policy pass over one online diagnosis (a detection listener)."""
        self.detections += 1
        action, info = self.policy.decide(update.diagnosis)
        if action is MitigationAction.NONE:
            return
        # already-excluded ranks keep haunting the sliding window until
        # their old events roll out — don't re-mitigate them
        ranks = tuple(sorted(set(update.diagnosis.slow_ranks) - self.excluded))
        links = tuple(sorted(tuple(l) for l in update.diagnosis.degraded_links))
        if not ranks and not links:
            return
        sig = (action.value, ranks, links)
        if sig in self._acted:
            return
        self._acted.add(sig)
        kind = "exclude" if action is MitigationAction.EXCLUDE_RESTART else "replan"
        self._pending.append(PendingAction(
            kind=kind, detect_step=update.step, slow_ranks=ranks,
            degraded_links=links, severity=float(info.get("severity", 0.0)),
        ))
        self.record(update.step, f"decide:{action.value}", {
            "slow_ranks": list(ranks),
            "degraded_links": [list(l) for l in links],
            "severity": round(float(info.get("severity", 0.0)), 4),
        })
        log.warning("ft: decision %s (slow=%s links=%s)",
                    action.value, list(ranks), [list(l) for l in links])

    def poll(self) -> list[PendingAction]:
        """Drain pending actions (the loop executes them at the step top)."""
        pending, self._pending = self._pending, []
        return pending

    # ---------------------------------------------------------------- chaos
    def crash_due(self, step: int) -> bool:
        return self.chaos is not None and self.chaos.crash_due(step)

    def poison_batch(self, batch: dict, step: int) -> dict:
        return batch if self.chaos is None else self.chaos.poison_batch(batch, step)

    def effective_obs(self, obs, step: int):
        """Fold chaos faults and exclusions into the per-rank event spec.

        The induced slowdown stops once its rank is excluded — the detector
        then watches the straggler *clear*, which is the observable proof
        that exclusion worked.
        """
        if obs is None:
            return None
        spec = obs
        if self.chaos is not None:
            c = self.chaos.spec
            if self.chaos.slow_active(step) and c.slow_rank not in self.excluded:
                spec = replace(spec, slow_rank=c.slow_rank,
                               slow_factor=c.slow_factor)
            link = self.chaos.link()
            if link is not None:
                spec = replace(spec, degrade_link=link,
                               degrade_factor=c.degrade_factor)
        if spec.slow_rank >= 0 and spec.slow_rank in self.excluded:
            spec = replace(spec, slow_rank=-1)
        return spec

    # --------------------------------------------------------------- guards
    def check_guards(self, step: int, loss: float, grad_norm: float) -> str | None:
        """In-band step guards; returns the guard action when one trips.

        NaN/inf loss means the update that just ran poisoned the state —
        ``rollback`` restores the last checkpoint (exact-trajectory replay),
        ``skip`` discards the update and keeps going (cheaper, but the
        skipped batch diverges the run from a fault-free trajectory).
        """
        import math

        o = self.options
        if o.guard_nan and not (math.isfinite(loss) and math.isfinite(grad_norm)):
            self.guard_trips += 1
            self._count("ft.guard_trips")
            self.record(step, f"guard:{o.guard_action}",
                        {"loss": str(loss), "grad_norm": str(grad_norm)})
            log.warning("ft: nonfinite guard tripped at step %d (loss=%s)",
                        step, loss)
            return o.guard_action
        if o.guard_spike > 0 and len(self._gnorms) >= 8:
            med = sorted(self._gnorms)[len(self._gnorms) // 2]
            if med > 0 and grad_norm > o.guard_spike * med:
                self.guard_trips += 1
                self._count("ft.guard_trips")
                self.record(step, f"guard:{o.guard_action}", {
                    "grad_norm": round(grad_norm, 4),
                    "median": round(med, 4),
                })
                log.warning("ft: grad-spike guard tripped at step %d "
                            "(%.3g > %.1fx median %.3g)",
                            step, grad_norm, o.guard_spike, med)
                return o.guard_action
        if math.isfinite(grad_norm):
            self._gnorms.append(grad_norm)
        return None

    # ----------------------------------------------------------- accounting
    def record(self, step: int, event: str, details: dict | None = None) -> None:
        self.timeline.append({"step": step, "event": event,
                              **({"details": details} if details else {})})

    def record_restart(self, failed_step: int, resumed_step: int, reason: str) -> None:
        self.restarts += 1
        self._count("ft.restarts")
        self.record(failed_step, "restart",
                    {"resumed_step": resumed_step, "reason": reason,
                     "restart": self.restarts})

    def record_rollback(self, step: int, to_step: int) -> None:
        self.rollbacks += 1
        self._count("ft.rollbacks")
        self.record(step, "rollback", {"to_step": to_step})

    def _count(self, name: str, v: float = 1) -> None:
        if self.registry is not None:
            self.registry.counter(name).inc(v)

    def report(self) -> dict:
        """The ``results["ft"]`` payload: mitigation timeline + counters."""
        return {
            "timeline": list(self.timeline),
            "restarts": self.restarts,
            "rollbacks": self.rollbacks,
            "replans": self.replans,
            "guard_trips": self.guard_trips,
            "detections": self.detections,
            "excluded_ranks": sorted(self.excluded),
            "compression_on": self.compression_on,
        }
