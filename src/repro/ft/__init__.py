from repro.ft.mitigation import MitigationAction, MitigationPolicy
from repro.ft.failover import TrainSupervisor
from repro.ft.compress import GradCompressor

__all__ = ["MitigationAction", "MitigationPolicy", "TrainSupervisor", "GradCompressor"]
