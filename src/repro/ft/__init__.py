from repro.ft.chaos import ChaosInjector, ChaosSpec, InjectedCrash, parse_link, simulate_policy
from repro.ft.compress import GradCompressor
from repro.ft.controller import FtController, FtOptions, PendingAction
from repro.ft.failover import TrainSupervisor
from repro.ft.mitigation import MitigationAction, MitigationPolicy

__all__ = [
    "ChaosInjector",
    "ChaosSpec",
    "FtController",
    "FtOptions",
    "GradCompressor",
    "InjectedCrash",
    "MitigationAction",
    "MitigationPolicy",
    "PendingAction",
    "TrainSupervisor",
    "parse_link",
    "simulate_policy",
]
