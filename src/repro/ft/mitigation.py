"""Straggler mitigation policy: MegaScan diagnosis -> action.

Closes the loop the paper leaves as future work ("native support for fast
failover after anomaly detection"): detection output drives either a MegaDPP
re-plan (soft mitigation — shift work away from a slow stage / degraded link)
or exclusion + elastic restart (hard mitigation) depending on severity.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum

from repro.core.tracing.detect import Diagnosis


class MitigationAction(Enum):
    NONE = "none"
    REPLAN = "replan"            # MegaDPP schedule re-plan around the anomaly
    EXCLUDE_RESTART = "exclude"  # drop the node, elastic restart from ckpt


@dataclass
class MitigationPolicy:
    slow_frac_soft: float = 0.3    # slow-op fraction -> replan
    slow_frac_hard: float = 0.7    # -> exclude + restart
    min_evidence: int = 8          # collective instances before acting

    def decide(self, diag: Diagnosis) -> tuple[MitigationAction, dict]:
        if diag.evidence.get("n_instances", 0) < self.min_evidence:
            return MitigationAction.NONE, {"reason": "insufficient evidence"}
        if not diag.slow_ranks and not diag.degraded_links:
            return MitigationAction.NONE, {"reason": "healthy"}
        worst = 0.0
        for r in diag.slow_ranks:
            worst = max(worst, diag.rank_scores.get(r, {}).get("slow_op_frac", 0.0))
        if worst >= self.slow_frac_hard:
            return MitigationAction.EXCLUDE_RESTART, {
                "exclude_ranks": diag.slow_ranks, "severity": worst,
            }
        return MitigationAction.REPLAN, {
            "slow_ranks": diag.slow_ranks,
            "degraded_links": diag.degraded_links,
            "severity": worst,
        }
