"""Speculative-decoding draft proposers for MegaServe.

A drafter guesses the next few tokens of a request so the target model can
*verify* them all in one batched forward (``engine.make_spec_verify_step``)
instead of generating them one step at a time.  Drafters are deliberately
host-side and stateless given the token history, so preemption-by-recompute
(which replays ``prompt + generated`` through a fresh prefill) cannot
desynchronize them — the same history always yields the same proposal, which
is what keeps greedy speculative serving token-identical to the
non-speculative path even across preemption round trips.

``NGramDrafter`` is prompt-lookup decoding (a.k.a. n-gram speculation): no
draft model, no extra parameters — it bets that the sequence's recent suffix
has occurred before and proposes whatever followed that earlier occurrence.
Cheap and surprisingly effective on repetitive/structured continuations
(code, extraction, self-repeating greedy loops); proposes nothing when the
history has no match, which lets the server skip verification entirely and
fall back to plain decode.

The ``Drafter`` protocol is the plug point for a future small-model drafter:
anything with ``propose(history, k) -> list[int]`` slots into
``MegaServe(..., drafter=...)``.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Drafter(Protocol):
    """Proposes up to ``k`` continuation tokens for a token history."""

    def propose(self, history: list[int], k: int) -> list[int]:
        """Return 0..k draft tokens continuing ``history``.  An empty list
        means "no guess" — the server then runs a plain decode step."""
        ...


class NGramDrafter:
    """Prompt-lookup drafter: longest-suffix n-gram match over the history.

    For ``n`` from ``max_ngram`` down to ``min_ngram``, the last ``n`` tokens
    are searched for in the earlier history (most recent occurrence first);
    on a hit, the ``k`` tokens that followed the match are proposed.  The
    scan is O(len(history) * max_ngram) per call — negligible next to a
    model forward, and bounded by ``max_history`` for very long sequences.
    """

    def __init__(self, max_ngram: int = 4, min_ngram: int = 1,
                 max_history: int = 4096):
        if min_ngram < 1 or max_ngram < min_ngram:
            raise ValueError(f"bad ngram range [{min_ngram}, {max_ngram}]")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_history = max_history

    def propose(self, history: list[int], k: int) -> list[int]:
        if k <= 0:
            return []
        hist = history[-self.max_history:]
        L = len(hist)
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            suffix = hist[L - n:]
            # most recent occurrence with a *full-length* continuation wins:
            # recency predicts best, but a match butted against the end of
            # history (the rule, not the exception, for periodic tails) only
            # yields a truncated draft — and since verification cost is fixed
            # at the padded draft ceiling, longer proposals are free
            best: list[int] = []
            for i in range(L - n - 1, -1, -1):
                if hist[i : i + n] == suffix:
                    cont = hist[i + n : i + n + k]
                    if len(cont) == k:
                        return list(cont)
                    if len(cont) > len(best):
                        best = list(cont)
            if best:
                return best
        return []


class RandomDrafter:
    """Adversarial drafter: proposes uniform-random tokens (acceptance ~1/V).

    Exists for worst-case benchmarking — every verification is wasted work,
    so serving throughput under this drafter bounds speculative decoding's
    regression on unfriendly workloads (and exercises the draft-length
    adaptation loop, which should shut speculation off).
    """

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def propose(self, history: list[int], k: int) -> list[int]:
        if k <= 0:
            return []
        # seeded from (seed, history) so the drafter stays stateless given
        # the token history — preemption-by-recompute replays identically
        rng = np.random.default_rng([self.seed, len(history), *history[-8:]])
        return rng.integers(2, self.vocab_size, size=k).tolist()


def get_drafter(kind: str, *, vocab_size: int = 0, max_ngram: int = 4,
                min_ngram: int = 1, seed: int = 0) -> Drafter:
    """CLI/benchmark factory: ``"ngram"`` or ``"random"`` (adversarial)."""
    if kind == "ngram":
        return NGramDrafter(max_ngram=max_ngram, min_ngram=min_ngram)
    if kind == "random":
        return RandomDrafter(vocab_size, seed=seed)
    raise ValueError(f"unknown drafter {kind!r}")
