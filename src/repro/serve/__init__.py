from repro.serve.engine import (
    cache_axes,
    make_decode_step,
    make_paged_decode_step,
    make_prefill_step,
    make_slot_decode_step,
    make_slot_prefill,
    make_spec_verify_step,
)
from repro.serve.paged_cache import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PoolSpec,
    blocks_for,
    pow2_bucket,
)
from repro.serve.request import Request, RequestStatus, aggregate_metrics
from repro.serve.router import Router, RouterConfig
from repro.serve.sampler import greedy_verify, rejection_verify, sample
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.server import MegaServe, run_static
from repro.serve.spec import Drafter, NGramDrafter, RandomDrafter, get_drafter

__all__ = [
    "BlockAllocator",
    "Drafter",
    "MegaServe",
    "NGramDrafter",
    "PagedKVCache",
    "PoolExhausted",
    "PoolSpec",
    "RandomDrafter",
    "Request",
    "RequestStatus",
    "Router",
    "RouterConfig",
    "Scheduler",
    "ServeConfig",
    "aggregate_metrics",
    "blocks_for",
    "cache_axes",
    "get_drafter",
    "greedy_verify",
    "make_decode_step",
    "make_paged_decode_step",
    "make_prefill_step",
    "make_slot_decode_step",
    "make_slot_prefill",
    "make_spec_verify_step",
    "pow2_bucket",
    "rejection_verify",
    "run_static",
    "sample",
]
