from repro.serve.engine import (
    cache_axes,
    make_decode_step,
    make_paged_decode_step,
    make_prefill_step,
    make_slot_decode_step,
    make_slot_prefill,
)
from repro.serve.paged_cache import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PoolSpec,
    blocks_for,
    pow2_bucket,
)
from repro.serve.request import Request, RequestStatus, aggregate_metrics
from repro.serve.sampler import sample
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.server import MegaServe, run_static

__all__ = [
    "BlockAllocator",
    "MegaServe",
    "PagedKVCache",
    "PoolExhausted",
    "PoolSpec",
    "Request",
    "RequestStatus",
    "Scheduler",
    "ServeConfig",
    "aggregate_metrics",
    "blocks_for",
    "cache_axes",
    "make_decode_step",
    "make_paged_decode_step",
    "make_prefill_step",
    "make_slot_decode_step",
    "make_slot_prefill",
    "pow2_bucket",
    "run_static",
    "sample",
]
