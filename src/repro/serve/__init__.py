from repro.serve.engine import make_decode_step, make_prefill_step, cache_axes
from repro.serve.sampler import sample

__all__ = ["make_decode_step", "make_prefill_step", "cache_axes", "sample"]
