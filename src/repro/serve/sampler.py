"""Token sampling and speculative-decoding acceptance.

Two halves, matching where each runs:

* ``sample`` — jax, inside the jitted decode step: greedy / temperature /
  top-k / top-p (nucleus) over a ``[B, V]`` logit batch.
* ``greedy_verify`` / ``rejection_verify`` — host-side numpy, consumed by the
  MegaServe scheduler loop after the batched spec-decode verification forward
  (``engine.make_spec_verify_step``) hands back per-position target
  predictions.  Greedy acceptance keeps the emitted stream token-identical to
  non-speculative greedy decoding; rejection sampling preserves the target
  model's sampling distribution exactly for any (deterministic) drafter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample(
    logits: jax.Array,  # [B, V]
    key: jax.Array | None = None,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jax.Array:
    """Sample one token per row.

    ``temperature <= 0`` (or no ``key``) is greedy argmax.  ``top_k > 0``
    restricts sampling to the k highest logits; ``0 < top_p < 1`` restricts
    it to the smallest set of tokens whose cumulative probability reaches
    ``top_p`` (nucleus sampling; the most likely token always survives).
    Both filters may be combined — top-k applies first.
    """
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if 0.0 < top_p < 1.0:
        sort_idx = jnp.argsort(logits, axis=-1)[..., ::-1]
        sorted_logits = jnp.take_along_axis(logits, sort_idx, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        # drop tokens once the mass *before* them already covers top_p, so
        # the minimal covering set (incl. the argmax) is always kept
        exceeded = jnp.cumsum(probs, axis=-1) - probs > top_p
        sorted_logits = jnp.where(exceeded, -jnp.inf, sorted_logits)
        inv = jnp.argsort(sort_idx, axis=-1)
        logits = jnp.take_along_axis(sorted_logits, inv, axis=-1)
    return jax.random.categorical(key, logits)


# ---------------------------------------------------------------------------
# Speculative-decoding acceptance (host side)
# ---------------------------------------------------------------------------
#
# The verification forward feeds one slot the token row
# ``[t_0, d_1, ..., d_k, <pad>...]`` (t_0 = the last committed token, d_i =
# the drafter's proposals) and returns the target model's prediction for the
# position after each row entry.  Row ``i`` therefore judges draft token
# ``d_{i+1}``; the first row whose verdict disagrees supplies the correction,
# and full acceptance promotes row ``k``'s prediction to a bonus token.
# Every step emits between 1 and k+1 tokens.


def greedy_verify(
    target: np.ndarray,      # [Q] greedy target predictions (argmax per row)
    draft: list[int],        # k <= Q - 1 proposed tokens
) -> tuple[int, list[int]]:
    """Greedy acceptance: returns ``(n_accepted, emitted)``.

    ``emitted`` is the accepted draft prefix plus one correction/bonus token,
    so it always holds ``n_accepted + 1`` tokens and exactly reproduces what
    non-speculative greedy decoding would have generated.
    """
    n = 0
    while n < len(draft) and int(target[n]) == int(draft[n]):
        n += 1
    return n, [int(d) for d in draft[:n]] + [int(target[n])]


def _renormalize(p: np.ndarray) -> np.ndarray:
    s = p.sum()
    if s <= 0.0:  # degenerate row: fall back to uniform
        return np.full_like(p, 1.0 / len(p))
    return p / s


def rejection_verify(
    target_probs: np.ndarray,  # [Q, V] target distribution per row
    draft: list[int],          # k <= Q - 1 proposed tokens
    rng: np.random.Generator,
) -> tuple[int, list[int]]:
    """Rejection-sampling acceptance for a *deterministic* drafter.

    Draft token ``d`` at row ``i`` is accepted with probability
    ``p_i(d)`` (the proposal places mass 1 on ``d``, so ``min(1, p/q) = p``).
    On rejection the emitted token is drawn from the residual distribution
    ``normalize(max(0, p_i - q_i))`` — here ``p_i`` with ``d`` zeroed out —
    which keeps the marginal distribution of every emitted token exactly the
    target model's (Leviathan et al., 2023).  Full acceptance samples the
    bonus token from row ``k``.  Returns ``(n_accepted, emitted)``.
    """
    emitted: list[int] = []
    n = 0
    for i, d in enumerate(draft):
        p = np.asarray(target_probs[i], np.float64)
        if rng.random() < p[int(d)]:
            emitted.append(int(d))
            n += 1
            continue
        residual = p.copy()
        residual[int(d)] = 0.0
        residual = _renormalize(residual)
        emitted.append(int(rng.choice(len(residual), p=residual)))
        return n, emitted
    p = _renormalize(np.asarray(target_probs[len(draft)], np.float64))
    emitted.append(int(rng.choice(len(p), p=p)))
    return n, emitted
