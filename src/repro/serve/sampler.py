"""Token sampling: greedy / temperature / top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,  # [B, V]
    key: jax.Array | None = None,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k > 0:
        vals, idx = jax.lax.top_k(logits, top_k)
        choice = jax.random.categorical(key, vals)
        return jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0]
    return jax.random.categorical(key, logits)
