"""Serving step factories + cache sharding axes.

``serve_step`` semantics for the dry-run cells: ``decode_*`` / ``long_*``
lower one new token against a KV cache of ``seq_len`` (assignment spec);
``prefill_*`` lowers the full-prompt cache-fill.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR

# cache leaf name -> logical axes (by trailing dims; leading "layers" handled
# by rank: stacked leaves carry one extra leading dim)
_CACHE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "v": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ck": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "cv": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ckv": ("batch", "kv_time", "kv_lora_act"),
    "kpe": ("batch", "kv_time", "head_dim_act"),
    "wkv": ("batch", "heads_act", "state", "state"),
    "x_prev": ("batch", "embed_act"),
    "conv": ("batch", "conv", "mlp_act"),
    "h": ("batch", "mlp_act"),
}


def cache_axes(cache: Any) -> Any:
    """Mirror a cache pytree with logical-axes tuples derived from leaf names."""

    def leaf_axes(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        axes = _CACHE_AXES[name]
        extra = leaf.ndim - len(axes)
        assert extra >= 0, (name, leaf.shape)
        return ("layers",) * extra + axes

    return jax.tree_util.tree_map_with_path(leaf_axes, cache)


def make_prefill_step(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(cfg, params, batch, cache, collector)

    return prefill_step


def make_decode_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    temperature: float = 0.0,
) -> Callable:
    model = get_model(cfg)
    from repro.serve.sampler import sample

    def decode_step(params, cache, tokens, pos):
        cache, logits = model.decode_step(cfg, params, cache, tokens, pos, collector)
        next_tok = sample(logits, temperature=temperature)
        return cache, logits, next_tok

    return decode_step


# ---------------------------------------------------------------------------
# MegaServe continuous-batching steps (per-slot positions)
# ---------------------------------------------------------------------------
#
# The static decode step above shares one scalar ``pos`` across the batch —
# fine when every slot decodes in lockstep, useless for continuous batching
# where each slot sits at its own length.  These factories vmap a B=1 decode
# over the slot axis so every lane carries its own cache position, without
# touching the model code.  Cache leaves follow the ``lm.init_cache`` layout
# ``[n_layers, batch, ...]`` (batch axis 1), hence ``in_axes=1``.


def make_paged_decode_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    block_size: int,
    paged_flags: Any,
    impl: str = "auto",
) -> Callable:
    """Returns ``step(params, pool, tables [S, M], tokens [S], pos [S]) ->
    (pool, logits [S, V], captures)`` — one batched decode over all slots
    straight against the physical block pool.

    Replaces the gathered path's ``gather -> vmap(B=1) -> scatter_decode``
    round trip: slots ride the batch axis of a single ``lm.forward`` call
    with per-slot positions, attention leaves dispatch to the paged-attention
    kernel (in-place pool block writes, block-table walk, O(kv_len) traffic),
    and slot-state leaves (rwkv/griffin recurrent state) use their dense
    per-slot pool storage directly.  ``paged_flags`` is the pool's leaf-kind
    tree (``PagedKVCache.paged``): pool leaves thread ``lm.forward``'s scan
    carry and are updated in place (donate the pool at the jit boundary!),
    so per-step cost is O(live kv_len), not O(pool).  ``tables`` may be
    sliced to the live block high-water mark; each distinct width compiles
    once.

    Captures surface with the slot axis leading (batched, not vmap-stacked),
    so per-position probe *reductions* see all slots at once — deep MegaScope
    probing should prefer the gathered path (``decode_path="gathered"``).
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    if cfg.use_mla:
        raise ValueError(f"{cfg.name}: MLA decodes via the gathered path")
    from repro.kernels.paged_attention.ops import PagedInfo
    from repro.models import layers as L
    from repro.models import lm

    def step(params, pool, tables, tokens, pos):
        paged = PagedInfo(tables=tables, block_size=block_size, impl=impl)
        hidden, new_pool, aux = lm.forward(
            cfg, params, {"tokens": tokens[:, None]},
            cache=pool, cache_pos=pos, paged=paged,
            paged_flags=paged_flags, collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden)[:, 0]
        return new_pool, logits, aux.get("captures", {})

    return step


def make_spec_verify_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    block_size: int,
    paged_flags: Any,
    impl: str = "auto",
) -> Callable:
    """Returns ``step(params, pool, tables [S, M], tokens [S, Q], pos [S]) ->
    (pool, greedy [S, Q], logits [S, Q, V], captures)`` — the speculative-
    decoding verification forward: every slot scores Q = draft_len + 1 tokens
    (its current last token followed by the drafter's proposal, right-padded
    to the static Q) in ONE batched call against the physical block pool.

    Row ``i`` of ``logits``/``greedy`` is the target model's prediction for
    the position *after* ``tokens[:, i]``, so row ``i`` verifies draft token
    ``i + 1`` and the last accepted row supplies the bonus/correction token
    (see ``sampler.greedy_verify`` / ``sampler.rejection_verify``).  K/V for
    all Q tokens are written in place at ``pos + i``; the caller commits the
    accepted prefix by advancing the slot cursor and *rewinds* the rest —
    rejected writes sit beyond the new ``kv_len``, where every later read
    masks them and every later write overwrites them before they could ever
    become live (``Scheduler.trim_blocks``).

    ``Q`` is baked into the compiled executable (one compile per distinct
    draft length ceiling); padded rows beyond a slot's real draft cost
    compute but are causally masked for the rows that matter and their
    writes land in the null block once past the slot's grown table reach.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    if cfg.use_mla:
        raise ValueError(f"{cfg.name}: MLA decodes via the gathered path")
    from repro.kernels.paged_attention.ops import PagedInfo
    from repro.models import layers as L
    from repro.models import lm

    def step(params, pool, tables, tokens, pos):
        # prefill=True: the Q verify rows take the fused flash-prefill path
        # (q-block x kv-block kernel) instead of the generic dense branch
        paged = PagedInfo(
            tables=tables, block_size=block_size, impl=impl, prefill=True,
        )
        hidden, new_pool, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=pool, cache_pos=pos, paged=paged,
            paged_flags=paged_flags, collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden)          # [S, Q, V]
        return new_pool, jnp.argmax(logits, -1), logits, aux.get("captures", {})

    return step


def make_chunk_prefill_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    block_size: int,
    paged_flags: Any,
    impl: str = "auto",
) -> Callable:
    """Returns ``step(params, pool, tables [1, M], tokens [1, C], pos [1],
    n_last) -> (pool, last_logits [V], captures)`` — one fixed-size prompt
    chunk pushed through the q_len>1 paged kernel path straight into the
    slot's pool blocks.

    Chunk ``i`` writes K/V for positions ``pos .. pos+C-1`` of the owning
    slot and attends causally over everything already in the table (the
    same masking the spec-verify step relies on), so a long prompt becomes
    ``ceil(P / C)`` cheap calls with decode ticks interleaved between them
    instead of one monolithic stall.  ``n_last`` is the in-chunk index of
    the prompt's final real token — only the last chunk's logits (sliced
    there) are meaningful; earlier chunks' are discarded by the caller.
    Pad tokens past ``n_last`` on the final chunk write garbage K/V beyond
    the slot's ``kv_len``, where every later read masks them and the first
    decode write overwrites them.  ``C`` is baked into the compiled
    executable: one compile per (chunk_len, table width) pair.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    if cfg.use_mla:
        raise ValueError(f"{cfg.name}: MLA decodes via the gathered path")
    from repro.kernels.paged_attention.ops import PagedInfo
    from repro.models import layers as L
    from repro.models import lm

    def step(params, pool, tables, tokens, pos, n_last):
        # prefill=True: the C chunk rows take the fused flash-prefill path
        paged = PagedInfo(
            tables=tables, block_size=block_size, impl=impl, prefill=True,
        )
        hidden, new_pool, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=pool, cache_pos=pos, paged=paged,
            paged_flags=paged_flags, collector=collector,
        )
        last = jax.lax.dynamic_slice_in_dim(hidden, n_last, 1, axis=1)
        logits = L.logits_fn(params, cfg, last)[0, 0]
        return new_pool, logits, aux.get("captures", {})

    return step


def make_flash_prefill_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    block_size: int,
    paged_flags: Any,
    impl: str = "auto",
) -> Callable:
    """Returns ``step(params, pool, tables [1, M], tokens [1, P], n_real) ->
    (pool, last_logits [V], captures)`` — the whole (right-padded) prompt in
    one call *straight into the slot's pool blocks* via the flash-prefill
    kernel: no dense ``[1, P, ...]`` cache is ever materialized and no
    ``scatter_prefill`` copy follows.

    ``q_start=0`` pins query 0 at absolute position 0 (statically, for the
    whole bucket), which unlocks the causal lower-triangular band in the
    kernel/oracle: prefill attention cost is ~P²/2 score work instead of the
    dense path's P² plus a pool-sized gather/scatter round trip.  Pad tokens
    past ``n_real`` write garbage K/V beyond the slot's ``kv_len`` exactly
    like the dense path's pad positions — masked by every later read,
    overwritten by the first decode write.  ``P`` (and the table width
    ``M = P / block_size``) is baked into the executable: one compile per
    pow2 bucket, same ladder the dense path uses.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    if cfg.use_mla:
        raise ValueError(f"{cfg.name}: MLA decodes via the gathered path")
    from repro.kernels.paged_attention.ops import PagedInfo
    from repro.models import layers as L
    from repro.models import lm

    def step(params, pool, tables, tokens, n_real):
        paged = PagedInfo(
            tables=tables, block_size=block_size, impl=impl,
            prefill=True, q_start=0,
        )
        pos = jnp.zeros((1,), jnp.int32)
        hidden, new_pool, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=pool, cache_pos=pos, paged=paged,
            paged_flags=paged_flags, collector=collector,
        )
        last = jax.lax.dynamic_slice_in_dim(hidden, n_real - 1, 1, axis=1)
        logits = L.logits_fn(params, cfg, last)[0, 0]
        return new_pool, logits, aux.get("captures", {})

    return step


def make_seg_prefill(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    """Returns ``seg(params, cache, tokens [1, W], pos) -> (cache, last_logits
    [V], captures)`` — one exact-length prompt *segment* integrated into a
    dense cache at offset ``pos``, for recurrent-state families (rwkv /
    griffin) whose prefill must visit every real position.

    The caller splits ``n_real`` into its descending binary decomposition
    (13 -> 8 + 4 + 1) and runs one segment per power of two, carrying the
    cache between calls: the compile set becomes {segment widths} x {cache
    buckets} — O(log² max_len) — instead of one executable per exact prompt
    length, which is what makes ``precompile()`` finite for these families.
    The last segment ends exactly at ``n_real``, so its final position's
    logits are the first-token logits.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    from repro.models import layers as L
    from repro.models import lm

    def seg(params, cache, tokens, pos):
        hidden, new_cache, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=cache, cache_pos=pos, collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden[:, -1:])[0, 0]
        return new_cache, logits, aux.get("captures", {})

    return seg


def make_slot_decode_step(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    """Returns ``step(params, dense_cache, tokens [S], pos [S]) ->
    (dense_cache, logits [S, V], captures)`` with per-slot positions.

    ``dense_cache`` is the gathered paged view (see ``paged_cache.gather``);
    captures come out of ``lm.forward``'s aux so MegaScope probes yield
    per-slot records (stacked over the slot axis by vmap).
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    from repro.models import layers as L
    from repro.models import lm

    def one(params, cache_s, tok, pos):
        cache_b = jax.tree.map(lambda a: a[:, None], cache_s)  # batch=1 back
        hidden, new_cache, aux = lm.forward(
            cfg, params, {"tokens": tok[None, None]},
            cache=cache_b, cache_pos=pos, collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden)[0, 0]
        new_cache = jax.tree.map(lambda a: a[:, 0], new_cache)
        return new_cache, logits, aux.get("captures", {})

    def step(params, cache, tokens, pos):
        return jax.vmap(one, in_axes=(None, 1, 0, 0), out_axes=(1, 0, 0))(
            params, cache, tokens, pos
        )

    return step


def make_slot_prefill(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    """Returns ``prefill(params, tokens [1, P], n_real, cache_len) ->
    (filled_cache, last_logits [V], captures)``.

    ``tokens`` may be right-padded to ``P >= n_real`` for attention-only
    families (the causal mask keeps real positions blind to pad garbage, and
    pad K/V land beyond ``kv_len`` where decode masks them); the logits are
    taken at ``n_real - 1`` regardless.  Recurrent-state families integrate
    every position, so their callers must pass exact-length prompts
    (``P == n_real``).  The cache is rounded up to a block multiple by the
    caller via ``cache_len``.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    from repro.models import layers as L
    from repro.models import lm

    def prefill(params, tokens, n_real, cache_len: int):
        cache = lm.init_cache(cfg, 1, cache_len)
        hidden, new_cache, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=cache, cache_pos=jnp.int32(0), collector=collector,
        )
        last = jax.lax.dynamic_slice_in_dim(hidden, n_real - 1, 1, axis=1)
        logits = L.logits_fn(params, cfg, last)[0, 0]
        return new_cache, logits, aux.get("captures", {})

    return prefill
