"""Serving step factories + cache sharding axes.

``serve_step`` semantics for the dry-run cells: ``decode_*`` / ``long_*``
lower one new token against a KV cache of ``seq_len`` (assignment spec);
``prefill_*`` lowers the full-prompt cache-fill.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR

# cache leaf name -> logical axes (by trailing dims; leading "layers" handled
# by rank: stacked leaves carry one extra leading dim)
_CACHE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "v": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ck": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "cv": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ckv": ("batch", "kv_time", "kv_lora_act"),
    "kpe": ("batch", "kv_time", "head_dim_act"),
    "wkv": ("batch", "heads_act", "state", "state"),
    "x_prev": ("batch", "embed_act"),
    "conv": ("batch", "conv", "mlp_act"),
    "h": ("batch", "mlp_act"),
}


def cache_axes(cache: Any) -> Any:
    """Mirror a cache pytree with logical-axes tuples derived from leaf names."""

    def leaf_axes(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        axes = _CACHE_AXES[name]
        extra = leaf.ndim - len(axes)
        assert extra >= 0, (name, leaf.shape)
        return ("layers",) * extra + axes

    return jax.tree_util.tree_map_with_path(leaf_axes, cache)


def make_prefill_step(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(cfg, params, batch, cache, collector)

    return prefill_step


def make_decode_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    temperature: float = 0.0,
) -> Callable:
    model = get_model(cfg)
    from repro.serve.sampler import sample

    def decode_step(params, cache, tokens, pos):
        cache, logits = model.decode_step(cfg, params, cache, tokens, pos, collector)
        next_tok = sample(logits, temperature=temperature)
        return cache, logits, next_tok

    return decode_step
