"""Serving step factories + cache sharding axes.

``serve_step`` semantics for the dry-run cells: ``decode_*`` / ``long_*``
lower one new token against a KV cache of ``seq_len`` (assignment spec);
``prefill_*`` lowers the full-prompt cache-fill.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR

# cache leaf name -> logical axes (by trailing dims; leading "layers" handled
# by rank: stacked leaves carry one extra leading dim)
_CACHE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "v": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ck": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "cv": ("batch", "kv_time", "kv_heads_act", "head_dim_act"),
    "ckv": ("batch", "kv_time", "kv_lora_act"),
    "kpe": ("batch", "kv_time", "head_dim_act"),
    "wkv": ("batch", "heads_act", "state", "state"),
    "x_prev": ("batch", "embed_act"),
    "conv": ("batch", "conv", "mlp_act"),
    "h": ("batch", "mlp_act"),
}


def cache_axes(cache: Any) -> Any:
    """Mirror a cache pytree with logical-axes tuples derived from leaf names."""

    def leaf_axes(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        axes = _CACHE_AXES[name]
        extra = leaf.ndim - len(axes)
        assert extra >= 0, (name, leaf.shape)
        return ("layers",) * extra + axes

    return jax.tree_util.tree_map_with_path(leaf_axes, cache)


def make_prefill_step(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    model = get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(cfg, params, batch, cache, collector)

    return prefill_step


def make_decode_step(
    cfg: ModelConfig,
    collector: Collector = NULL_COLLECTOR,
    *,
    temperature: float = 0.0,
) -> Callable:
    model = get_model(cfg)
    from repro.serve.sampler import sample

    def decode_step(params, cache, tokens, pos):
        cache, logits = model.decode_step(cfg, params, cache, tokens, pos, collector)
        next_tok = sample(logits, temperature=temperature)
        return cache, logits, next_tok

    return decode_step


# ---------------------------------------------------------------------------
# MegaServe continuous-batching steps (per-slot positions)
# ---------------------------------------------------------------------------
#
# The static decode step above shares one scalar ``pos`` across the batch —
# fine when every slot decodes in lockstep, useless for continuous batching
# where each slot sits at its own length.  These factories vmap a B=1 decode
# over the slot axis so every lane carries its own cache position, without
# touching the model code.  Cache leaves follow the ``lm.init_cache`` layout
# ``[n_layers, batch, ...]`` (batch axis 1), hence ``in_axes=1``.


def make_slot_decode_step(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    """Returns ``step(params, dense_cache, tokens [S], pos [S]) ->
    (dense_cache, logits [S, V], captures)`` with per-slot positions.

    ``dense_cache`` is the gathered paged view (see ``paged_cache.gather``);
    captures come out of ``lm.forward``'s aux so MegaScope probes yield
    per-slot records (stacked over the slot axis by vmap).
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    from repro.models import layers as L
    from repro.models import lm

    def one(params, cache_s, tok, pos):
        cache_b = jax.tree.map(lambda a: a[:, None], cache_s)  # batch=1 back
        hidden, new_cache, aux = lm.forward(
            cfg, params, {"tokens": tok[None, None]},
            cache=cache_b, cache_pos=pos, collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden)[0, 0]
        new_cache = jax.tree.map(lambda a: a[:, 0], new_cache)
        return new_cache, logits, aux.get("captures", {})

    def step(params, cache, tokens, pos):
        return jax.vmap(one, in_axes=(None, 1, 0, 0), out_axes=(1, 0, 0))(
            params, cache, tokens, pos
        )

    return step


def make_slot_prefill(cfg: ModelConfig, collector: Collector = NULL_COLLECTOR) -> Callable:
    """Returns ``prefill(params, tokens [1, P], cache_len) ->
    (filled_cache, last_logits [V], captures)``.

    The prompt runs at its exact length (recurrent-state families integrate
    every position, so right-padding would corrupt rwkv/griffin state); only
    the cache is rounded up to a block multiple by the caller via
    ``cache_len``.
    """
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: continuous batching serves token archs")
    from repro.models import layers as L
    from repro.models import lm

    def prefill(params, tokens, cache_len: int):
        cache = lm.init_cache(cfg, 1, cache_len)
        hidden, new_cache, aux = lm.forward(
            cfg, params, {"tokens": tokens},
            cache=cache, cache_pos=jnp.int32(0), collector=collector,
        )
        logits = L.logits_fn(params, cfg, hidden[:, -1:, :])[0, 0]
        return new_cache, logits, aux.get("captures", {})

    return prefill
