"""MegaServe front-end: ``submit() / step() / drain()`` over the paged engine.

One ``step()`` is one scheduler tick: admit + prefill newly-arrived requests,
grow block tables (preempting if the pool is dry), run one fused decode step
for every active slot, evict finished slots so their space refills next tick.

Observability is first-class, mirroring the four-module philosophy:

* every prefill / decode step is bracketed by a MegaScan ``Tracer`` scope, so
  serving timelines flow through the same ``TraceEvent`` pipeline (chrome
  export, analytics, straggler detection) as training traces;
* an optional MegaScope ``ScopeCollector`` threads through the model; probe
  captures surface per-slot (the vmapped decode stacks them over the slot
  axis) and are attached to each request's stream records.

The static-batch baseline (`run_static`) drives the pre-existing lockstep
path for benchmarking and equivalence tests.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.compile_cache import CompileCache, aot_compile, mesh_descriptor
from repro.core.tracing.tracer import Tracer
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.serve.engine import (
    make_chunk_prefill_step,
    make_decode_step,
    make_flash_prefill_step,
    make_paged_decode_step,
    make_prefill_step,
    make_seg_prefill,
    make_slot_decode_step,
    make_slot_prefill,
    make_spec_verify_step,
)
from repro.serve.paged_cache import (
    PagedKVCache,
    PoolSpec,
    blocks_for,
    pow2_bucket,
    pow2_segments,
)
from repro.serve.request import Request, RequestStatus, aggregate_metrics
from repro.serve.sampler import greedy_verify, sample
from repro.serve.scheduler import Scheduler, ServeConfig
from repro.serve.spec import Drafter, NGramDrafter


@dataclass
class StreamItem:
    """One generated token of one request, with optional probe captures.

    Capture shapes differ by phase: the admission item's captures come from
    the B=1 prefill over the whole prompt (leaves keep their batch=1/time
    axes), later items are per-slot slices of the vmapped single-token
    decode.  Consumers should branch on which phase an item came from (the
    admission item is the first of a stream / of a recompute segment).
    """
    step: int
    token: int
    captures: dict = field(default_factory=dict)


class MegaServe:
    """Continuous-batching serving front-end: ``submit() / step() / drain()``.

    One ``step()`` is one scheduler tick — admit + prefill arrivals, grow
    block tables (preempting by recompute when the pool runs dry), run one
    fused decode (or speculative verify) step over every active slot, evict
    finished requests.  Greedy decoding is deterministic across all engine
    paths: paged vs gathered, speculative vs plain, and preemption round
    trips all produce token-identical streams.

    Construction keyword knobs:

    * ``collector`` — a MegaScope ``Collector``; probe captures attach to
      each generated token's ``StreamItem`` (deep per-slot probing prefers
      ``decode_path="gathered"``, which ``"auto"`` selects for you);
    * ``tracer`` — a MegaScan ``Tracer``; every phase (``prefill``,
      ``decode``, speculative ``draft``/``verify``/``accept``) emits
      ``TraceEvent``s consumable by the chrome exporter and analytics;
    * ``drafter`` — speculative-decoding proposer (``serve.spec.Drafter``);
      defaults to the n-gram prompt-lookup drafter when
      ``serve_cfg.spec_decode`` is set;
    * ``clock`` — injectable time source for deterministic tests/replays;
    * ``use_jit`` — disable jit for step-through debugging.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        serve_cfg: ServeConfig = ServeConfig(),
        *,
        collector: Collector = NULL_COLLECTOR,
        tracer: Tracer | None = None,
        clock: Callable[[], float] | None = None,
        drafter: Drafter | None = None,
        use_jit: bool = True,
        wrap_step: Callable[[Callable], Callable] | None = None,
        registry=None,
        metrics_prefix: str = "serve.",
        prefill_only: bool = False,
        compile_cache: CompileCache | None = None,
    ):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.params = params
        # live telemetry (a repro.obs.MetricsRegistry, or None): TTFT and
        # decode/prefill latency histograms, queue-depth / KV-occupancy
        # gauges, preemption + spec-acceptance counters publish per tick.
        # ``metrics_prefix`` namespaces the series (the router runs replica
        # i under "serve.r{i}." so per-replica load is attributable).
        self.registry = registry
        self._mpfx = metrics_prefix
        # disaggregation: a prefill-only replica admits + prefills (emitting
        # each request's first token) but never decodes — the router harvests
        # its filled slots via ``export_request`` and hands them to a decode
        # replica's ``adopt_request``
        self.prefill_only = prefill_only
        # decorator applied to every jitted engine step (prefill / decode /
        # spec-verify) — the ModulePlugin.wrap_step attach point
        self._wrap = wrap_step if wrap_step is not None else (lambda f: f)
        # persistent compilation cache (core.compile_cache): precompile()
        # consults it so a restarted process deserializes yesterday's
        # executables instead of re-running XLA
        self.compile_cache = compile_cache
        # AOT-compiled executables by (step kind, *static widths); the tick
        # paths dispatch through ``_aot_exec.get(key, jitted_fallback)`` so a
        # precompiled bucket skips the jit call cache entirely and an
        # unseen width still traces on demand
        self._aot_exec: dict[tuple, Callable] = {}
        self.sched = Scheduler(serve_cfg)
        self.tracer = tracer or Tracer(rank=0, enabled=True)
        self.collector = collector
        self._capture = collector is not NULL_COLLECTOR
        self.streams: dict[int, list[StreamItem]] = {}
        self.step_idx = 0
        self._next_rid = 0
        # offset-based clock: t=0 at construction (or last reset()), for
        # injected clocks too, so reset() re-times warmed-up runs correctly
        self._raw_clock = clock or time.perf_counter
        self._base = self._raw_clock()
        self._clock = lambda: self._raw_clock() - self._base

        # decode-path selection: the paged kernel needs gqa-style k/v leaves
        # (MLA's latent cache has no head axis to walk), and deep MegaScope
        # probing wants the vmapped per-slot capture semantics of the oracle
        paged_ok = not cfg.use_mla
        path = serve_cfg.decode_path
        if path == "auto":
            # speculative verification exists only on the paged path, so a
            # spec_decode request overrides the collector's gathered bias
            if serve_cfg.spec_decode:
                path = "paged"
            else:
                path = "paged" if paged_ok and not self._capture else "gathered"
        elif path not in ("paged", "gathered"):
            raise ValueError(f"unknown decode_path {serve_cfg.decode_path!r}")
        if path == "paged" and not paged_ok:
            raise ValueError(f"{cfg.name}: decode_path='paged' unsupported (MLA)")
        if serve_cfg.spec_decode and path != "paged":
            raise ValueError(
                "spec_decode requires the paged decode path "
                f"(got decode_path={serve_cfg.decode_path!r})"
            )
        self.decode_path = path

        self.kv = PagedKVCache(
            cfg,
            PoolSpec(
                num_slots=serve_cfg.num_slots,
                num_blocks=serve_cfg.num_blocks,
                block_size=serve_cfg.block_size,
                max_blocks=serve_cfg.max_blocks_per_slot,
            ),
            # XLA CPU cannot alias bf16 scatters: the paged path's in-place
            # pool writes would silently degrade to full-pool copies
            promote_store=(
                path == "paged" and jax.default_backend() == "cpu"
            ),
        )
        # take ownership of the pool buffers; keeping them referenced from
        # self.kv too would pin a second full KV pool in device memory
        self.pool, self.kv.pool = self.kv.pool, None

        if path == "paged":
            paged_step = make_paged_decode_step(
                cfg, collector, block_size=serve_cfg.block_size,
                paged_flags=self.kv.paged, impl=serve_cfg.paged_attn_impl,
            )

            def decode_fn(params, pool, tables, tokens, pos):
                pool, logits, caps = paged_step(params, pool, tables, tokens, pos)
                return pool, jnp.argmax(logits, -1), caps
        else:
            slot_step = make_slot_decode_step(cfg, collector)

            def decode_fn(params, pool, tables, tokens, pos):
                dense = self.kv.gather(pool, tables)
                new_dense, logits, caps = slot_step(params, dense, tokens, pos)
                pool = self.kv.scatter_decode(pool, new_dense, tables, pos)
                return pool, jnp.argmax(logits, -1), caps

        # donate the pool: it is the largest buffer in the program and every
        # step rewrites it, so double-buffering it would waste a full KV pool.
        # The unwrapped jit is kept separate so precompile() can .lower() it
        # (wrap_step decorators do not preserve the AOT surface).
        self._decode_jit = (
            jax.jit(decode_fn, donate_argnums=(1,)) if use_jit else decode_fn
        )
        self._decode = self._wrap(self._decode_jit)

        # speculative decoding: draft proposer + batched verification step.
        # Recurrent slot-state (rwkv / griffin rec blocks) integrates every
        # token into an O(1) state that cannot be rewound to the accepted
        # prefix, so speculation is limited to attention-only cache families.
        self._spec_step = self._spec_jit = None
        self.drafter = drafter
        if serve_cfg.spec_decode:
            leaves = jax.tree.leaves(self.kv.paged)
            if not (leaves and all(leaves)):
                raise ValueError(
                    f"{cfg.name}: spec_decode needs an attention-only KV "
                    "cache (recurrent slot-state cannot roll back rejected "
                    "drafts)"
                )
            if serve_cfg.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {serve_cfg.spec_k}")
            if self.drafter is None:
                self.drafter = NGramDrafter(
                    max_ngram=serve_cfg.spec_ngram_max,
                    min_ngram=serve_cfg.spec_ngram_min,
                )
            spec_fn = make_spec_verify_step(
                cfg, collector, block_size=serve_cfg.block_size,
                paged_flags=self.kv.paged, impl=serve_cfg.paged_attn_impl,
            )
            self._spec_jit = (
                jax.jit(spec_fn, donate_argnums=(1,)) if use_jit else spec_fn
            )
            self._spec_step = self._wrap(self._spec_jit)

        self._slot_prefill = make_slot_prefill(cfg, collector)
        self._prefill_cache: dict[int, Callable] = {}
        self._use_jit = use_jit
        # right-pad prompts to power-of-two block buckets when every cache
        # leaf is attention-paged (causal masking keeps pad positions
        # invisible); recurrent-state families integrate every position, so
        # they compile per exact prompt length instead
        leaves = jax.tree.leaves(self.kv.paged)
        self._pad_prefill = bool(leaves) and all(leaves)

        # prefill-path selection: the flash kernel streams the whole padded
        # prompt straight into the slot's pool blocks (banded causal
        # attention, no dense-cache materialization or scatter copy), which
        # needs the paged decode path's pool/table plumbing and an
        # attention-only cache family
        flash_ok = path == "paged" and self._pad_prefill
        ppath = serve_cfg.prefill_path
        if ppath == "auto":
            # the flash kernel is a Pallas/TPU program; off-TPU the dispatch
            # falls back to the banded jnp oracle, which is a correctness
            # harness, not a win — one-shot dense prefill beats it there.
            # "auto" therefore only picks flash where the kernel is real (or
            # explicitly requested via paged_attn_impl); forcing
            # prefill_path="flash" still works everywhere for parity tests.
            impl = serve_cfg.paged_attn_impl
            kernel_real = impl in ("pallas", "pallas_interpret") or (
                impl == "auto" and jax.default_backend() == "tpu")
            ppath = "flash" if (flash_ok and kernel_real) else "dense"
        elif ppath not in ("flash", "dense"):
            raise ValueError(
                f"unknown prefill_path {serve_cfg.prefill_path!r}")
        if ppath == "flash" and not flash_ok:
            raise ValueError(
                f"{cfg.name}: prefill_path='flash' needs the paged decode "
                "path and an attention-only KV cache (got "
                f"decode_path={path!r})"
            )
        self.prefill_path = ppath
        self._flash_fn = None
        if ppath == "flash":
            self._flash_fn = make_flash_prefill_step(
                cfg, collector, block_size=serve_cfg.block_size,
                paged_flags=self.kv.paged, impl=serve_cfg.paged_attn_impl,
            )

        # recurrent-state families (rwkv / griffin) prefill through the
        # binary segment driver: exact pow2-width segments integrate into a
        # block-bucketed dense cache, bounding the compile set at
        # O(log^2 max_len) instead of one executable per exact prompt
        # length.  A live collector keeps the one-shot exact path so probe
        # captures still cover the whole prompt in a single forward.
        self._seg_ok = not self._pad_prefill and not self._capture
        self._seg_jit = self._seg_step = self._seg_finish = None
        if self._seg_ok:
            seg_fn = make_seg_prefill(cfg, collector)

            def seg_finish(cache, logits, pool, slot, phys):
                pool = self.kv.scatter_prefill(pool, cache, slot, phys)
                return pool, jnp.argmax(logits, -1)

            self._seg_jit = (
                jax.jit(seg_fn, donate_argnums=(1,)) if use_jit else seg_fn
            )
            self._seg_step = self._wrap(self._seg_jit)
            # donate only the pool: the dense cache's [n, 1, ...] leaves
            # cannot alias the pool's [n, slots|blocks, ...] outputs anyway
            self._seg_finish = (
                jax.jit(seg_finish, donate_argnums=(2,))
                if use_jit else seg_finish
            )

        # chunked prefill: prompts longer than chunk_len stream block-aligned
        # chunks through the q_len>1 paged path, one chunk per tick, so
        # decode ticks for other slots interleave between them
        self._chunking: dict[int, dict] = {}
        self._chunk_step = self._chunk_jit = None
        if serve_cfg.chunked_prefill:
            if path != "paged" or not self._pad_prefill:
                raise ValueError(
                    f"{cfg.name}: chunked_prefill needs the paged decode path "
                    "and an attention-only KV cache (recurrent slot-state "
                    "must integrate every position in one pass); got "
                    f"decode_path={path!r}"
                )
            chunk_fn = make_chunk_prefill_step(
                cfg, collector, block_size=serve_cfg.block_size,
                paged_flags=self.kv.paged, impl=serve_cfg.paged_attn_impl,
            )

            def chunk_step(params, pool, tables, tokens, pos, n_last):
                pool, logits, caps = chunk_fn(
                    params, pool, tables, tokens, pos, n_last
                )
                return pool, jnp.argmax(logits, -1), caps

            self._chunk_jit = (
                jax.jit(chunk_step, donate_argnums=(1,))
                if use_jit else chunk_step
            )
            self._chunk_step = self._wrap(self._chunk_jit)

        # slot migration (disaggregated prefill -> decode hand-off): pure
        # gather/scatter over the pool, retraced per pow2 block-bucket width.
        # Export reads the pool (no donation); import rewrites it (donated).
        self._export_step = (
            jax.jit(self.kv.export_slot) if use_jit else self.kv.export_slot
        )
        self._import_step = (
            jax.jit(self.kv.import_slot, donate_argnums=(0,))
            if use_jit else self.kv.import_slot
        )

    @classmethod
    def from_session(cls, session, params: Any, serve_cfg: ServeConfig, **kw):
        """Construct a server wired to a ``repro.app.Session``: the session's
        MegaScan tracer and MegaScope collector (claimed by whichever module
        plugins are enabled) become this server's, and every jitted engine
        step runs through the plugins' ``wrap_step`` chain — so serving
        emits through the same observability spine as every workload."""
        kw.setdefault("registry", getattr(session, "metrics_registry", None))
        kw.setdefault("compile_cache", getattr(session, "compile_cache", None))
        return cls(
            session.model_cfg, params, serve_cfg,
            collector=session.collector, tracer=session.tracer,
            wrap_step=session.wrap_step, **kw,
        )

    # -------------------------------------------------------------- intake
    def submit(
        self,
        prompt: list[int],
        max_new: int,
        *,
        arrival: float | None = None,
        eos_id: int | None = None,
        rid: int | None = None,
    ) -> int:
        """Queue a prompt; returns its rid.  ``rid`` lets a router supply
        globally-unique ids across replicas (local auto-ids stay ahead)."""
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        else:
            self._next_rid = max(self._next_rid, rid + 1)
        req = Request(
            rid=rid, prompt=list(prompt), max_new=max_new,
            arrival=self._clock() if arrival is None else arrival,
            eos_id=eos_id,
            draft_len=self.serve_cfg.spec_k if self._spec_step else 0,
        )
        self.sched.submit(req)
        self.streams[rid] = []
        return rid

    # ------------------------------------------------------------ prefill
    def _prefill_blocks(self, n_tokens: int) -> int:
        """Block count the prefill executable for ``n_tokens`` covers: a
        power-of-two bucket (capped at the table width) for every family —
        bounding the compile cache at O(log max_len) entries even under
        preemption-recompute prompts of arbitrary length.  The one exception
        is a state family under a live MegaScope collector, which keeps the
        exact count (its one-shot exact prefill carries the whole-prompt
        capture semantics)."""
        n_blk = blocks_for(n_tokens, self.serve_cfg.block_size)
        if not self._pad_prefill and not self._seg_ok:
            return n_blk
        return min(pow2_bucket(n_blk), self.serve_cfg.max_blocks_per_slot)

    def _build_prefill_jit(self, n_blk: int) -> Callable:
        """The one-shot prefill jit for a ``n_blk``-block bucket (flash or
        dense), unwrapped so precompile() can ``.lower()`` it.  Signature:
        ``(params, tokens [1,P], n_real, pool, slot, phys [n_blk])``."""
        bs = self.serve_cfg.block_size
        cache_len = n_blk * bs

        if self._flash_fn is not None:
            flash = self._flash_fn

            def prefill_fn(params, tokens, n_real, pool, slot, phys):
                # the padded block list *is* the slot's table row: the
                # kernel writes K/V straight into those pool blocks
                pool, logits, caps = flash(
                    params, pool, phys[None, :], tokens, n_real
                )
                return pool, jnp.argmax(logits, -1), caps
        else:

            def prefill_fn(params, tokens, n_real, pool, slot, phys):
                filled, logits, caps = self._slot_prefill(
                    params, tokens, n_real, cache_len
                )
                pool = self.kv.scatter_prefill(pool, filled, slot, phys)
                return pool, jnp.argmax(logits, -1), caps

        return (
            jax.jit(prefill_fn, donate_argnums=(3,))
            if self._use_jit else prefill_fn
        )

    def _make_seg_driver(self, n_tokens: int) -> Callable:
        """Prefill driver for recurrent-state families: runs the descending
        binary decomposition of ``n_tokens`` as exact pow2 segments through
        one shared jitted segment step (shape-keyed on (width, cache_len)),
        then scatters the filled dense cache into the slot's pool blocks.
        Matches the one-shot prefill signature so ``step()`` is agnostic."""
        from repro.models import lm

        bs = self.serve_cfg.block_size
        n_blk = self._prefill_blocks(n_tokens)
        cache_len = n_blk * bs
        segs = pow2_segments(n_tokens)

        def driver(params, tokens, n_real, pool, slot, phys):
            cache = lm.init_cache(self.cfg, 1, cache_len)
            off, logits, caps = 0, None, {}
            for w in segs:
                exe = self._aot_exec.get(("seg", w, cache_len), self._seg_step)
                cache, logits, caps = exe(
                    params, cache, tokens[:, off:off + w], jnp.int32(off)
                )
                off += w
            fin = self._aot_exec.get(("seg_fin", n_blk), self._seg_finish)
            pool, tok = fin(cache, logits, pool, slot, phys)
            return pool, tok, caps

        return driver

    def _prefill_for(self, n_tokens: int) -> Callable:
        n_blk = self._prefill_blocks(n_tokens)
        key = n_blk if self._pad_prefill else n_tokens
        fn = self._prefill_cache.get(key)
        if fn is not None:
            return fn
        if self._seg_ok:
            fn = self._make_seg_driver(n_tokens)
        else:
            fn = self._wrap(self._build_prefill_jit(n_blk))
        self._prefill_cache[key] = fn
        return fn

    def _m(self, name: str) -> str:
        return self._mpfx + name

    # --------------------------------------------------------------- step
    def step(self) -> dict:
        """One scheduler tick; returns what happened for observability."""
        now = self._clock()
        admitted, tokens_out = [], 0
        chunk_min = (
            self.serve_cfg.resolved_chunk_len
            if self._chunk_step is not None else None
        )

        for adm in self.sched.admit(now):
            if self.registry is not None and not adm.is_recompute:
                wait = self.sched.requests[adm.rid].queue_wait
                if wait is not None:
                    self.registry.histogram(
                        self._m("queue_wait_s")).observe(wait)
            n_real = len(adm.tokens)
            if chunk_min is not None and n_real > chunk_min:
                # long prompt: don't stall this tick on a monolithic prefill
                # — stream it chunk-by-chunk (first chunk runs just below),
                # with decode ticks interleaving until the last chunk lands
                self._chunking[adm.slot] = {
                    "rid": adm.rid, "toks": list(adm.tokens),
                    "written": 0, "t0": now,
                }
                admitted.append(adm.rid)
                continue
            fn = self._prefill_for(n_real)
            toks, phys = list(adm.tokens), list(adm.phys)
            n_blk = self._prefill_blocks(n_real)
            if self._pad_prefill:
                # right-pad tokens to the bucketed cache length and the block
                # list to the bucket width with null-block entries (their
                # garbage K/V land in block 0, which every read masks out)
                toks += [0] * (n_blk * self.serve_cfg.block_size - n_real)
                phys += [0] * (n_blk - len(phys))
            elif self._seg_ok:
                # segment driver: tokens stay exact (recurrent state must
                # integrate every real position, none invented), but the
                # block list pads to the bucketed scatter width
                phys += [0] * (n_blk - len(phys))
            tokens = jnp.asarray(toks, jnp.int32)[None, :]
            t_pre = self._clock()
            with self.tracer.scope(
                "prefill", kind="compute", rid=adm.rid, slot=adm.slot,
                tokens=n_real, recompute=adm.is_recompute,
                step=self.step_idx,
            ):
                self.pool, tok, caps = fn(
                    self.params, tokens, jnp.int32(n_real), self.pool,
                    jnp.int32(adm.slot), jnp.asarray(phys, jnp.int32),
                )
                tok = jax.block_until_ready(tok)
            now = self._clock()
            self._emit(adm.slot, int(tok), caps, slot_axis=False)
            self.sched.record_token(adm.slot, int(tok), now)
            if self.registry is not None:
                self.registry.histogram(self._m("prefill_s")).observe(now - t_pre)
                if not adm.is_recompute:  # recomputes kept their first TTFT
                    ttft = self.sched.requests[adm.rid].ttft
                    if ttft is not None:
                        self.registry.histogram(self._m("ttft_s")).observe(ttft)
            admitted.append(adm.rid)
            tokens_out += 1

        # one chunk per chunking slot per tick; a completed last chunk
        # emits that request's first token
        if self._chunking:
            tokens_out += self._chunk_tick()
            now = self._clock()

        # a prefill token can complete a request (max_new=1, or eos emitted
        # right away): evict before decode or the slot runs one step past
        # its budget and buries the eos
        finished = self.sched.evict_finished(now)

        preempted: list[int] = []
        active = self.sched.active_slots()
        # mid-chunking slots hold blocks but cannot decode yet: their ride
        # through the batched step would be wasted work, so they are excluded
        # from drafting/decoding (their garbage write at pos lands where the
        # first real decode write overwrites it before it could become live)
        runnable = [s for s in active if s not in self._chunking]
        if not self.prefill_only:
            # speculative drafts are gathered before capacity planning: a
            # slot about to verify k drafts needs 1 + k write positions
            drafts: dict[int, list[int]] = {}
            if self._spec_step is not None and runnable:
                drafts = self._collect_drafts()
            preempted = self.sched.ensure_capacity(
                {s: 1 + len(d) for s, d in drafts.items()} if drafts else None
            )
            active = self.sched.active_slots()
            runnable = [s for s in active if s not in self._chunking]
            drafts = {s: d for s, d in drafts.items() if s in set(runnable)}
            if runnable:
                if drafts:
                    tokens_out += self._spec_tick(runnable, drafts)
                else:
                    tokens_out += self._decode_tick(runnable)
                now = self._clock()

        finished += self.sched.evict_finished(now)
        if admitted or active:
            self.step_idx += 1  # idle ticks don't count as engine steps
        # preempted alone still publishes: ensure_capacity can evict every
        # slot (pool too tight for even one), and that count must not vanish
        if self.registry is not None and (admitted or active or preempted):
            self._publish_tick(active, preempted, tokens_out)
        return {
            "admitted": admitted,
            "preempted": preempted,
            "finished": finished,
            "active": len(active),
            "tokens": tokens_out,
        }

    def _chunk_tick(self) -> int:
        """Advance every mid-chunking slot by one prompt chunk; returns the
        number of first tokens emitted (chunking runs that finished).  A slot
        whose rid no longer matches was preempted mid-chunking — its entry is
        dropped and the re-admission restarts chunking from scratch, so
        greedy streams stay token-identical under preemption."""
        scfg = self.serve_cfg
        C, bs = scfg.resolved_chunk_len, scfg.block_size
        out = 0
        for slot in sorted(self._chunking):
            st = self._chunking[slot]
            if self.sched.slots[slot] != st["rid"]:
                del self._chunking[slot]
                continue
            toks, w = st["toks"], st["written"]
            n_real = len(toks)
            chunk = toks[w : w + C]
            final = w + C >= n_real
            n_last = (n_real - 1 - w) if final else (len(chunk) - 1)
            chunk = chunk + [0] * (C - len(chunk))
            # table width: pow2 bucket over the blocks this chunk can touch,
            # so the compile cache stays O(log max_blocks) like live tables
            width = min(
                pow2_bucket(blocks_for(w + C, bs)), scfg.max_blocks_per_slot
            )
            tables = jnp.asarray(self.sched.tables[slot : slot + 1, :width])
            t0 = self._clock()
            with self.tracer.scope(
                "prefill_chunk", kind="compute", rid=st["rid"], slot=slot,
                offset=w, tokens=min(C, n_real - w), step=self.step_idx,
            ):
                fn = self._aot_exec.get(("chunk", width), self._chunk_step)
                self.pool, tok, caps = fn(
                    self.params, self.pool, tables,
                    jnp.asarray(chunk, jnp.int32)[None, :],
                    jnp.asarray([w], jnp.int32), jnp.int32(n_last),
                )
                tok = jax.block_until_ready(tok)
            now = self._clock()
            if self.registry is not None:
                self.registry.histogram(self._m("chunk_s")).observe(now - t0)
            st["written"] = w + C
            if not final:
                continue
            del self._chunking[slot]
            self._emit(slot, int(tok), caps, slot_axis=False)
            self.sched.record_token(slot, int(tok), now)
            req = self.sched.requests[st["rid"]]
            if self.registry is not None:
                self.registry.histogram(
                    self._m("prefill_s")).observe(now - st["t0"])
                if req.n_preemptions == 0 and req.ttft is not None:
                    self.registry.histogram(self._m("ttft_s")).observe(req.ttft)
            out += 1
        return out

    def _publish_tick(
        self, active: list[int], preempted: list[int], tokens_out: int
    ) -> None:
        """Per-tick serve series into the registry (host bookkeeping only)."""
        reg, alloc = self.registry, self.sched.allocator
        reg.counter(self._m("tokens")).inc(tokens_out)
        if preempted:
            reg.counter(self._m("preemptions")).inc(len(preempted))
        reg.gauge(self._m("queue_depth")).set(len(self.sched.waiting))
        reg.gauge(self._m("active_slots")).set(len(active))
        used = alloc.num_blocks - alloc.reserved - alloc.num_free
        reg.gauge(self._m("kv_occupancy")).set(
            used / max(self.serve_cfg.usable_blocks, 1)
        )

    def _live_tables(self, active: list[int]) -> jax.Array:
        """Block tables for the decode/verify step.  On the paged path they
        are sliced to the live-block high-water mark (next power of two): the
        kernel's sweep — and the XLA fallback's gather — then cost O(max live
        kv_len), not O(pool max_len); bucketing keeps the compile cache at
        O(log max_blocks)."""
        if self.decode_path != "paged":
            return jnp.asarray(self.sched.tables)
        live = max((len(self.sched.blocks[s]) for s in active), default=1)
        hb = min(pow2_bucket(live), self.serve_cfg.max_blocks_per_slot)
        return jnp.asarray(self.sched.tables[:, :hb])

    def _decode_tick(self, active: list[int]) -> int:
        """One plain fused decode step over every active slot (1 token each)."""
        toks = jnp.asarray(self.sched.last_tok, jnp.int32)
        pos = jnp.asarray(self.sched.pos, jnp.int32)
        tables = self._live_tables(active)
        t_dec = self._clock()
        with self.tracer.scope(
            "decode", kind="compute", step=self.step_idx,
            active=len(active), tokens=len(active),
        ):
            fn = self._aot_exec.get(("decode", tables.shape[1]), self._decode)
            self.pool, next_tok, caps = fn(
                self.params, self.pool, tables, toks, pos
            )
            next_tok = jax.block_until_ready(next_tok)
        now = self._clock()
        if self.registry is not None:
            self.registry.histogram(self._m("decode_step_s")).observe(now - t_dec)
        next_tok = np.asarray(next_tok)
        for s in active:
            self.sched.advance(s)
            self._emit(s, int(next_tok[s]), caps,
                       slot_axis=(self.decode_path == "gathered"))
            self.sched.record_token(s, int(next_tok[s]), now)
        return len(active)

    # --------------------------------------------------------- speculation
    def _collect_drafts(self) -> dict[int, list[int]]:
        """Ask the drafter for proposals, one per active slot.

        Each request's draft budget is its adapted ``draft_len`` capped so
        the verify writes stay inside the slot's table reach and the
        request's remaining token budget (drafting past either is pure
        waste).  Requests whose budget has adapted to 0 re-probe with a
        1-token draft every ``spec_retry`` steps."""
        t0 = self._clock()
        drafts: dict[int, list[int]] = {}
        proposed = 0
        for s in self.sched.active_slots():
            if s in self._chunking:   # no committed tokens to draft from yet
                continue
            req = self.sched.requests[self.sched.slots[s]]
            if req.draft_len == 0:
                # exponential re-probe backoff: a request that keeps failing
                # its probes gets probed less and less often, so a hostile
                # workload converges to plain decode throughput
                req.spec_idle += 1
                if req.spec_idle >= self.serve_cfg.spec_retry * req.spec_backoff:
                    req.spec_idle = 0
                    req.draft_len = 1
                continue
            k = min(
                req.draft_len,
                self.serve_cfg.spec_k,
                req.remaining - 1,
                self.serve_cfg.max_len - self.sched.pos[s] - 1,
            )
            if k <= 0:
                continue
            # clamp: the Drafter protocol is a user plug point, and a
            # proposal longer than k would overflow the verify row / the
            # slot's grown table reach
            d = list(self.drafter.propose(req.prompt + req.generated, k))[:k]
            if d:
                drafts[s] = d
                proposed += len(d)
        self.tracer.record(
            "draft", t0, self._clock() - t0, kind="host",
            step=self.step_idx, proposed=proposed, slots=len(drafts),
        )
        return drafts

    def _spec_tick(self, active: list[int], drafts: dict[int, list[int]]) -> int:
        """One batched draft-verification step.

        Every active slot rides the same ``Q = spec_k + 1``-token forward:
        row 0 is its last committed token, rows 1..k its draft, the rest
        padding (causally invisible to the rows that matter).  Greedy
        acceptance (``sampler.greedy_verify``) commits the agreeing prefix
        plus one correction/bonus token per slot — between 1 and ``k + 1``
        tokens — then the block tables are rewound past the committed
        high-water mark (``Scheduler.trim_blocks``)."""
        scfg = self.serve_cfg
        Q = scfg.spec_k + 1
        toks = np.zeros((scfg.num_slots, Q), np.int32)
        for s in active:
            row = [self.sched.last_tok[s]] + drafts.get(s, [])
            toks[s, : len(row)] = row
        pos = jnp.asarray(self.sched.pos, jnp.int32)
        tables = self._live_tables(active)
        v0 = self._clock()
        fn = self._aot_exec.get(("verify", tables.shape[1]), self._spec_step)
        self.pool, greedy, _logits, caps = fn(
            self.params, self.pool, tables, jnp.asarray(toks), pos
        )
        greedy = np.asarray(jax.block_until_ready(greedy))
        now = self._clock()
        v_dur = now - v0
        t0 = now
        emitted_total = accepted_total = 0
        for s in active:
            d = drafts.get(s, [])
            n_acc, emitted = greedy_verify(greedy[s], d)
            req = self.sched.requests[self.sched.slots[s]]
            if d:
                req.spec_proposed += len(d)
                req.spec_accepted += n_acc
                accepted_total += n_acc
                # acceptance-rate adaptation: the verify forward costs the
                # same whatever the draft length (Q is padded), so any
                # acceptance at all restores the full budget.  An *isolated*
                # miss is the signature of a continuation shift — the next
                # lookup either proposes the new pattern or nothing at all —
                # so only consecutive zero-acceptance verifies (a drafter
                # that is systematically wrong) shut speculation off for
                # this request, with exponentially backed-off re-probes:
                # each wasted verify is a plain decode step at multi-token
                # price, so a hostile workload must degrade to plain decode
                if n_acc > 0:
                    req.draft_len = scfg.spec_k
                    req.spec_miss = 0
                    req.spec_backoff = 1
                else:
                    req.spec_miss += 1
                    if req.spec_miss >= 3:
                        req.draft_len = 0
                        req.spec_backoff = min(req.spec_backoff * 2, 16)
            n_commit = 0
            for t in emitted[: req.remaining]:
                n_commit += 1
                self._emit(s, int(t), caps, slot_axis=False)
                self.sched.record_token(s, int(t), now)
                if req.eos_id is not None and int(t) == req.eos_id:
                    break
            self.sched.advance(s, n_commit)
            emitted_total += n_commit
        self.sched.trim_blocks()
        # the verify event is recorded after acceptance so it can carry the
        # realized token count (the scope context manager freezes args at
        # entry); ts/dur still bracket exactly the jitted verification
        self.tracer.record(
            "verify", v0, v_dur, kind="compute", step=self.step_idx,
            active=len(active), tokens=emitted_total,
            drafted=sum(len(d) for d in drafts.values()),
        )
        self.tracer.record(
            "accept", t0, self._clock() - t0, kind="host",
            step=self.step_idx, accepted=accepted_total, emitted=emitted_total,
        )
        if self.registry is not None:
            reg = self.registry
            reg.histogram(self._m("verify_step_s")).observe(v_dur)
            drafted = sum(len(d) for d in drafts.values())
            if drafted:
                reg.counter(self._m("spec_proposed")).inc(drafted)
                reg.counter(self._m("spec_accepted")).inc(accepted_total)
                reg.gauge(self._m("spec_accept_rate")).set(
                    reg.counter(self._m("spec_accepted")).value
                    / reg.counter(self._m("spec_proposed")).value
                )
        return emitted_total

    def _emit(self, slot: int, tok: int, caps: Any, *, slot_axis: bool) -> None:
        rid = self.sched.slots[slot]
        captures = {}
        if self._capture and caps:
            # slot_axis is only set on the gathered path, where vmap stacks
            # *every* capture leaf over the slot axis, so slicing is exact.
            # The batched paged step offers no such guarantee (probe
            # reductions may collapse the axis entirely), so its captures
            # attach whole — deep per-slot probing should use
            # decode_path="gathered" (what "auto" picks under a collector).
            take = (lambda a: np.asarray(a[slot])) if slot_axis else np.asarray
            captures = jax.tree.map(take, caps)
        self.streams[rid].append(StreamItem(self.step_idx, tok, captures))

    # ---------------------------------------------------------- migration
    def exportable(self) -> list[int]:
        """Rids whose prefill has completed here but whose decode has not
        begun — on a prefill-only replica these are ready for hand-off (a
        colocated replica never exports; it decodes its own prefills)."""
        if not self.prefill_only:
            return []
        out = []
        for s in self.sched.active_slots():
            if s in self._chunking:
                continue
            req = self.sched.requests[self.sched.slots[s]]
            if req.t_first_token is not None and not req.done:
                out.append(req.rid)
        return out

    def export_request(self, rid: int) -> dict:
        """Pull a prefilled request out of this replica: its KV blocks leave
        the pool as an ``export_slot`` bundle (padded to the pow2 block
        bucket with null-block entries), its slot/blocks are freed, and the
        ``Request`` object + token stream ride the package so timing fields
        and emitted tokens survive the migration."""
        slot = next(
            (s for s, r in enumerate(self.sched.slots) if r == rid), None)
        if slot is None:
            raise ValueError(f"rid {rid} not active (cannot export)")
        req = self.sched.requests[rid]
        phys = list(self.sched.blocks[slot])
        pos = self.sched.pos[slot]
        last_tok = self.sched.last_tok[slot]
        width = min(
            pow2_bucket(max(len(phys), 1)), self.serve_cfg.max_blocks_per_slot
        )
        padded = phys + [0] * (width - len(phys))
        with self.tracer.scope(
            "kv_export", kind="comm", rid=rid, slot=slot, blocks=len(phys),
            step=self.step_idx,
        ):
            bundle = self._export_step(
                self.pool, jnp.asarray(padded, jnp.int32), jnp.int32(slot)
            )
            bundle = jax.block_until_ready(bundle)
        stream = self.streams.pop(rid)
        self.sched.release_request(rid)
        return {
            "req": req, "stream": stream, "bundle": bundle,
            "n_blocks": len(phys), "width": width,
            "pos": pos, "last_tok": last_tok,
        }

    def adopt_request(self, package: dict) -> bool:
        """Install an ``export_request`` package into this replica: claim a
        slot + blocks, scatter the bundle's KV into them, and resume decode
        from the migrated cursor.  Returns False (package untouched) when no
        slot/blocks are free — the router retries next tick.  Bit-identical
        KV import means the greedy continuation is token-identical to the
        colocated engine's."""
        req = package["req"]
        got = self.sched.adopt(req, package["pos"], package["last_tok"])
        if got is None:
            return False
        slot, phys = got
        padded = phys + [0] * (package["width"] - len(phys))
        with self.tracer.scope(
            "kv_import", kind="comm", rid=req.rid, slot=slot,
            blocks=package["n_blocks"], step=self.step_idx,
        ):
            self.pool = self._import_step(
                self.pool, package["bundle"],
                jnp.asarray(padded, jnp.int32), jnp.int32(slot),
            )
        self.streams[req.rid] = package["stream"]
        self._next_rid = max(self._next_rid, req.rid + 1)
        return True

    # --------------------------------------------------------- precompile
    def _avatar(self, tree: Any) -> Any:
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
        )

    def _width_ladder(self, hi: int) -> list[int]:
        """The pow2 bucket ladder 1, 2, 4, ... capped at ``hi`` — exactly
        the static widths the tick paths can request."""
        ws, w = [], 1
        while True:
            ws.append(w)
            if w >= hi:
                return ws
            w = min(2 * w, hi)

    def _aot(
        self, jitted: Any, avatars: tuple, *, kind: str,
        donate: tuple, extra: dict,
    ) -> tuple[Callable, bool]:
        """AOT-compile one bucketed step variant through the persistent
        compile cache (or plain lower+compile when none is attached)."""
        params_sig = [
            f"{l.shape}/{l.dtype}" for l in jax.tree.leaves(self._avatar(self.params))
        ]
        return aot_compile(
            jitted, avatars, cache=self.compile_cache,
            key_parts={
                "model": self.cfg,
                "serve": self.serve_cfg,
                "mesh": mesh_descriptor(None),
                "capture": self._capture,
                "params": params_sig,
                "step": kind,
                "donate": list(donate),
                **extra,
            },
        )

    def precompile(self) -> dict:
        """Ahead-of-time compile every bucketed step variant before serving.

        Each engine step retraces per pow2 static-shape bucket (decode /
        verify table widths, chunk widths, prefill prompt buckets, recurrent
        segment widths x cache buckets), and which bucket occurs first is
        timing-dependent — a shape reached mid-run pays its XLA compile
        inside the serving loop (hundreds of ms), exactly the jitter a
        latency SLO or cold-start benchmark cannot absorb.  This walks every
        ladder through ``jit(...).lower().compile()`` (consulting the
        persistent ``compile_cache`` when attached, so a restarted process
        deserializes instead of recompiling) and parks the executables where
        the tick paths dispatch first (``_aot_exec`` / ``_prefill_cache``).

        Returns per-path counts and wall-clock milliseconds::

            {"decode": {"count", "ms"}, "prefill": {...}, "chunk": {...},
             "verify": {...}, "total": n[, "cache": CacheStats dict]}

        and publishes each path's ms as a ``precompile_ms.<path>`` gauge.
        """
        out: dict[str, Any] = {
            p: {"count": 0, "ms": 0.0}
            for p in ("decode", "prefill", "chunk", "verify")
        }
        out["total"] = 0
        if not self._use_jit:
            return out
        scfg = self.serve_cfg
        n_slots, max_w, bs = scfg.num_slots, scfg.max_blocks_per_slot, scfg.block_size
        i32 = jnp.int32
        pa = self._avatar(self.params)
        pool_a = self._avatar(self.pool)
        scalar = jax.ShapeDtypeStruct((), i32)

        def run(path, jitted, avatars, *, donate, **extra):
            t0 = self._raw_clock()
            exe, _hit = self._aot(
                jitted, avatars, kind=path, donate=donate, extra=extra
            )
            out[path]["count"] += 1
            out[path]["ms"] += (self._raw_clock() - t0) * 1e3
            return exe

        # ---- decode: one executable per live-table width bucket
        widths = (
            self._width_ladder(max_w)
            if self.decode_path == "paged" else [max_w]
        )
        toks_a = jax.ShapeDtypeStruct((n_slots,), i32)
        pos_a = jax.ShapeDtypeStruct((n_slots,), i32)
        for w in widths:
            tb = jax.ShapeDtypeStruct((n_slots, w), i32)
            exe = run("decode", self._decode_jit,
                      (pa, pool_a, tb, toks_a, pos_a),
                      donate=(1,), width=w)
            self._aot_exec[("decode", w)] = self._wrap(exe)

        # ---- speculative verify: same width ladder, Q = spec_k + 1 rows
        if self._spec_jit is not None:
            vt = jax.ShapeDtypeStruct((n_slots, scfg.spec_k + 1), i32)
            for w in widths:
                tb = jax.ShapeDtypeStruct((n_slots, w), i32)
                exe = run("verify", self._spec_jit,
                          (pa, pool_a, tb, vt, pos_a),
                          donate=(1,), width=w)
                self._aot_exec[("verify", w)] = self._wrap(exe)

        # ---- chunked prefill: the chunk-tick table widths actually reachable
        if self._chunk_jit is not None:
            C = scfg.resolved_chunk_len
            cws = sorted({
                min(pow2_bucket(blocks_for(off + C, bs)), max_w)
                for off in range(0, scfg.max_len, C)
            })
            ct = jax.ShapeDtypeStruct((1, C), i32)
            cp = jax.ShapeDtypeStruct((1,), i32)
            for w in cws:
                tb = jax.ShapeDtypeStruct((1, w), i32)
                exe = run("chunk", self._chunk_jit,
                          (pa, pool_a, tb, ct, cp, scalar),
                          donate=(1,), width=w)
                self._aot_exec[("chunk", w)] = self._wrap(exe)

        # ---- prefill: prompt block-bucket ladder (padded families) or the
        # pow2 segment-width x cache-bucket grid (recurrent families)
        if self._pad_prefill:
            for n_blk in self._width_ladder(max_w):
                tok_a = jax.ShapeDtypeStruct((1, n_blk * bs), i32)
                phys_a = jax.ShapeDtypeStruct((n_blk,), i32)
                exe = run("prefill", self._build_prefill_jit(n_blk),
                          (pa, tok_a, scalar, pool_a, scalar, phys_a),
                          donate=(3,), n_blk=n_blk,
                          prefill_impl=self.prefill_path)
                self._prefill_cache[n_blk] = self._wrap(exe)
        elif self._seg_ok:
            from repro.models import lm

            for n_blk in self._width_ladder(max_w):
                cache_len = n_blk * bs
                cache_a = jax.eval_shape(
                    lambda L=cache_len: lm.init_cache(self.cfg, 1, L)
                )
                seg_out = None
                for w in self._width_ladder(cache_len):
                    tok_a = jax.ShapeDtypeStruct((1, w), i32)
                    exe = run("prefill", self._seg_jit,
                              (pa, cache_a, tok_a, scalar),
                              donate=(1,), seg_w=w, cache_len=cache_len)
                    self._aot_exec[("seg", w, cache_len)] = self._wrap(exe)
                    if seg_out is None:
                        seg_out = jax.eval_shape(
                            self._seg_jit, pa, cache_a, tok_a, scalar
                        )
                phys_a = jax.ShapeDtypeStruct((n_blk,), i32)
                exe = run("prefill", self._seg_finish,
                          (self._avatar(seg_out[0]),
                           self._avatar(seg_out[1]),
                           pool_a, scalar, phys_a),
                          donate=(2,), fin_blk=n_blk)
                self._aot_exec[("seg_fin", n_blk)] = exe

        out["total"] = sum(
            v["count"] for k, v in out.items() if isinstance(v, dict)
        )
        if self.compile_cache is not None:
            out["cache"] = self.compile_cache.stats.as_dict()
        if self.registry is not None:
            for p in ("decode", "prefill", "chunk", "verify"):
                self.registry.gauge(
                    self._m(f"precompile_ms.{p}")).set(out[p]["ms"])
        return out

    # -------------------------------------------------------------- drain
    def drain(
        self,
        max_steps: int = 100_000,
        *,
        on_step: Callable[[list, dict], None] | None = None,
    ) -> dict[int, list[int]]:
        """Run until every submitted request finishes; returns token streams.

        ``max_steps`` bounds productive engine steps and (separately) idle
        ticks spent waiting for future arrivals; with an injected clock that
        never reaches the next arrival this raises instead of spinning.
        ``on_step(events, report)`` observes each tick — the TraceEvents it
        emitted and the scheduler report — which is how Session plugins
        attach to the serving loop."""
        work = idle = 0
        while not self.sched.all_done:
            n_ev = len(self.tracer.events)
            out = self.step()
            if on_step is not None:
                on_step(self.tracer.events[n_ev:], out)
            if out["admitted"] or out["active"]:
                work += 1
                idle = 0
                if work > max_steps:
                    raise RuntimeError(f"drain: not done after {work} steps")
                continue
            idle += 1
            if idle > max_steps:
                raise RuntimeError(
                    f"drain: stalled waiting for arrival at "
                    f"t={self.sched.next_arrival()} (now={self._clock():.3f})"
                )
            nxt = self.sched.next_arrival()
            if nxt is not None:
                # real sleep is harmless for injected clocks too: either the
                # clock advances elsewhere or the idle guard above fires
                time.sleep(max(0.0, min(nxt - self._clock(), 1e-3)))
        return {rid: [it.token for it in s] for rid, s in self.streams.items()}

    # ------------------------------------------------------------ metrics
    def metrics(self) -> dict:
        """Fleet metrics: tokens/s, TTFT/latency percentiles, preemptions,
        engine steps, and (when speculation is on) draft acceptance."""
        reqs = list(self.sched.requests.values())
        out = {
            **aggregate_metrics(reqs, wall=self._clock()),
            "steps": self.step_idx,
        }
        if self._spec_step is not None:
            proposed = sum(r.spec_proposed for r in reqs)
            accepted = sum(r.spec_accepted for r in reqs)
            out["spec_proposed"] = proposed
            out["spec_accepted"] = accepted
            out["spec_accept_rate"] = accepted / proposed if proposed else 0.0
        return out

    def trace_events(self):
        return self.tracer.events

    def reset(self) -> None:
        """Drop finished requests/streams/traces and restart the clock —
        lets a warmed-up server (compiled steps) time a fresh workload."""
        if not self.sched.all_done:
            raise RuntimeError("reset() with requests still in flight")
        self.sched.requests.clear()
        self.streams.clear()
        self.tracer.clear()
        self._chunking.clear()
        self.step_idx = 0
        self._base = self._raw_clock()


# ---------------------------------------------------------------------------
# Static-batch baseline (the pre-existing lockstep path)
# ---------------------------------------------------------------------------


class StaticRunner:
    """Length-bucketed static batching: requests sharing one prompt length
    batch together in arrival order (the static prefill/decode steps require
    a single prompt length and one shared position), the whole batch decodes
    in lockstep to the slowest member's budget, and a batch only launches
    once its last member has arrived.  Holds its jitted steps so repeat runs
    (benchmark warmup) reuse compilations."""

    def __init__(self, cfg: ModelConfig, params: Any):
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg))

    def run(
        self,
        requests: list[tuple[list[int], int, float]],  # (prompt, max_new, arrival)
        *,
        batch_size: int,
        tracer: Tracer | None = None,
        clock: Callable[[], float] | None = None,
    ) -> tuple[dict[int, list[int]], dict]:
        """Returns (rid -> tokens, metrics); rids index ``requests``."""
        cfg, params = self.cfg, self.params
        tracer = tracer or Tracer(rank=0, enabled=True)
        t0 = time.perf_counter()
        clock = clock or (lambda: time.perf_counter() - t0)
        model, prefill, decode = self.model, self.prefill, self.decode

        reqs = [Request(rid=i, prompt=list(p), max_new=m, arrival=a)
                for i, (p, m, a) in enumerate(requests)]
        buckets: dict[int, list[Request]] = {}
        for r in reqs:
            buckets.setdefault(r.prompt_len, []).append(r)

        outputs: dict[int, list[int]] = {}
        for P in sorted(buckets):
            group = buckets[P]
            for i in range(0, len(group), batch_size):
                members = group[i : i + batch_size]
                B = len(members)
                steps = max(r.max_new for r in members)
                launch = max(r.arrival for r in members)
                stalls = 0
                while clock() < launch:
                    before = clock()
                    time.sleep(min(launch - before, 1e-3))
                    # injected clocks may be simulated: bail out instead of
                    # spinning forever if time never advances (~10 s real)
                    stalls = stalls + 1 if clock() <= before else 0
                    if stalls > 10_000:
                        raise RuntimeError(
                            f"static: stalled waiting for batch launch at "
                            f"t={launch} (now={clock():.3f})"
                        )
                cache = model.init_cache(cfg, B, P + steps)
                prompts = jnp.asarray([r.prompt for r in members], jnp.int32)
                with tracer.scope("prefill", kind="compute", tokens=B * P, batch=B):
                    cache, logits = prefill(params, {"tokens": prompts}, cache)
                    jax.block_until_ready(logits)
                tok = sample(logits, temperature=0.0)
                now = clock()
                for b, r in enumerate(members):
                    r.t_admitted = launch
                    r.t_first_token = now
                    r.generated.append(int(tok[b]))
                    if len(r.generated) == r.max_new:
                        r.t_finished = now
                for s in range(steps - 1):
                    with tracer.scope("decode", kind="compute", step=s, active=B,
                                      tokens=B):
                        cache, logits, tok = decode(params, cache, tok, jnp.int32(P + s))
                        tok = jax.block_until_ready(tok)
                    now = clock()
                    for b, r in enumerate(members):
                        if len(r.generated) < r.max_new:
                            r.generated.append(int(tok[b]))
                            if len(r.generated) == r.max_new:
                                r.t_finished = now
                for r in members:
                    if r.t_finished is None:
                        r.t_finished = now
                    r.status = RequestStatus.FINISHED
                    outputs[r.rid] = list(r.generated)

        metrics = aggregate_metrics(reqs, wall=clock())
        return outputs, metrics

def run_static(
    cfg: ModelConfig,
    params: Any,
    requests: list[tuple[list[int], int, float]],
    *,
    batch_size: int,
    tracer: Tracer | None = None,
    clock: Callable[[], float] | None = None,
) -> tuple[dict[int, list[int]], dict]:
    """One-shot convenience wrapper over ``StaticRunner``."""
    return StaticRunner(cfg, params).run(
        requests, batch_size=batch_size, tracer=tracer, clock=clock
    )


def make_poisson_workload(
    cfg: ModelConfig,
    *,
    n: int,
    rate: float,
    prompt_lens: tuple[int, ...],
    max_new_range: tuple[int, int],
    num_slots: int,
    block_size: int = 16,
    num_blocks: int = 0,
    seed: int = 0,
    traffic: str = "poisson",
):
    """Shared CLI workload builder (launcher + benchmark): arrival specs
    (``traffic`` picks the process — ``poisson`` / ``bursty`` MMPP /
    ``diurnal`` sinusoidal), random token prompts, and a ``ServeConfig``
    sized so the worst request fits one slot — ``num_blocks=0`` sizes the
    pool for zero preemption (every slot can hold its worst case
    simultaneously, plus the reserved null block).  The sizing also covers
    speculative decoding: draft budgets are capped so every real verify
    write stays inside the worst-case footprint (``_collect_drafts``).
    Returns (specs, prompts by rid, serve_cfg)."""
    from repro.core.simkit.workload import (
        bursty_requests,
        diurnal_requests,
        poisson_requests,
    )

    gens = {
        "poisson": poisson_requests,
        "bursty": bursty_requests,
        "diurnal": diurnal_requests,
    }
    if traffic not in gens:
        raise ValueError(
            f"unknown traffic {traffic!r}; one of {sorted(gens)}"
        )
    specs = gens[traffic](
        n, rate, prompt_lens=prompt_lens, max_new_range=max_new_range,
        seed=seed,
    )
    rng = np.random.default_rng(seed)
    prompts = {
        s.rid: rng.integers(2, cfg.vocab_size, size=s.prompt_len).tolist()
        for s in specs
    }
    worst = max(blocks_for(s.prompt_len + s.max_new, block_size) for s in specs)
    serve_cfg = ServeConfig(
        num_slots=num_slots, block_size=block_size,
        num_blocks=num_blocks or (num_slots * worst + 1),
        max_blocks_per_slot=worst,
    )
    return specs, prompts, serve_cfg
