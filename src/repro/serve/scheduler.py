"""Continuous-batching scheduler: admission, eviction, preemption-by-recompute.

Pure host-side bookkeeping (numpy block tables, python free list) deliberately
split from the jax engine: the policy is exercised directly by unit tests and
mirrored by ``core.simkit.workload.serving_workload`` for offline evaluation
on the discrete-event engine.

Invariants:
  * every active slot holds exactly ``ceil(pos / block_size)`` physical
    blocks, except transiently inside ``ensure_capacity`` which grows it to
    cover the next write position;
  * block-table padding entries point at the reserved null block 0;
  * preemption frees *all* of a victim's blocks and requeues it at the head
    of the waiting line with its generated tokens folded into the prompt —
    greedy decode recomputes to the identical continuation.

Policy knobs: admission is FIFO over arrived requests; capacity priority is
oldest-admitted-first; the preemption victim is the youngest-admitted active
slot (LIFO, so the request closest to done keeps running).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serve.paged_cache import BlockAllocator, blocks_for
from repro.serve.request import Request, RequestStatus


@dataclass(frozen=True)
class ServeConfig:
    """Static serving-engine configuration (pool geometry + policy knobs).

    Pool geometry: ``num_slots`` concurrent sequences share ``num_blocks``
    physical KV blocks of ``block_size`` tokens (block 0 is the reserved
    null block); ``max_blocks_per_slot`` is the block-table width, so a
    single sequence can span at most ``max_len = max_blocks_per_slot *
    block_size`` positions.

    Speculative decoding (``spec_decode=True``): each step a host-side
    drafter proposes up to ``spec_k`` continuation tokens per request and
    the target model verifies all slots' drafts in ONE batched
    ``spec_k + 1``-token forward; accepted prefixes commit in place,
    rejected suffixes are rewound (``Scheduler.trim_blocks``).  Greedy
    outputs stay token-identical to non-speculative decoding.  Requires the
    paged decode path and an attention-only cache family (recurrent
    slot-state cannot roll back).  Per-request draft lengths adapt to the
    observed acceptance rate, down to 0 (speculation off for that request,
    re-probed every ``spec_retry`` steps).
    """

    num_slots: int = 4
    block_size: int = 16
    num_blocks: int = 65           # physical blocks incl. the reserved null
    max_blocks_per_slot: int = 16  # block-table width; max_len = this * bs
    max_prefills_per_step: int = 1 # prefill/decode interleaving bound
    # decode engine: "paged" streams KV blocks straight from the pool (no
    # dense gather, in-place block writes); "gathered" is the original
    # gather -> vmap(B=1) -> scatter oracle; "auto" picks paged whenever the
    # family supports it and no MegaScope collector needs per-slot captures
    decode_path: str = "auto"      # auto | paged | gathered
    paged_attn_impl: str = "auto"  # auto | xla | pallas | pallas_interpret
    # prefill engine: "flash" runs the whole (right-padded) prompt through
    # the flash-prefill kernel straight into the slot's pool blocks (banded
    # causal attention, no dense-cache round trip); "dense" is the original
    # dense prefill + scatter_prefill copy; "auto" picks flash whenever the
    # paged decode path and an attention-only cache family make it legal
    prefill_path: str = "auto"     # auto | flash | dense
    # speculative decoding (draft + batched paged verification)
    spec_decode: bool = False      # verify spec_k drafts/slot per step
    spec_k: int = 4                # max draft tokens per request per step
    spec_ngram_max: int = 4        # prompt-lookup drafter: longest suffix
    spec_ngram_min: int = 1        #   n-gram tried, shortest accepted
    spec_retry: int = 16           # steps between draft re-probes at len 0
    # chunked prefill: prompts longer than ``chunk_len`` stream into the
    # pool ``chunk_len`` tokens per tick through the q_len>1 paged kernel
    # path, so decode ticks interleave between chunks instead of stalling
    # behind one monolithic long-prompt prefill.  ``chunk_len=0`` auto-sizes
    # to 2 * block_size; explicit values must be a multiple of ``block_size``
    # (the prefill bucket quantum — chunks must land on block boundaries).
    chunked_prefill: bool = False
    chunk_len: int = 0

    def __post_init__(self) -> None:
        if self.chunk_len < 0:
            raise ValueError(f"chunk_len must be >= 0, got {self.chunk_len}")
        if self.chunk_len and self.chunk_len % self.block_size != 0:
            raise ValueError(
                f"chunk_len={self.chunk_len} must be a multiple of the "
                f"prefill bucket size (block_size={self.block_size})"
            )

    @property
    def resolved_chunk_len(self) -> int:
        return self.chunk_len or 2 * self.block_size

    @property
    def max_len(self) -> int:
        return self.max_blocks_per_slot * self.block_size

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1


@dataclass
class Admission:
    slot: int
    rid: int
    tokens: list[int]              # prompt to prefill (recompute incl.)
    phys: list[int]                # freshly-allocated physical blocks
    is_recompute: bool


class Scheduler:
    """Host-side serving policy: slot assignment, block accounting, and the
    admission / capacity / eviction decisions one ``MegaServe.step()`` tick
    is made of.  Owns the numpy block tables the jitted engine steps read;
    never touches jax itself (unit-testable without a device)."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.allocator = BlockAllocator(cfg.num_blocks, reserved=1)
        self.requests: dict[int, Request] = {}
        self.waiting: list[int] = []
        S, M = cfg.num_slots, cfg.max_blocks_per_slot
        self.slots: list[int | None] = [None] * S
        self.blocks: list[list[int]] = [[] for _ in range(S)]
        self.pos: list[int] = [0] * S
        self.last_tok: list[int] = [0] * S
        self.tables = np.zeros((S, M), np.int32)
        self._admit_seq = [0] * S
        self._seq = 0

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        """Queue a request for admission; rejects requests whose worst-case
        footprint can never fit a slot (prompt + budget vs table width)."""
        worst = blocks_for(req.prompt_len + req.max_new, self.cfg.block_size)
        if worst > min(self.cfg.usable_blocks, self.cfg.max_blocks_per_slot):
            raise ValueError(
                f"request {req.rid}: needs {worst} blocks, pool/slot caps are "
                f"{self.cfg.usable_blocks}/{self.cfg.max_blocks_per_slot}"
            )
        if req.rid in self.requests:
            raise ValueError(f"duplicate rid {req.rid}")
        self.requests[req.rid] = req
        self.waiting.append(req.rid)

    # ---------------------------------------------------------- admission
    def admit(self, now: float) -> list[Admission]:
        """FIFO-admit arrived requests into free slots while blocks last,
        bounded by ``max_prefills_per_step``."""
        out: list[Admission] = []
        while len(out) < self.cfg.max_prefills_per_step:
            slot = next((s for s, r in enumerate(self.slots) if r is None), None)
            if slot is None:
                break
            rid = next(
                (r for r in self.waiting if self.requests[r].arrival <= now), None
            )
            if rid is None:
                break
            req = self.requests[rid]
            tokens = req.recompute_prompt
            phys = self.allocator.try_alloc(blocks_for(len(tokens), self.cfg.block_size))
            if phys is None:
                break
            self.waiting.remove(rid)
            self.slots[slot] = rid
            self.blocks[slot] = list(phys)
            self.pos[slot] = len(tokens)
            self.tables[slot, :] = 0
            self.tables[slot, : len(phys)] = phys
            self._seq += 1
            self._admit_seq[slot] = self._seq
            req.status = RequestStatus.RUNNING
            if req.t_admitted is None:
                req.t_admitted = now
            out.append(Admission(slot, rid, tokens, list(phys),
                                 is_recompute=req.n_preemptions > 0))
        return out

    # -------------------------------------------------- migration intake
    def adopt(self, req: Request, pos: int, last_tok: int) -> tuple[int, list[int]] | None:
        """Take over a request mid-flight (disaggregated prefill hand-off):
        claim a free slot plus enough blocks to cover ``pos`` already-written
        cache positions and register the request as RUNNING — the imported
        KV blocks land where the returned ``phys`` list says.  Returns
        ``(slot, phys)``, or ``None`` when no slot/blocks are free (the
        router retries next tick)."""
        if req.rid in self.requests:
            raise ValueError(f"duplicate rid {req.rid}")
        slot = next((s for s, r in enumerate(self.slots) if r is None), None)
        if slot is None:
            return None
        phys = self.allocator.try_alloc(blocks_for(pos, self.cfg.block_size))
        if phys is None:
            return None
        self.requests[req.rid] = req
        self.slots[slot] = req.rid
        self.blocks[slot] = list(phys)
        self.pos[slot] = pos
        self.last_tok[slot] = last_tok
        self.tables[slot, :] = 0
        self.tables[slot, : len(phys)] = phys
        self._seq += 1
        self._admit_seq[slot] = self._seq
        req.status = RequestStatus.RUNNING
        return slot, list(phys)

    def release_request(self, rid: int) -> None:
        """Drop a request entirely (migrated away): free its slot/blocks and
        forget it — unlike preemption it is NOT requeued here, and unlike
        eviction it is not marked finished (the adopting replica owns its
        lifecycle from now on)."""
        slot = next((s for s, r in enumerate(self.slots) if r == rid), None)
        if slot is None:
            raise ValueError(f"rid {rid} not active (cannot release)")
        self._release(slot)
        del self.requests[rid]

    # ----------------------------------------------------------- capacity
    def ensure_capacity(self, extra: dict[int, int] | None = None) -> list[int]:
        """Grow each active slot's block table to cover its next ``e`` write
        positions (``e = extra.get(slot, 1)``; speculative verification
        writes ``1 + draft_len`` positions at once), preempting
        youngest-admitted slots when the pool runs dry.  Returns the rids
        preempted this call."""
        preempted: list[int] = []
        for slot in sorted(self.active_slots(), key=lambda s: self._admit_seq[s]):
            if self.slots[slot] is None:       # victim of an earlier preempt
                continue
            e = max(extra.get(slot, 1) if extra else 1, 1)
            want = (self.pos[slot] + e - 1) // self.cfg.block_size + 1
            while len(self.blocks[slot]) < want:
                got = self.allocator.try_alloc(1)
                if got is not None:
                    b = got[0]
                    self.tables[slot, len(self.blocks[slot])] = b
                    self.blocks[slot].append(b)
                    continue
                # LIFO victim: the youngest-admitted active slot — possibly
                # the growing slot itself, which then waits its turn back
                # in the queue rather than stealing from an older request
                victims = [
                    s for s in self.active_slots() if self.slots[s] is not None
                ]
                victim = max(victims, key=lambda s: self._admit_seq[s])
                preempted.append(self.preempt(victim))
                if victim == slot:
                    break
        return preempted

    def preempt(self, slot: int) -> int:
        """Evict a running request: free all its blocks and requeue it at the
        head of the waiting line with generated tokens folded into the
        prompt (preemption-by-recompute).  Returns the rid."""
        rid = self.slots[slot]
        assert rid is not None
        req = self.requests[rid]
        req.status = RequestStatus.WAITING
        req.n_preemptions += 1
        self._release(slot)
        self.waiting.insert(0, rid)
        return rid

    # ------------------------------------------------------------- decode
    def active_slots(self) -> list[int]:
        return [s for s, r in enumerate(self.slots) if r is not None]

    def record_token(self, slot: int, tok: int, now: float) -> None:
        """Append one generated token for the request in ``slot``."""
        rid = self.slots[slot]
        assert rid is not None
        req = self.requests[rid]
        req.generated.append(tok)
        if req.t_first_token is None:
            req.t_first_token = now
        self.last_tok[slot] = tok

    def advance(self, slot: int, n: int = 1) -> None:
        """A decode/verify step wrote K/V at ``pos .. pos + n - 1``; move the
        write cursor past the committed prefix."""
        self.pos[slot] += n

    def trim_blocks(self) -> None:
        """Rewind speculative over-allocation: free each active slot's
        blocks past the committed high-water mark ``ceil(pos / block_size)``
        and re-point their table entries at the null block.  Rejected verify
        writes live only in the freed region or beyond ``kv_len``'s mask, so
        the freed blocks carry no live data."""
        for slot in self.active_slots():
            keep = max(blocks_for(self.pos[slot], self.cfg.block_size), 1)
            drop = self.blocks[slot][keep:]
            if drop:
                self.allocator.free(drop)
                del self.blocks[slot][keep:]
                self.tables[slot, keep:] = 0

    def evict_finished(self, now: float) -> list[int]:
        out = []
        for slot in self.active_slots():
            req = self.requests[self.slots[slot]]
            if req.done:
                req.status = RequestStatus.FINISHED
                req.t_finished = now
                out.append(req.rid)
                self._release(slot)
        return out

    def _release(self, slot: int) -> None:
        self.allocator.free(self.blocks[slot])
        self.blocks[slot] = []
        self.slots[slot] = None
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self.tables[slot, :] = 0

    # -------------------------------------------------------------- state
    @property
    def all_done(self) -> bool:
        return not self.waiting and not self.active_slots() and all(
            r.status is RequestStatus.FINISHED for r in self.requests.values()
        )

    def next_arrival(self) -> float | None:
        if not self.waiting:
            return None
        return min(self.requests[r].arrival for r in self.waiting)
