"""MegaRoute: a router fronting N MegaServe engine replicas.

Placement policies and SLO-aware admission live in
``repro.core.simkit.workload`` (jax-free) so the offline discrete-event
evaluation (``router_workload``) and this live router execute the same
decision logic; this package adds the engine-replica orchestration —
stepping, disaggregated prefill→decode KV migration, and merged metrics.
"""

from repro.serve.router.router import Router, RouterConfig

__all__ = ["Router", "RouterConfig"]
