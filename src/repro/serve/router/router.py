"""MegaRoute: router-fronted multi-replica serving.

A ``Router`` fronts N ``MegaServe`` replicas — each with its own params
view, KV pool, and scheduler — stepped round-robin inside one process.
Per tick it:

* **places** arrived requests onto a replica via a pluggable policy
  (``round_robin`` / ``least_kv`` / ``jsq``) with SLO-aware admission:
  a TTFT estimate from the replica's live queue/occupancy snapshot
  (``estimate_ttft`` over a ``PlacementView``) decides admit vs redirect
  vs shed — the *same* functions ``router_workload`` evaluates offline,
  so an offline policy ranking transfers to the live engines;
* **migrates** prefilled KV between replicas when prefill/decode
  disaggregation is on (``prefill_replicas > 0``): prefill-only replicas
  emit each request's first token, then the router exports the slot's KV
  blocks (``PagedKVCache.export_slot``) and adopts them into a decode
  replica (``import_slot``) — bit-identical blocks, so the greedy
  continuation is token-identical to a colocated run;
* **steps** every replica once, merging their streams/metrics/traces.

The replicas are real engines, not simulations: policies are compared on
actual prefill/decode wall time, and the chunked-prefill and speculative
paths run unchanged underneath the router.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

from repro.configs.base import ModelConfig
from repro.core.simkit.workload import (
    POLICIES,
    PlacementView,
    ServeProfile,
    admission_decision,
    place,
)
from repro.core.tracing.tracer import Tracer
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.serve.request import aggregate_metrics
from repro.serve.scheduler import ServeConfig
from repro.serve.server import MegaServe
from repro.serve.spec import Drafter


@dataclass(frozen=True)
class RouterConfig:
    """Router-level knobs (replica topology + placement/admission policy).

    ``replicas`` engine replicas serve behind one router.  ``policy`` picks
    the placement rule (a key of ``simkit.workload.POLICIES``).  With
    ``prefill_replicas = k > 0`` the first ``k`` replicas are prefill-only
    and the rest decode-only: new requests are placed on prefill replicas,
    and their KV migrates to a decode replica after the first token
    (disaggregation); ``0`` keeps every replica colocated.  ``slo_ttft_s``
    enables SLO-aware admission (``0`` disables it): a request whose
    estimated TTFT busts the SLO on the policy's pick is redirected to a
    replica that meets it, or shed entirely when none does (``shed=False``
    admits on the least-bad replica instead of shedding).
    """

    replicas: int = 2
    policy: str = "round_robin"
    prefill_replicas: int = 0
    slo_ttft_s: float = 0.0
    shed: bool = True

    def __post_init__(self) -> None:
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown router policy {self.policy!r}; "
                f"one of {sorted(POLICIES)}"
            )
        if self.prefill_replicas < 0:
            raise ValueError(
                f"prefill_replicas must be >= 0, got {self.prefill_replicas}"
            )
        if self.prefill_replicas >= self.replicas and self.prefill_replicas:
            raise ValueError(
                f"prefill_replicas={self.prefill_replicas} needs at least "
                f"one decode replica (replicas={self.replicas}); "
                "disaggregation splits the fleet, it cannot consume all of it"
            )
        if self.slo_ttft_s < 0:
            raise ValueError(
                f"slo_ttft_s must be >= 0, got {self.slo_ttft_s}"
            )

    @property
    def disaggregated(self) -> bool:
        return self.prefill_replicas > 0


class Router:
    """Front ``cfg.replicas`` MegaServe engines with placement, SLO-aware
    admission, and (optionally) disaggregated prefill→decode KV migration.

    Mirrors the single-engine surface — ``submit() / step() / drain() /
    metrics() / streams()`` — so launchers and benchmarks swap it in
    wherever a ``MegaServe`` went.  All replicas share one clock (t=0 at
    router construction), so arrival stamps and TTFTs are comparable
    across the fleet.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        serve_cfg: ServeConfig = ServeConfig(),
        router_cfg: RouterConfig = RouterConfig(),
        *,
        collector: Collector = NULL_COLLECTOR,
        tracer: Tracer | None = None,
        clock: Callable[[], float] | None = None,
        drafter: Drafter | None = None,
        use_jit: bool = True,
        wrap_step: Callable[[Callable], Callable] | None = None,
        replica_wrap_steps: Sequence[Callable | None] | None = None,
        replica_step_every: Sequence[int] | None = None,
        registry=None,
        profile: ServeProfile = ServeProfile(),
        compile_cache=None,
    ):
        self.router_cfg = router_cfg
        self.registry = registry
        self.profile = profile
        # router-level trace lane: placement / shed / migration hand-off
        # events; each replica traces its own compute on rank=i
        self.tracer = tracer or Tracer(rank=router_cfg.replicas, enabled=True)
        self._raw_clock = clock or time.perf_counter
        self._base = self._raw_clock()
        self._clock = lambda: self._raw_clock() - self._base

        if replica_wrap_steps is not None and (
            len(replica_wrap_steps) != router_cfg.replicas
        ):
            raise ValueError(
                f"replica_wrap_steps has {len(replica_wrap_steps)} entries "
                f"for {router_cfg.replicas} replicas"
            )
        # heterogeneous-speed emulation: replica i is stepped only every
        # ``replica_step_every[i]``-th router tick.  Inside one process the
        # replicas step in lockstep, so wall-clock tricks (sleeping inside a
        # replica's jitted step) slow every replica's tick equally and leave
        # per-tick throughput symmetric; thinning a replica's steps is the
        # honest single-process analogue of a 1/k-speed straggler, matching
        # the offline model's ``replica_speeds`` semantics.  Greedy streams
        # are unaffected — only when steps happen, never what they compute.
        if replica_step_every is None:
            replica_step_every = [1] * router_cfg.replicas
        if len(replica_step_every) != router_cfg.replicas:
            raise ValueError(
                f"replica_step_every has {len(replica_step_every)} entries "
                f"for {router_cfg.replicas} replicas"
            )
        if any(int(e) < 1 for e in replica_step_every):
            raise ValueError(
                f"replica_step_every entries must be >= 1, "
                f"got {list(replica_step_every)}"
            )
        self._step_every = [int(e) for e in replica_step_every]
        self.tick = 0
        self.replicas: list[MegaServe] = []
        for i in range(router_cfg.replicas):
            wrap = wrap_step
            if replica_wrap_steps is not None and replica_wrap_steps[i]:
                wrap = replica_wrap_steps[i]
            srv = MegaServe(
                cfg, params, serve_cfg,
                collector=collector,
                tracer=Tracer(rank=i, enabled=True),
                clock=self._raw_clock,
                drafter=drafter,
                use_jit=use_jit,
                wrap_step=wrap,
                registry=registry,
                metrics_prefix=f"serve.r{i}.",
                prefill_only=(
                    router_cfg.disaggregated and i < router_cfg.prefill_replicas
                ),
                compile_cache=compile_cache,
            )
            # share the router's epoch: every replica clock reads t=0 at
            # router construction (the _clock lambda reads _base at call
            # time, so overwriting it after construction is sufficient)
            srv._base = self._base
            self.replicas.append(srv)

        # pending: submitted but not yet placed (arrival in the future)
        self._pending: list[dict] = []
        # exported KV packages waiting for a decode replica with capacity
        self.migrations: list[dict] = []
        self._next_rid = 0
        self._rr = 0          # placement cursor (round_robin)
        self._rr_mig = 0      # migration-target cursor
        self.shed_rids: dict[int, float] = {}   # rid -> estimated ttft
        self.n_redirects = 0
        self.n_migrations = 0
        self.placed: dict[int, int] = {}        # rid -> replica index

    @classmethod
    def from_session(
        cls, session, params: Any, serve_cfg: ServeConfig,
        router_cfg: RouterConfig, **kw,
    ):
        """Router wired to a ``repro.app.Session``: replicas share the
        session's MegaScope collector and metrics registry, and every
        replica's jitted steps run through the plugins' ``wrap_step``."""
        kw.setdefault("registry", getattr(session, "metrics_registry", None))
        kw.setdefault("compile_cache", getattr(session, "compile_cache", None))
        return cls(
            session.model_cfg, params, serve_cfg, router_cfg,
            collector=session.collector, wrap_step=session.wrap_step, **kw,
        )

    # -------------------------------------------------------------- intake
    @property
    def _intake(self) -> list[int]:
        """Replica indices new requests may be placed on: the prefill tier
        when disaggregated, the whole fleet when colocated."""
        rc = self.router_cfg
        if rc.disaggregated:
            return list(range(rc.prefill_replicas))
        return list(range(rc.replicas))

    @property
    def _decoders(self) -> list[int]:
        rc = self.router_cfg
        return list(range(rc.prefill_replicas, rc.replicas))

    def submit(
        self,
        prompt: list[int],
        max_new: int,
        *,
        arrival: float | None = None,
        eos_id: int | None = None,
    ) -> int:
        """Queue a prompt with a globally-unique rid; placement happens at
        arrival time (inside ``step``), when replica load is observable."""
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append({
            "rid": rid, "prompt": list(prompt), "max_new": max_new,
            "arrival": self._clock() if arrival is None else arrival,
            "eos_id": eos_id,
        })
        self._pending.sort(key=lambda p: (p["arrival"], p["rid"]))
        return rid

    def _view(self, idx: int) -> PlacementView:
        """Live load snapshot of replica ``idx`` in the exact shape the
        offline evaluator uses, so policy decisions match bit-for-bit."""
        srv = self.replicas[idx]
        sched = srv.sched
        alloc = sched.allocator
        used = alloc.num_blocks - alloc.reserved - alloc.num_free
        return PlacementView(
            queued=len(sched.waiting),
            queued_prefill_tokens=sum(
                len(sched.requests[r].recompute_prompt) for r in sched.waiting
            ),
            active=len(sched.active_slots()),
            kv_used_frac=used / max(srv.serve_cfg.usable_blocks, 1),
        )

    def _place_arrivals(self, now: float) -> tuple[list[int], list[int]]:
        """Route every pending request whose arrival has passed; returns
        (placed rids, shed rids)."""
        rc = self.router_cfg
        placed, shed = [], []
        while self._pending and self._pending[0]["arrival"] <= now:
            p = self._pending.pop(0)
            intake = self._intake
            views = [self._view(i) for i in intake]
            action, pick, est = admission_decision(
                rc.policy, views, len(p["prompt"]),
                prof=self.profile, rr=self._rr,
                slo_ttft_s=rc.slo_ttft_s, shed=rc.shed,
            )
            self._rr += 1
            t0 = self._clock()
            if action == "shed":
                self.shed_rids[p["rid"]] = est
                shed.append(p["rid"])
                self.tracer.record(
                    "shed", t0, 0.0, kind="host", rid=p["rid"],
                    est_ttft=est, slo=rc.slo_ttft_s,
                )
                if self.registry is not None:
                    self.registry.counter("router.shed").inc()
                continue
            if action == "redirect":
                self.n_redirects += 1
                if self.registry is not None:
                    self.registry.counter("router.redirects").inc()
            replica = intake[pick]
            srv = self.replicas[replica]
            srv.submit(
                p["prompt"], p["max_new"],
                arrival=p["arrival"], eos_id=p["eos_id"], rid=p["rid"],
            )
            self.placed[p["rid"]] = replica
            placed.append(p["rid"])
            self.tracer.record(
                "route", t0, 0.0, kind="host", rid=p["rid"],
                replica=replica, action=action, est_ttft=est,
            )
            if self.registry is not None:
                self.registry.counter("router.placed").inc()
                self.registry.counter(f"router.placed_r{replica}").inc()
        return placed, shed

    # ---------------------------------------------------------- migration
    def _try_adopt(self, package: dict) -> bool:
        """Hand an exported KV package to a decode replica: the placement
        policy picks the preferred target, the rest are fallbacks in order
        (a full replica returns the package untouched)."""
        decoders = self._decoders
        views = [self._view(i) for i in decoders]
        first = place(self.router_cfg.policy, views, self._rr_mig)
        self._rr_mig += 1
        order = [decoders[first]] + [
            d for j, d in enumerate(decoders) if j != first
        ]
        for idx in order:
            if self.replicas[idx].adopt_request(package):
                rid = package["req"].rid
                self.placed[rid] = idx
                self.n_migrations += 1
                self.tracer.record(
                    "migrate", self._clock(), 0.0, kind="host",
                    rid=rid, replica=idx, blocks=package["n_blocks"],
                )
                if self.registry is not None:
                    self.registry.counter("router.migrations").inc()
                return True
        return False

    def _migrate(self) -> int:
        """Drain prefill-tier completions into the decode tier: export every
        ready slot (freeing prefill capacity immediately), then adopt as
        many packages as the decode tier has room for; the rest retry next
        tick.  Oldest packages first — migration is FIFO so a burst cannot
        starve an early request."""
        if not self.router_cfg.disaggregated:
            return 0
        for i in self._intake:
            srv = self.replicas[i]
            for rid in srv.exportable():
                self.migrations.append(srv.export_request(rid))
        moved = 0
        remaining = []
        for package in self.migrations:
            if self._try_adopt(package):
                moved += 1
            else:
                remaining.append(package)
        self.migrations = remaining
        return moved

    # --------------------------------------------------------------- step
    def step(self) -> dict:
        """One router tick: place arrivals, retry queued migrations, step
        every replica once, then harvest fresh prefill completions."""
        now = self._clock()
        placed, shed = self._place_arrivals(now)
        moved = self._migrate()   # queued packages first: frees decode work
        admitted, finished, preempted = [], [], 0
        active = tokens = 0
        for i, srv in enumerate(self.replicas):
            if self.tick % self._step_every[i]:
                # thinned-out replica: skipped this tick, but its live slots
                # still count as work so drain doesn't idle past them
                active += len(srv.sched.active_slots())
                continue
            rep = srv.step()
            admitted += rep["admitted"]
            finished += rep["finished"]
            preempted += len(rep["preempted"])
            active += rep["active"]
            tokens += rep["tokens"]
        self.tick += 1
        moved += self._migrate()  # fresh exports from this tick's prefills
        if self.registry is not None and (placed or active or moved):
            self.registry.gauge("router.pending").set(len(self._pending))
            self.registry.gauge("router.migrations_queued").set(
                len(self.migrations)
            )
        return {
            "placed": placed, "shed": shed, "migrated": moved,
            "admitted": admitted, "finished": finished,
            "preempted": preempted, "active": active, "tokens": tokens,
        }

    # -------------------------------------------------------------- drain
    @property
    def all_done(self) -> bool:
        return (
            not self._pending
            and not self.migrations
            and all(srv.sched.all_done for srv in self.replicas)
        )

    def next_arrival(self) -> float | None:
        if not self._pending:
            return None
        return self._pending[0]["arrival"]

    def drain(
        self,
        max_steps: int = 100_000,
        *,
        on_step: Callable[[list, dict], None] | None = None,
    ) -> dict[int, list[int]]:
        """Run until every placed request finishes; returns merged streams
        (shed rids are absent — check ``shed_rids``).  ``on_step(events,
        report)`` observes each tick with the TraceEvents all lanes (router
        + replicas) emitted, mirroring ``MegaServe.drain``."""
        tracers = [self.tracer] + [srv.tracer for srv in self.replicas]
        marks = [len(t.events) for t in tracers]
        work = idle = 0
        while not self.all_done:
            out = self.step()
            if on_step is not None:
                events = []
                for k, t in enumerate(tracers):
                    events += t.events[marks[k]:]
                    marks[k] = len(t.events)
                events.sort(key=lambda e: e.ts)
                on_step(events, out)
            busy = (
                out["placed"] or out["migrated"] or out["admitted"]
                or out["active"] or self.migrations
            )
            if busy:
                work += 1
                idle = 0
                if work > max_steps:
                    raise RuntimeError(f"drain: not done after {work} steps")
                continue
            idle += 1
            if idle > max_steps:
                raise RuntimeError(
                    f"drain: stalled waiting for arrival at "
                    f"t={self.next_arrival()} (now={self._clock():.3f})"
                )
            nxt = self.next_arrival()
            if nxt is not None:
                time.sleep(max(0.0, min(nxt - self._clock(), 1e-3)))
        return self.streams()

    def precompile(self) -> dict:
        """Precompile every replica's bucketed step variants (see
        ``MegaServe.precompile``) so no replica pays an XLA compile inside
        the serving loop.  Returns the per-path counts and compile
        milliseconds aggregated across the fleet (prefill / chunk / verify /
        decode are tallied separately, plus ``total``)."""
        agg: dict[str, Any] = {}
        for srv in self.replicas:
            rep = srv.precompile()
            for k, v in rep.items():
                if not isinstance(v, dict) or k == "cache":
                    continue
                a = agg.setdefault(k, {"count": 0, "ms": 0.0})
                a["count"] += v["count"]
                a["ms"] += v["ms"]
        agg["total"] = sum(v["count"] for v in agg.values())
        return agg

    # ------------------------------------------------------------- output
    def streams(self) -> dict[int, list[int]]:
        """rid -> generated tokens, merged across replicas.  After drain a
        rid's stream lives on exactly one replica (migration moves it)."""
        out: dict[int, list[int]] = {}
        for srv in self.replicas:
            for rid, items in srv.streams.items():
                out[rid] = [it.token for it in items]
        return out

    def metrics(self) -> dict:
        """Fleet metrics over every replica's requests, plus router-level
        accounting: placement spread, redirects, shed rate, migrations."""
        reqs = []
        for srv in self.replicas:
            reqs += list(srv.sched.requests.values())
        out = aggregate_metrics(reqs, wall=self._clock())
        submitted = self._next_rid
        replica_tokens = [
            sum(len(r.generated) for r in srv.sched.requests.values())
            for srv in self.replicas
        ]
        placed_per = [0] * self.router_cfg.replicas
        for rep in self.placed.values():
            placed_per[rep] += 1
        out.update({
            "steps": sum(srv.step_idx for srv in self.replicas),
            "submitted": submitted,
            "shed": len(self.shed_rids),
            "shed_rate": len(self.shed_rids) / submitted if submitted else 0.0,
            "redirects": self.n_redirects,
            "migrations": self.n_migrations,
            "placed_per_replica": placed_per,
            "replica_tokens": replica_tokens,
            "load_skew": (
                max(replica_tokens) / max(min(replica_tokens), 1)
                if replica_tokens else 0.0
            ),
        })
        return out

    def trace_events(self):
        """All lanes merged (router rank=N, replicas rank=0..N-1), by ts."""
        events = list(self.tracer.events)
        for srv in self.replicas:
            events += srv.tracer.events
        return sorted(events, key=lambda e: e.ts)

    def reset(self) -> None:
        """Drop all finished state and restart the shared clock (replicas
        keep their compiled steps, so a warmed-up fleet re-times cleanly)."""
        if not self.all_done:
            raise RuntimeError("reset() with requests still in flight")
        self._base = self._raw_clock()
        for srv in self.replicas:
            srv.reset()
            srv._base = self._base
        self.tracer.clear()
        self._pending.clear()
        self.migrations.clear()
        self.shed_rids.clear()
        self.placed.clear()
        self._next_rid = 0
        self._rr = self._rr_mig = 0
        self.n_redirects = self.n_migrations = 0
        self.tick = 0
