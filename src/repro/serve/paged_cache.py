"""Paged KV cache: a fixed-size physical block pool + per-slot block tables.

The static serving path allocates one dense ``[B, cache_len, ...]`` cache, so
every slot pays for the longest sequence it might ever hold.  Here the time
axis of each attention cache leaf is cut into fixed-size blocks that live in
one shared physical pool; a slot owns an ordered *block table* of pool
indices, and slots with wildly different lengths share the pool densely.

Layout convention (matches ``lm.init_cache``): every cache leaf is stacked
over layers exactly once, i.e. shaped ``[n_layers, batch, ...]``.  Leaves
whose post-batch axis is the full-length ``kv_time`` axis (k/v, ckv/kpe,
griffin window k/v) are *paged*:

    dense leaf  [n, B, L_max, *feat]   ->   pool [n, num_blocks, bs, *feat]

All other leaves (rwkv wkv/x_prev, griffin conv/h — O(1) recurrent state per
slot, nothing to page) are *slot-state* leaves stored densely per slot:

    state leaf  [n, B, *feat]          ->   pool [n, num_slots, *feat]

Block 0 is reserved as the *null block*: padding entries of every block table
point at it, so the decode-path scatter of inactive slots lands there
harmlessly and gathered positions beyond a slot's ``kv_len`` are masked out
by attention anyway.

Two decode paths share this pool (``server.ServeConfig.decode_path``):

* **paged** (default): no dense view is ever built — the paged-attention
  kernel walks each slot's block table directly against the pool and the new
  token's K/V are written in place into the owning block
  (``layers.gqa_apply`` paged branch / ``engine.make_paged_decode_step``);
* **gathered** (the correctness oracle, and the MegaScope deep-probe path):
  gather -> step -> scatter-touched-block — one decode step writes a single
  position per slot, so only the block containing that position goes back to
  the pool.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.engine import cache_axes


class PoolExhausted(RuntimeError):
    """No free physical blocks — the scheduler should preempt."""


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size physical blocks.

    Block ids ``[reserved, num_blocks)`` are allocatable; ``[0, reserved)``
    (the null block) never leave the allocator.
    """

    def __init__(self, num_blocks: int, reserved: int = 1):
        if num_blocks <= reserved:
            raise ValueError(f"need > {reserved} blocks, got {num_blocks}")
        self.num_blocks = num_blocks
        self.reserved = reserved
        # LIFO free list: recently-freed blocks are reused first (warm)
        self._free: list[int] = list(range(num_blocks - 1, reserved - 1, -1))
        self._held: set[int] = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_held(self) -> int:
        return len(self._held)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise PoolExhausted(f"want {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._held.update(out)
        return out

    def try_alloc(self, n: int = 1) -> list[int] | None:
        if n > len(self._free):
            return None
        return self.alloc(n)

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b not in self._held:
                raise ValueError(f"block {b} not held (double free?)")
            self._held.remove(b)
            self._free.append(b)


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache positions."""
    return -(-n_tokens // block_size)


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= ``n`` — the jit-compile-cache bucketing used
    for prefill cache lengths and the decode-table high-water mark, so the
    number of compiled shapes stays O(log max_len) under Poisson workloads."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pow2_segments(n: int) -> list[int]:
    """Descending binary decomposition of ``n`` (13 -> [8, 4, 1]): the exact
    segment widths the recurrent-family prefill driver runs, so any prompt
    length is covered by O(log n) power-of-two segment executables instead of
    one compile per exact length."""
    if n <= 0:
        raise ValueError(f"need n >= 1, got {n}")
    return [1 << b for b in range(n.bit_length() - 1, -1, -1) if n >> b & 1]


@dataclass(frozen=True)
class PoolSpec:
    num_slots: int
    num_blocks: int          # physical blocks incl. the reserved null block
    block_size: int
    max_blocks: int          # block-table width per slot

    @property
    def max_len(self) -> int:
        return self.max_blocks * self.block_size


class PagedKVCache:
    """The physical pool pytree + pure gather/scatter transforms.

    ``self.pool`` mirrors the model's cache treedef; methods are pure in the
    pool (take + return it) so the server can fold them into jitted steps.
    """

    def __init__(self, cfg: ModelConfig, spec: PoolSpec, *,
                 promote_store: bool = False):
        """``promote_store`` widens bfloat16 *paged* leaves to float32
        containers (values are still quantized through bfloat16 on every
        write, so numerics are bit-identical to a bf16 pool).  The in-place
        paged decode path needs this on CPU: XLA CPU cannot alias bf16
        scatters, so a bf16 pool would silently copy itself every step."""
        self.cfg = cfg
        self.spec = spec
        L = spec.max_len
        template = jax.eval_shape(lambda: lm.init_cache(cfg, 1, L))
        axes = cache_axes(template)

        def is_paged(leaf, ax) -> bool:
            # ax comes from cache_axes with the "layers" prefix included
            n_layers = sum(1 for a in ax if a == "layers")
            assert n_layers == 1 and ax[1] == "batch", (
                f"expected [layers, batch, ...], got {leaf.shape} axes {ax}"
            )
            if "kv_time" not in ax:
                return False
            return leaf.shape[ax.index("kv_time")] == L

        self.paged = jax.tree.map(is_paged, template, axes)

        def make_pool(leaf, paged):
            n = leaf.shape[0]
            feat = leaf.shape[3:] if paged else leaf.shape[2:]
            dtype = leaf.dtype
            if paged and promote_store and dtype == jnp.bfloat16:
                dtype = jnp.float32
            if paged:
                shape = (n, spec.num_blocks, spec.block_size, *feat)
            else:
                shape = (n, spec.num_slots, *feat)
            return jnp.zeros(shape, dtype)

        self.pool = jax.tree.map(make_pool, template, self.paged)

    # ------------------------------------------------------------ gather
    def gather(self, pool: Any, tables: jax.Array) -> Any:
        """Materialize the dense decode cache for all slots.

        ``tables`` [num_slots, max_blocks] int32 — padding entries must point
        at the null block.  Paged leaves become ``[n, S, max_len, *feat]``;
        slot-state leaves pass through (they already carry the slot axis).
        """
        S, M = tables.shape
        bs = self.spec.block_size

        def leaf(p, paged):
            if not paged:
                return p
            n = p.shape[0]
            g = jnp.take(p, tables.reshape(-1), axis=1)       # [n, S*M, bs, f]
            return g.reshape(n, S, M * bs, *p.shape[3:])

        return jax.tree.map(leaf, pool, self.paged)

    # ------------------------------------------------- scatter (decode)
    def scatter_decode(
        self, pool: Any, dense: Any, tables: jax.Array, pos: jax.Array
    ) -> Any:
        """Write back the one block each slot touched at ``pos`` (per-slot
        write position of this decode step); slot-state leaves are replaced
        wholesale since the dense tree *is* their storage."""
        S = tables.shape[0]
        bs = self.spec.block_size
        tb = pos // bs                                         # [S]
        phys = tables[jnp.arange(S), tb]                       # [S]

        def leaf(p, d, paged):
            if not paged:
                return d

            def pick(d_s, start):                              # d_s [n, L, f]
                return jax.lax.dynamic_slice_in_dim(d_s, start, bs, axis=1)

            blocks = jax.vmap(pick, in_axes=(1, 0), out_axes=1)(d, tb * bs)
            return p.at[:, phys].set(blocks)                   # [n, S, bs, f]

        return jax.tree.map(leaf, pool, dense, self.paged)

    # ------------------------------------------- slot migration (export)
    def export_slot(self, pool: Any, phys: jax.Array, slot: jax.Array) -> Any:
        """Pull one slot's cache state out of the pool as a self-contained
        bundle — the disaggregation hand-off unit.  Paged leaves become
        ``[n, n_blk, bs, *feat]`` (the slot's blocks in table order);
        slot-state leaves become ``[n, *feat]`` (the slot's row).  ``phys``
        may be padded with null-block entries: the padding rows carry
        whatever the null block holds and are ignored on import.
        """

        def leaf(p, paged):
            if paged:
                return jnp.take(p, phys, axis=1)
            return p[:, slot]

        return jax.tree.map(leaf, pool, self.paged)

    # ------------------------------------------- slot migration (import)
    def import_slot(
        self, pool: Any, bundle: Any, phys: jax.Array, slot: jax.Array
    ) -> Any:
        """Deposit an :meth:`export_slot` bundle into this pool at ``phys``
        blocks + slot-state row ``slot``.  Padding entries of ``phys`` must
        point at the null block, where the extra writes land harmlessly
        (same convention as the decode scatter of inactive slots)."""

        def leaf(p, b, paged):
            if paged:
                return p.at[:, phys].set(b.astype(p.dtype))
            return p.at[:, slot].set(b.astype(p.dtype))

        return jax.tree.map(leaf, pool, bundle, self.paged)

    # ------------------------------------------------ scatter (prefill)
    def scatter_prefill(
        self, pool: Any, filled: Any, slot: jax.Array, phys: jax.Array
    ) -> Any:
        """Deposit a freshly-prefilled B=1 dense cache (cache_len = a block
        multiple) into ``phys`` [n_blk] pool blocks + slot-state row ``slot``."""
        bs = self.spec.block_size
        n_blk = phys.shape[0]

        def leaf(p, f, paged):
            if not paged:
                return p.at[:, slot].set(f[:, 0])
            n = p.shape[0]
            r = f[:, 0].reshape(n, n_blk, bs, *p.shape[3:])
            return p.at[:, phys].set(r)

        return jax.tree.map(leaf, pool, filled, self.paged)
