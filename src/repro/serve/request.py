"""MegaServe request model: lifecycle state + per-request latency metrics.

A request moves WAITING -> RUNNING -> FINISHED.  Preemption-by-recompute
(block pool exhausted) sends a RUNNING request back to WAITING with its
already-generated tokens folded into the prompt, so a later re-admission
re-prefills the full history and greedy decoding continues token-for-token
where it left off.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class RequestStatus(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class Request:
    rid: int
    prompt: list[int]                  # token ids
    max_new: int                       # generation budget
    arrival: float = 0.0               # seconds on the server clock
    eos_id: int | None = None

    # -- mutable lifecycle state (owned by the scheduler/server) ----------
    status: RequestStatus = RequestStatus.WAITING
    generated: list[int] = field(default_factory=list)
    n_preemptions: int = 0
    # -- speculative decoding (owned by the server's spec loop) -----------
    draft_len: int = 0                 # current per-request draft budget
    spec_idle: int = 0                 # steps since speculation shut off
    spec_miss: int = 0                 # consecutive zero-acceptance verifies
    spec_backoff: int = 1              # re-probe interval multiplier
    spec_proposed: int = 0             # draft tokens sent to verification
    spec_accepted: int = 0             # draft tokens the target accepted
    # timing (server clock; None until the transition happens)
    t_admitted: float | None = None
    t_first_token: float | None = None
    t_finished: float | None = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def recompute_prompt(self) -> list[int]:
        """Prompt for re-prefill after preemption: original + generated."""
        return list(self.prompt) + list(self.generated)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.generated)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new:
            return True
        return bool(
            self.eos_id is not None
            and self.generated
            and self.generated[-1] == self.eos_id
        )

    # ------------------------------------------------------------ metrics
    @property
    def queue_wait(self) -> float | None:
        """Arrival -> first admission: the queueing share of TTFT, split out
        so router-induced waiting is attributable separately from compute."""
        if self.t_admitted is None:
            return None
        return self.t_admitted - self.arrival

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def latency(self) -> float | None:
        if self.t_finished is None:
            return None
        return self.t_finished - self.arrival


def percentile(values: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    xs = sorted(values)
    rank = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[rank]


def aggregate_metrics(requests: list[Request], wall: float) -> dict:
    """Fleet-level serving metrics over finished requests."""
    fin = [r for r in requests if r.status is RequestStatus.FINISHED]
    ttfts = [r.ttft for r in fin if r.ttft is not None]
    lats = [r.latency for r in fin if r.latency is not None]
    waits = [r.queue_wait for r in fin if r.queue_wait is not None]
    total_tokens = sum(len(r.generated) for r in fin)
    return {
        "finished": len(fin),
        "total_requests": len(requests),
        "generated_tokens": total_tokens,
        "wall_s": wall,
        "tokens_per_s": total_tokens / wall if wall > 0 else 0.0,
        "ttft_p50_s": percentile(ttfts, 50),
        "ttft_p99_s": percentile(ttfts, 99),
        "queue_wait_p50_s": percentile(waits, 50),
        "queue_wait_p99_s": percentile(waits, 99),
        "latency_p50_s": percentile(lats, 50),
        "latency_p99_s": percentile(lats, 99),
        "preemptions": sum(r.n_preemptions for r in requests),
    }
