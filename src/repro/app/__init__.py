"""`repro.app` — one Session API + one CLI for every workload and module.

Public surface:

* :class:`~repro.app.config.RunConfig` — typed, layered run configuration
  (arch config -> workload defaults -> JSON -> dotted ``--set`` overrides);
* :class:`~repro.app.session.Session` — the runtime object that owns mesh
  selection, sharding-rule installation, the module plugins, and the shared
  chrome-trace export;
* :class:`~repro.app.plugins.ModulePlugin` + ``register_plugin`` — the
  uniform plugin protocol under which MegaScan / MegaScope / MegaFBD /
  MegaDPP attach to any workload;
* ``python -m repro {train,serve,trace,dryrun}``
  (:mod:`repro.app.cli`) — the single CLI replacing the per-workload
  launchers (``repro.launch.train`` / ``repro.launch.serve`` remain as
  deprecation shims).
"""

from repro.app.config import (
    RunConfig,
    WORKLOADS,
    apply_dict,
    apply_sets,
    build_run_config,
    parse_modules,
    set_by_path,
)
from repro.app.plugins import (
    PLUGIN_REGISTRY,
    DppPlugin,
    FbdPlugin,
    ModulePlugin,
    ScanPlugin,
    ScopePlugin,
    build_plugins,
    register_plugin,
)
from repro.app.session import Session, pick_mesh

__all__ = [
    "PLUGIN_REGISTRY",
    "RunConfig",
    "Session",
    "WORKLOADS",
    "ModulePlugin",
    "ScanPlugin",
    "ScopePlugin",
    "FbdPlugin",
    "DppPlugin",
    "apply_dict",
    "apply_sets",
    "build_plugins",
    "build_run_config",
    "parse_modules",
    "pick_mesh",
    "register_plugin",
    "set_by_path",
]
