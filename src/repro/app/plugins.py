"""ModulePlugin protocol: the four MegatronApp modules as uniform plugins.

Every module attaches to a :class:`repro.app.session.Session` through the
same four-hook surface:

* ``setup(session)``    — claim resources on the session (tracer, collector,
  planner state) before the workload builds anything;
* ``wrap_step(fn)``     — decorate the workload's jitted step callable;
* ``on_step(session, events, metrics)`` — observe one workload step: the
  MegaScan ``TraceEvent``s it emitted and its metrics dict;
* ``finalize(session)`` — return a JSON-able report (merged into
  ``session.results``) and release anything held.

Adding a module to every workload is a registration (``@register_plugin``)
instead of another hand-wired driver — the redesign's whole point.

Plugins are constructed from their ``RunConfig`` section only; heavyweight
imports (jax-backed collectors) happen inside ``setup`` so the CLI can parse
and validate configs before any backend initialisation.
"""

from __future__ import annotations

import numpy as np

PLUGIN_REGISTRY: dict[str, type["ModulePlugin"]] = {}


def register_plugin(cls: type["ModulePlugin"]) -> type["ModulePlugin"]:
    """Class decorator: make a plugin selectable via ``--modules <name>``."""
    if not getattr(cls, "name", ""):
        raise ValueError(f"{cls.__name__} needs a non-empty `name`")
    PLUGIN_REGISTRY[cls.name] = cls
    return cls


class ModulePlugin:
    """Base plugin: every hook is a no-op, subclass what you need."""

    name = ""

    def __init__(self, run_cfg):
        self.run_cfg = run_cfg

    def setup(self, session) -> None:  # noqa: ARG002 - uniform signature
        return None

    def wrap_step(self, step_fn):
        return step_fn

    def on_step(self, session, events, metrics) -> None:
        return None

    def finalize(self, session) -> dict:
        return {}


def build_plugins(names, run_cfg) -> list[ModulePlugin]:
    out = []
    for n in names:
        cls = PLUGIN_REGISTRY.get(n)
        if cls is None:
            raise ValueError(f"unknown module {n!r}; registered: {sorted(PLUGIN_REGISTRY)}")
        out.append(cls(run_cfg))
    return out


# ---------------------------------------------------------------------------
# MegaScan — always-on workload tracing
# ---------------------------------------------------------------------------


@register_plugin
class ScanPlugin(ModulePlugin):
    """Owns the session Tracer; optionally synchronises inside step scopes.

    The tracer the session hands to the train loop / MegaServe brackets
    *dispatch* of jitted blocks; with ``--set scan.sync=true`` the step
    callable is wrapped with ``jax.block_until_ready`` so scope durations
    are faithful — the CPU analogue of the paper's CUDA-event bracketing —
    at the cost of serializing async dispatch (off by default).

    Two online extensions ride the same plugin:

    * ``--detect-online`` runs an :class:`repro.obs.OnlineDetector` over the
      step event stream (topology from the composed ``ParallelPlan`` when
      one resolves, else the ``obs`` section); verdict deltas
      are stamped into the trace as ``diagnosis`` instant events and the
      last diagnosis lands in the ``scan.online`` report;
    * a ``--trace-out`` path additionally streams every event through an
      ``AsyncTraceWriter`` to a ``.jsonl`` sidecar as the run progresses,
      so a mid-run crash leaves a usable trace (a ``.jsonl`` trace_out IS
      the stream — Session then skips the end-of-run chrome export).
    """

    name = "scan"

    def setup(self, session) -> None:
        from repro.core.tracing.tracer import Tracer

        sc = self._scan_cfg = self.run_cfg.scan
        session.tracer = Tracer(rank=sc.rank, enabled=True)
        self._detector = None
        self._first_detect: int | None = None
        if sc.detect_online:
            from repro.core.simkit.workload import Topology
            from repro.obs import OnlineDetector

            # a composed ParallelPlan wins over the obs section's synthetic
            # dims: detector rank coordinates must match the mesh actually
            # training or the ft mitigation routes links to the wrong axis
            plan = session.parallel_plan()
            o = self.run_cfg.obs
            topo = (
                plan.topology() if plan is not None
                else Topology(dp=o.dp, pp=o.pp, tp=o.tp)
            )
            self._detector = OnlineDetector(
                topo,
                every=sc.detect_every, window=sc.detect_window,
                align=sc.detect_align,
                thresholds=dict(
                    slow_ratio=sc.slow_ratio,
                    candidate_frac=sc.candidate_frac,
                    skew_margin=sc.skew_margin,
                    late_frac=sc.late_frac,
                    degrade_ratio=sc.degrade_ratio,
                ),
            )
        self._writer = None
        self._streamed = 0
        if self.run_cfg.trace_out:
            from pathlib import Path

            from repro.core.tracing.tracer import AsyncTraceWriter

            self._stream_path = Path(self.run_cfg.trace_out).with_suffix(".jsonl")
            self._writer = AsyncTraceWriter(self._stream_path, mode="w")

    def wrap_step(self, step_fn):
        if not self._scan_cfg.sync:
            return step_fn
        import jax

        def synced(*a, **kw):
            out = step_fn(*a, **kw)
            jax.block_until_ready(out)
            return out

        return synced

    def on_step(self, session, events, metrics) -> None:
        if self._detector is not None and events:
            update = self._detector.push(events)
            if update is not None:
                if update.diagnosis.slow_ranks and self._first_detect is None:
                    self._first_detect = update.step
                # every completed pass flows to registered detection
                # listeners (the ft controller) — they decide on the full
                # diagnosis, not just the delta
                session.notify_detection(update)
                if update.changed:
                    session.tracer.instant(
                        "diagnosis",
                        slow_ranks=list(update.diagnosis.slow_ranks),
                        new=update.new_slow_ranks,
                        cleared=update.cleared_slow_ranks,
                        degraded_links=[
                            list(l) for l in update.new_degraded_links
                        ],
                    )
        if self._writer is not None:
            evs = session.tracer.events
            self._writer.submit(evs[self._streamed:])
            self._streamed = len(evs)

    def finalize(self, session) -> dict:
        by_name: dict[str, float] = {}
        for e in session.tracer.events:
            by_name[e.name] = by_name.get(e.name, 0.0) + e.dur
        out = {
            "events": len(session.tracer.events),
            "dur_s_by_name": {k: round(v, 4) for k, v in sorted(by_name.items())},
        }
        if self._writer is not None:
            evs = session.tracer.events
            self._writer.submit(evs[self._streamed:])
            self._streamed = len(evs)
            self._writer.close()
            out["stream"] = str(self._stream_path)
        if self._detector is not None:
            last = self._detector.history[-1] if self._detector.history else {}
            out["online"] = {
                "passes": len(self._detector.history),
                "first_detect_step": self._first_detect,
                "slow_ranks": last.get("slow_ranks", []),
                "degraded_links": last.get("degraded_links", []),
            }
        return out


# ---------------------------------------------------------------------------
# Live metrics — the MetricsRegistry + exporters behind every workload
# ---------------------------------------------------------------------------


@register_plugin
class MetricsPlugin(ModulePlugin):
    """Owns the session :class:`repro.obs.MetricsRegistry`.

    The instrumented loops (``train.loop``, ``serve.server``) publish their
    standard series into ``session.metrics_registry``; this plugin samples
    the registry every ``obs.every`` steps — appending a flat JSONL row to
    ``--metrics-out`` and chrome counter events to the session trace (they
    render as counter tracks next to the spans in the shared
    ``--trace-out``) — and at finalize writes the ``obs.prom_out``
    Prometheus snapshot and reports the flattened series (plus an MFU
    estimate when ``obs.peak_tflops`` is set).
    """

    name = "metrics"

    def setup(self, session) -> None:
        from repro.obs import JsonlExporter, MetricsRegistry

        self._obs = self.run_cfg.obs
        self.registry = MetricsRegistry()
        session.metrics_registry = self.registry
        self._jsonl = (
            JsonlExporter(self._obs.metrics_out)
            if self._obs.metrics_out else None
        )
        self._n = 0

    def on_step(self, session, events, metrics) -> None:
        self._n += 1
        if self._n % max(self._obs.every, 1):
            return
        if not len(self.registry):
            return  # nothing published yet (e.g. an idle serve tick)
        from repro.obs import counter_events, flatten_snapshot

        # flatten once; counter_events accepts the already-flat view
        flat = flatten_snapshot(self.registry.snapshot())
        ts = session.tracer.clock()
        if self._jsonl is not None:
            self._jsonl.write({"step": self._n, "ts": ts, **flat})
        if session.tracer.enabled:
            session.tracer.events.extend(counter_events(flat, ts=ts))

    def finalize(self, session) -> dict:
        from repro.obs import flatten_snapshot, prometheus_text

        snap = self.registry.snapshot()
        out: dict = {
            "series": {
                k: round(v, 6) for k, v in flatten_snapshot(snap).items()
            },
        }
        if self._jsonl is not None:
            self._jsonl.close()
            out["metrics_out"] = str(self._jsonl.path)
            out["rows"] = self._jsonl.rows
        if self._obs.prom_out:
            from pathlib import Path

            p = Path(self._obs.prom_out)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(prometheus_text(self.registry))
            out["prom_out"] = str(p)
        flops_s = snap.get("train.model_flops_per_s")
        if self._obs.peak_tflops > 0 and isinstance(flops_s, dict):
            out["mfu_est"] = round(
                flops_s["p50"] / (self._obs.peak_tflops * 1e12), 6
            )
        return out


# ---------------------------------------------------------------------------
# Fault tolerance — the detect -> decide -> mitigate -> recover controller
# ---------------------------------------------------------------------------


@register_plugin
class FtPlugin(ModulePlugin):
    """Owns the session :class:`repro.ft.FtController`.

    Closes the loop the scan plugin's ``--detect-online`` opens: every
    online ``DetectionUpdate`` runs through ``MitigationPolicy.decide``, and
    the decisions execute *in the train loop* — gradient compression for a
    degraded DP link, a MegaDPP schedule replan around a slow stage, or a
    rank exclusion + checkpoint rollback.  The same controller supervises
    the loop (crash -> restore-latest -> resume, NaN/grad-spike guards) and
    drives the declarative chaos spec (``--set ft.chaos.*``) that proves the
    recovery end to end.  ``finalize`` reports the mitigation timeline,
    restart/rollback counters, and exclusions as ``results["ft"]``.
    """

    name = "ft"

    def setup(self, session) -> None:
        from repro.ft import (
            ChaosInjector,
            ChaosSpec,
            FtController,
            FtOptions,
            MitigationPolicy,
        )

        sec = self.run_cfg.ft
        c = sec.chaos
        spec = ChaosSpec(
            crash_at_step=c.crash_at_step, nan_at_step=c.nan_at_step,
            slow_rank_from=c.slow_rank_from, slow_rank=c.slow_rank,
            slow_factor=c.slow_factor, degrade_link=c.degrade_link,
            degrade_factor=c.degrade_factor,
        )
        if sec.guard_action not in ("rollback", "skip"):
            raise ValueError(
                f"ft.guard_action must be rollback|skip, got {sec.guard_action!r}"
            )
        needs_ckpt = spec.crash_at_step >= 0 or (
            spec.nan_at_step >= 0 and sec.guard_action == "rollback"
        )
        if needs_ckpt and not self.run_cfg.train.ckpt_dir:
            raise ValueError(
                "ft.chaos crash/NaN-rollback recovery needs train.ckpt_dir "
                "(--ckpt-dir) for a restore target"
            )
        if ((spec.slow_rank_from >= 0 or spec.degrade_link)
                and not self.run_cfg.scan.detect_online):
            import logging

            logging.getLogger("repro.ft").warning(
                "ft.chaos injects a straggler/degraded link but "
                "scan.detect_online is off — nothing will detect or "
                "mitigate it (add --detect-online)"
            )
        self.controller = FtController(
            policy=MitigationPolicy(
                slow_frac_soft=sec.slow_frac_soft,
                slow_frac_hard=sec.slow_frac_hard,
                min_evidence=sec.min_evidence,
            ),
            chaos=ChaosInjector(spec) if spec.active else None,
            options=FtOptions(
                max_restarts=sec.max_restarts, backoff_s=sec.backoff_s,
                guard_nan=sec.guard_nan, guard_spike=sec.guard_spike,
                guard_action=sec.guard_action,
            ),
        )
        session.ft_controller = self.controller
        session.detection_listeners.append(self.controller.on_detection)

    def finalize(self, session) -> dict:
        return self.controller.report()


# ---------------------------------------------------------------------------
# MegaScope — probes + perturbations through the model hooks
# ---------------------------------------------------------------------------


def _parse_probe(spec: str):
    from repro.core.scope import ProbeSpec

    pattern, _, compress = spec.partition(":")
    return ProbeSpec(pattern, compress or "stats")


def _parse_perturb(spec: str):
    from repro.core.scope import PerturbSpec

    parts = spec.split(":")
    if len(parts) < 3:
        raise ValueError(
            f"perturb spec {spec!r}; want pattern:kind:amount[:layer]"
        )
    layer = int(parts[3]) if len(parts) > 3 else None
    return PerturbSpec(parts[0], parts[1], float(parts[2]), layer)


@register_plugin
class ScopePlugin(ModulePlugin):
    """Owns the session ScopeCollector, built from compact config strings."""

    name = "scope"

    def setup(self, session) -> None:
        from repro.core.scope import ScopeCollector

        sec = self.run_cfg.scope
        self._probes = [_parse_probe(s) for s in sec.probes]
        self._perturbs = [_parse_perturb(s) for s in sec.perturbs]
        session.collector = ScopeCollector(
            probes=self._probes, perturbs=self._perturbs
        )
        self._captured: dict[str, int] = {}

    def on_step(self, session, events, metrics) -> None:
        # captures ride the workload's metrics under a nested "captures"
        # tree ({segment: {"<tag>.<compressor>": leaf}}); count leaf hits
        def walk(prefix: str, node) -> None:
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(f"{prefix}{k}/" if isinstance(v, dict) else f"{prefix}{k}", v)
            else:
                self._captured[prefix] = self._captured.get(prefix, 0) + 1

        caps = (metrics or {}).get("captures") if isinstance(metrics, dict) else None
        if caps:
            walk("", caps)

    def finalize(self, session) -> dict:
        return {
            "probes": [f"{p.pattern}:{p.compress}" for p in self._probes],
            "perturbs": len(self._perturbs),
            "captured": dict(sorted(self._captured.items())),
        }


# ---------------------------------------------------------------------------
# MegaFBD — heterogeneous placement + coordination check
# ---------------------------------------------------------------------------


@register_plugin
class FbdPlugin(ModulePlugin):
    """Forward/backward-decoupling coordination for the configured cluster.

    ``setup`` plans virtual-rank placement over the section's heterogeneous
    speed model and verifies launch-order deadlock freedom with the
    bit-vector coordinator; ``finalize`` reports the decoupled-vs-colocated
    speedup.  Host-side planning only — step numerics are untouched, so the
    plugin composes with every workload.
    """

    name = "fbd"

    def setup(self, session) -> None:
        from repro.core.fbd.coordinator import ThreadProgram, run_with_coordinator
        from repro.core.fbd.ranks import (
            colocated_placement,
            evaluate_placement,
            plan_placement,
        )

        sec = self.run_cfg.fbd
        n_slow = int(sec.n_devices * sec.slow_frac)
        speed = {d: 1.0 for d in range(sec.n_devices - n_slow)}
        speed |= {d: sec.slow_speed for d in range(sec.n_devices - n_slow, sec.n_devices)}
        self.placement = plan_placement(sec.n_virtual, speed)
        self._t_decoupled = evaluate_placement(self.placement)
        self._t_colocated = evaluate_placement(
            colocated_placement(sec.n_virtual, speed)
        )
        # coordination check: each virtual rank posts one all-ranks group and
        # one pairwise group; the bit-vector protocol must order all of them
        # without deadlock on this placement's control threads
        vmap = self.placement.mapping
        n_v = sec.n_virtual
        groups = {0: tuple(range(n_v))}
        groups |= {1 + i: (i, i + 1) for i in range(n_v - 1)}
        programs = [
            ThreadProgram(
                vrank=v,
                control=vmap.control_thread(vmap.fwd_device[v]),
                group_ids=[0] + sorted(g for g, ms in groups.items() if g and v in ms),
            )
            for v in range(n_v)
        ]
        self._launch_order = run_with_coordinator(
            programs, groups, n_controls=sec.n_devices
        )

    def finalize(self, session) -> dict:
        return {
            "decoupled_ms": round(self._t_decoupled * 1e3, 3),
            "colocated_ms": round(self._t_colocated * 1e3, 3),
            "speedup": round(self._t_colocated / self._t_decoupled, 3),
            "coordinated_groups": len(self._launch_order),
        }


# ---------------------------------------------------------------------------
# MegaDPP — pipeline planning + step-time telemetry
# ---------------------------------------------------------------------------


@register_plugin
class DppPlugin(ModulePlugin):
    """Plans the pipeline schedule for the configured topology at ``setup``
    and folds observed step times in at ``finalize`` (the planner's
    telemetry-driven ``replan`` path is exercised by the trace workload's
    ``Diagnosis``; here the live loop contributes measured step dispersion).
    """

    name = "dpp"

    def setup(self, session) -> None:
        from repro.core.dpp.planner import Planner
        from repro.core.simkit.workload import ModelProfile, Topology

        sec = self.run_cfg.dpp
        self.planner = Planner(
            Topology(dp=sec.dp, pp=sec.pp, tp=sec.tp),
            ModelProfile(n_chunks=sec.n_chunks),
            n_micro=sec.n_micro,
            memory_cap=int(sec.memory_cap_gib * (1 << 30)),
        )
        self.plan = self.planner.plan()
        self._step_durs: list[float] = []

    def on_step(self, session, events, metrics) -> None:
        for e in events:
            if e.name in ("train_step", "decode", "prefill", "verify"):
                self._step_durs.append(e.dur)

    def finalize(self, session) -> dict:
        durs = np.asarray(self._step_durs)
        out = {
            "schedule": self.plan.schedule_name,
            "wave": self.plan.wave,
            "makespan_ms": round(self.plan.makespan * 1e3, 3),
            "peak_memory_mib": self.plan.peak_memory >> 20,
        }
        if durs.size:
            out["step_ms_p50"] = round(float(np.median(durs)) * 1e3, 3)
            out["step_ms_max"] = round(float(durs.max()) * 1e3, 3)
        return out
