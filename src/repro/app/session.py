"""Session: the one runtime object behind every `python -m repro` workload.

A Session owns the pieces every entry point used to re-roll by hand:

* the **model config** (``get_config(arch, smoke=...)``),
* the **mesh** (shared auto/host/pod selection) and the architecture's
  **sharding rules** (installed via ``parallel.sharding.axis_rules``),
* the **module plugins** (MegaScan / MegaScope / MegaFBD / MegaDPP), each
  attached through the uniform :class:`repro.app.plugins.ModulePlugin`
  surface,
* the shared **chrome-trace export** (``run_cfg.trace_out`` works for every
  workload, not just training).

Tracing is on by default for every workload (the ``scan`` module is in the
default module set) — the documented unification of the old split where
``train()`` silently disabled its tracer while ``MegaServe`` enabled it.
Pass ``--modules none`` (or build a Session with ``modules=()``) to opt out.

Workloads: ``train`` (the jitted train loop), ``serve`` (MegaServe
continuous batching or the static lockstep baseline), ``trace`` (offline
MegaScan: simulate/load -> align -> detect), ``dryrun`` (compile-analysis
cells; see ``repro.launch.dryrun`` for the XLA-flags ordering caveat).
"""

from __future__ import annotations

import json
import logging
import time
from pathlib import Path
from typing import Any, Callable

from repro.app.config import RunConfig
from repro.app.plugins import ModulePlugin, build_plugins

log = logging.getLogger("repro.app")


def pick_mesh(spec: str):
    """Shared mesh selection (was private to the train launcher).

    ``auto`` picks the largest production mesh the device fleet provides,
    else a host mesh; ``auto-mp`` prefers the two-pod shape; ``host`` /
    ``pod1`` / ``pod2`` force a shape.
    """
    import jax

    from repro.launch.mesh import make_host_mesh, make_production_mesh

    n = len(jax.devices())
    if spec == "host":
        return make_host_mesh()
    if spec == "pod1":
        return make_production_mesh(multi_pod=False)
    if spec == "pod2":
        return make_production_mesh(multi_pod=True)
    if spec in ("auto", "auto-mp"):
        if spec == "auto-mp" and n >= 512:
            return make_production_mesh(multi_pod=True)
        if n >= 256:
            return make_production_mesh(multi_pod=False)
        return make_host_mesh()
    raise ValueError(f"unknown mesh spec {spec!r}")


class Session:
    """One configured run: plugins + mesh + config, with a uniform lifecycle.

    >>> s = Session(RunConfig.for_workload("train", arch="qwen2-0.5b",
    ...                                    smoke=True))
    >>> state, history = s.run()         # doctest: +SKIP
    >>> s.results["scan"]["events"]      # doctest: +SKIP

    ``run()`` dispatches on ``run_cfg.workload``, then finalizes every
    plugin (reports land in ``session.results``) and exports the chrome
    trace when ``run_cfg.trace_out`` is set.
    """

    def __init__(
        self,
        run_cfg: RunConfig,
        plugins: list[ModulePlugin] | None = None,
        *,
        model_cfg=None,
    ):
        from repro.core.tracing.tracer import Tracer
        from repro.models.hooks import NULL_COLLECTOR

        self.run_cfg = run_cfg
        # an explicit ModelConfig (e.g. an unregistered preset) wins over
        # the arch-registry lookup
        self.model_cfg = model_cfg
        if model_cfg is None and run_cfg.arch:
            from repro.configs import get_config

            self.model_cfg = get_config(run_cfg.arch, smoke=run_cfg.smoke)
        # plugin-claimable resources, with inert defaults: no scan plugin ->
        # disabled tracer, no scope plugin -> null collector, no metrics
        # plugin -> no registry (instrumented loops skip publication)
        self.tracer = Tracer(rank=0, enabled=False)
        self.collector = NULL_COLLECTOR
        self.metrics_registry = None
        # no ft plugin -> no controller (the train loop runs unsupervised);
        # detection listeners receive every online DetectionUpdate the scan
        # plugin's detector produces
        self.ft_controller = None
        # runtime.compile_cache -> persistent executable store shared by the
        # train loop's AOT step and the serving engines' precompile ladders
        # (MegaServe.from_session / Router.from_session pick it up by name)
        self.compile_cache = None
        if run_cfg.runtime.compile_cache:
            from repro.core.compile_cache import CompileCache

            self.compile_cache = CompileCache(run_cfg.runtime.compile_cache)
        self.detection_listeners: list[Callable] = []
        self.results: dict[str, Any] = {}
        self.plugins = (
            plugins if plugins is not None
            else build_plugins(run_cfg.modules, run_cfg)
        )
        for p in self.plugins:
            p.setup(self)
        self._finalized = False

    # ------------------------------------------------------------ plumbing
    def mesh(self):
        return pick_mesh(self.run_cfg.mesh)

    def sharding_rules(self, shape_kind: str):
        from repro.parallel.profiles import rules_for

        return rules_for(self.model_cfg, shape_kind)

    def wrap_step(self, step_fn: Callable) -> Callable:
        for p in self.plugins:
            step_fn = p.wrap_step(step_fn)
        return step_fn

    def notify_step(self, events, metrics) -> None:
        for p in self.plugins:
            p.on_step(self, events, metrics)

    def notify_detection(self, update) -> None:
        """Fan one online ``DetectionUpdate`` out to detection listeners
        (the ft controller registers here) — called by the scan plugin."""
        for listener in self.detection_listeners:
            listener(update)

    def step_hooks(self):
        from repro.train.loop import StepHooks

        return StepHooks(wrap_step=self.wrap_step, on_step=self.notify_step)

    def finalize(self) -> dict[str, Any]:
        """Run every plugin's finalize once; export the shared chrome trace."""
        if self._finalized:
            return self.results
        self._finalized = True
        for p in self.plugins:
            self.results[p.name] = p.finalize(self)
        if self.run_cfg.trace_out:
            # an explicit --trace-out always writes, even when the run
            # traced nothing (e.g. --modules none) — an empty trace file
            # is debuggable, a silently missing one is not
            if not self.tracer.events:
                log.warning(
                    "trace_out=%s: no TraceEvents were recorded (is the "
                    "'scan' module enabled?)", self.run_cfg.trace_out)
            out_path = Path(self.run_cfg.trace_out)
            streamed = self.results.get("scan", {}).get("stream", "")
            if out_path.suffix == ".jsonl":
                # a .jsonl trace_out asks for the streaming format itself;
                # the scan plugin already wrote it incrementally — only
                # dump at the end when no plugin streamed (--modules none)
                if str(out_path) != streamed:
                    with open(out_path, "w") as f:
                        for e in self.tracer.events:
                            f.write(json.dumps(e.to_json()) + "\n")
            else:
                from repro.core.tracing.chrome import save_chrome

                save_chrome(self.tracer.events, self.run_cfg.trace_out)
            self.results["trace_out"] = self.run_cfg.trace_out
            log.info("trace -> %s", self.run_cfg.trace_out)
        return self.results

    # ----------------------------------------------------------- dispatch
    def run(self):
        """Run the configured workload, then finalize plugins."""
        fn = {
            "train": self.train,
            "serve": self.serve,
            "trace": self.trace,
            "dryrun": self.dryrun,
        }[self.run_cfg.workload]
        try:
            return fn()
        finally:
            self.finalize()

    # -------------------------------------------------------------- train
    def _rank_event_spec(self, plan=None):
        """Resolve the ``obs`` section into a per-rank event synthesis spec
        (``None`` unless rank events or straggler induction are asked for).

        With a composed ``ParallelPlan`` the synthesized topology follows the
        plan's real (dp, pp, tp) — so detector rank coordinates and the ft
        mitigation's link-axis routing agree with the mesh actually training
        — and the ``obs`` section's dims only apply to plan-less runs."""
        o = self.run_cfg.obs
        ch = self.run_cfg.ft.chaos
        chaos_needs = self.ft_controller is not None and (
            ch.slow_rank_from >= 0 or bool(ch.degrade_link)
        )
        if not (o.rank_events or o.slow_rank >= 0 or chaos_needs):
            return None
        from repro.obs import RankEventSpec

        dims = (
            {"dp": plan.dp, "pp": plan.pp, "tp": plan.tp}
            if plan is not None else {"dp": o.dp, "pp": o.pp, "tp": o.tp}
        )
        return RankEventSpec(
            **dims,
            slow_rank=o.slow_rank, slow_factor=o.slow_factor,
        )

    def _train_derived(self):
        """Resolve the 0-means-auto training fields against smoke/full."""
        rc, t = self.run_cfg, self.run_cfg.train
        seq = t.seq_len or (128 if rc.smoke else 4096)
        batch = t.global_batch or (8 if rc.smoke else 256)
        # minicpm trains with WSD per its paper (kept from the old launcher)
        schedule = t.schedule
        if self.model_cfg.name.startswith("minicpm") and schedule == "cosine":
            schedule = "wsd"
        return seq, batch, schedule

    def parallel_plan(self):
        """Resolve the ``parallel`` section into a ``ParallelPlan`` (pp>1) or
        ``None`` (the plain DP/TP path).  Wave resolution — ``schedule=wave``
        with ``wave=0`` — runs MegaDPP's planner under the ``dpp`` section's
        memory cap."""
        par = self.run_cfg.parallel
        if par.pp <= 1:
            return None
        from repro.parallel.plan import ParallelPlan, resolve_plan

        return resolve_plan(
            ParallelPlan(
                dp=par.dp, tp=par.tp, pp=par.pp,
                n_micro=par.n_micro, n_chunks=par.n_chunks,
                schedule=par.schedule, wave=par.wave,
                fbd_backward=par.fbd_backward,
            ),
            memory_cap_gib=self.run_cfg.dpp.memory_cap_gib,
        )

    def train(self):
        """The training workload: returns ``(state, history)``."""
        from repro.data.pipeline import DataConfig
        from repro.parallel.sharding import axis_rules
        from repro.train.loop import LoopConfig, train
        from repro.train.optim import OptimizerConfig

        rc, t = self.run_cfg, self.run_cfg.train
        cfg = self.model_cfg
        if cfg is None:
            raise ValueError("train workload needs an --arch")
        seq, batch, schedule = self._train_derived()
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch)
        ocfg = OptimizerConfig(
            lr=t.lr, schedule=schedule,
            warmup_steps=t.warmup_steps or max(t.steps // 10, 5),
            total_steps=t.steps,
        )
        loop = LoopConfig(
            n_steps=t.steps,
            log_every=t.log_every or max(t.steps // 10, 1),
            ckpt_dir=t.ckpt_dir or None,
            ckpt_every=t.ckpt_every,
            grad_accum=t.grad_accum,
            seed=rc.seed,
        )
        plan = self.parallel_plan()
        if plan is not None:
            from repro.launch.mesh import make_pipeline_mesh
            from repro.parallel.plan import plan_summary

            # per-axis divisibility: the batch first splits into grad_accum
            # macrobatches, each macrobatch into n_micro microbatches, and
            # the microbatch axis shards across dp groups (n_micro % dp is
            # plan.validate()'s job)
            ga = max(1, loop.grad_accum)
            if batch % ga != 0:
                raise ValueError(
                    f"global batch {batch} not divisible by "
                    f"train.grad_accum={ga}"
                )
            if (batch // ga) % plan.n_micro != 0:
                raise ValueError(
                    f"per-accumulation batch {batch // ga} (global {batch} "
                    f"/ grad_accum {ga}) not divisible by "
                    f"parallel.n_micro={plan.n_micro}"
                )
            mesh = make_pipeline_mesh(plan.pp, plan.dp, plan.tp)
            self.results["parallel"] = {
                **plan_summary(plan), "mesh": dict(mesh.shape),
            }
        else:
            mesh = self.mesh()
        log.info("arch=%s mesh=%s tokens/step=%d",
                 cfg.name, dict(mesh.shape), batch * seq)
        with mesh, axis_rules(mesh, self.sharding_rules("train")):
            state, history = train(
                cfg, ocfg, data, loop,
                collector=self.collector, tracer=self.tracer,
                hooks=self.step_hooks(), plan=plan,
                registry=self.metrics_registry,
                obs=self._rank_event_spec(plan),
                controller=self.ft_controller,
                compile_cache=self.compile_cache,
            )
        self.results["history"] = history
        return state, history

    # -------------------------------------------------------------- serve
    def serve(self):
        """The serving workload: returns ``(outputs, metrics)``.

        ``serve.continuous`` drives MegaServe (paged KV cache, scheduler,
        optional speculation); otherwise the static lockstep baseline runs.
        """
        cfg = self.model_cfg
        if cfg is None:
            raise ValueError("serve workload needs an --arch")
        s = self.run_cfg.serve
        if s.continuous:
            if cfg.input_kind != "tokens":
                raise ValueError(
                    f"{cfg.name}: continuous serving needs token archs"
                )
            if s.temperature != 0.0:
                raise ValueError(
                    "continuous serving decodes greedily "
                    "(preemption-by-recompute needs deterministic decode)"
                )
            return self._serve_continuous()
        if cfg.input_kind != "tokens" and cfg.family != "encdec":
            raise ValueError(
                f"{cfg.name} needs a modality frontend; serve token archs"
            )
        return self._serve_static()

    def _serve_continuous(self):
        from dataclasses import replace

        import jax

        from repro.models import get_model
        from repro.serve import MegaServe, Router, RouterConfig, get_drafter
        from repro.serve.server import make_poisson_workload

        cfg, rc, s = self.model_cfg, self.run_cfg, self.run_cfg.serve
        r = rc.router
        # always construct the RouterConfig so router.* validation fires
        # (bad policy / replica split fails loudly even on single-engine runs)
        router_cfg = RouterConfig(
            replicas=r.replicas, policy=r.policy,
            prefill_replicas=r.prefill_replicas,
            slo_ttft_s=r.slo_ttft_s, shed=r.shed,
        )
        use_router = (
            router_cfg.replicas > 1
            or router_cfg.disaggregated
            or router_cfg.slo_ttft_s > 0
            or router_cfg.policy != "round_robin"
        )
        m = get_model(cfg)
        params = m.init(cfg, jax.random.PRNGKey(0))
        specs, prompts, serve_cfg = make_poisson_workload(
            cfg, n=s.requests, rate=s.rate, prompt_lens=tuple(s.prompt_lens),
            max_new_range=(max(1, s.max_new // 4), s.max_new),
            num_slots=s.slots, block_size=s.block_size,
            num_blocks=s.num_blocks, seed=rc.seed, traffic=s.traffic,
        )
        serve_cfg = replace(
            serve_cfg, decode_path=s.decode_path,
            prefill_path=s.prefill_path,
            spec_decode=s.spec_decode, spec_k=s.spec_k,
            chunked_prefill=s.chunked_prefill, chunk_len=s.chunk_len,
        )
        drafter = None
        if s.spec_decode and s.drafter != "ngram":
            drafter = get_drafter(s.drafter, vocab_size=cfg.vocab_size,
                                  seed=rc.seed)
        if use_router:
            srv = Router.from_session(
                self, params, serve_cfg, router_cfg, drafter=drafter)
            replica_streams = [rep.streams for rep in srv.replicas]
        else:
            srv = MegaServe.from_session(
                self, params, serve_cfg, drafter=drafter)
            replica_streams = [srv.streams]
        if self.compile_cache is not None:
            # warm the full bucket ladder up front: with a populated on-disk
            # cache this deserializes executables instead of compiling, so
            # restart-to-first-token is dominated by weights, not XLA
            self.results["precompile"] = srv.precompile()
        for spec in specs:
            srv.submit(prompts[spec.rid], spec.max_new, arrival=spec.arrival)
        outs = srv.drain(on_step=self.notify_step)
        metrics = srv.metrics()
        if use_router:
            # replica lanes trace on their own rank=i tracers; fold them into
            # the session tracer so the shared trace_out export sees them
            self.tracer.events.extend(srv.trace_events())
        self.results["serve_config"] = {
            "num_slots": serve_cfg.num_slots,
            "block_size": serve_cfg.block_size,
            "num_blocks": serve_cfg.num_blocks,
            "replicas": router_cfg.replicas if use_router else 1,
            "policy": router_cfg.policy if use_router else "",
            "traffic": s.traffic,
        }
        # MegaServe attaches probe captures per generated token (StreamItem),
        # not per tick — replay them through on_step so capture-observing
        # plugins (MegaScope) see serving captures like training ones
        from repro.models.hooks import NULL_COLLECTOR

        if self.collector is not NULL_COLLECTOR:
            for streams in replica_streams:
                for items in streams.values():
                    for it in items:
                        if it.captures:
                            self.notify_step([], {"captures": it.captures})
        self.results["serve_metrics"] = metrics
        self.results["decode_path"] = (
            srv.replicas[0].decode_path if use_router else srv.decode_path
        )
        return outs, metrics

    def _serve_static(self):
        import jax
        import jax.numpy as jnp

        from repro.models import get_model
        from repro.parallel.sharding import axis_rules
        from repro.serve.engine import make_decode_step, make_prefill_step
        from repro.serve.sampler import sample

        cfg, s = self.model_cfg, self.run_cfg.serve
        m = get_model(cfg)
        mesh = self.mesh()
        with mesh, axis_rules(mesh, self.sharding_rules("decode")):
            params = m.init(cfg, jax.random.PRNGKey(0))
            B, P = s.batch, s.prompt_len
            cache_len = P + s.max_new
            cache = (m.init_cache(cfg, B, cache_len, P)
                     if cfg.family == "encdec"
                     else m.init_cache(cfg, B, cache_len))
            prompts = jax.random.randint(
                jax.random.PRNGKey(1), (B, P), 2, cfg.vocab_size)
            batch = {"tokens": prompts}
            if cfg.family == "encdec":
                batch["embeds"] = jax.random.normal(
                    jax.random.PRNGKey(2), (B, P, cfg.d_model), jnp.bfloat16)

            prefill = self.wrap_step(
                jax.jit(make_prefill_step(cfg, self.collector)))
            decode = self.wrap_step(
                jax.jit(make_decode_step(cfg, self.collector,
                                         temperature=s.temperature)))

            t0 = time.perf_counter()
            n_ev = len(self.tracer.events)
            with self.tracer.scope("prefill", kind="compute",
                                   tokens=B * P, batch=B):
                cache, logits = prefill(params, batch, cache)
                jax.block_until_ready(logits)
            t_prefill = time.perf_counter() - t0
            tok = sample(logits, temperature=s.temperature)
            self.notify_step(self.tracer.events[n_ev:], {})

            outs = [tok]
            t0 = time.perf_counter()
            for i in range(s.max_new - 1):
                n_ev = len(self.tracer.events)
                with self.tracer.scope("decode", kind="compute", step=i,
                                       active=B, tokens=B):
                    cache, logits, tok = decode(params, cache, tok,
                                                jnp.int32(P + i))
                outs.append(tok)
                self.notify_step(self.tracer.events[n_ev:], {})
            jax.block_until_ready(outs[-1])
            t_decode = time.perf_counter() - t0

        gen = jnp.stack(outs, axis=1)
        metrics = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "prefill_tok_s": B * P / max(t_prefill, 1e-9),
            "decode_tok_s": B * (s.max_new - 1) / max(t_decode, 1e-9),
        }
        if self.metrics_registry is not None:
            reg = self.metrics_registry
            reg.histogram("serve.prefill_s").observe(t_prefill)
            reg.counter("serve.tokens").inc(B * s.max_new)
            reg.gauge("serve.decode_tok_s").set(metrics["decode_tok_s"])
        self.results["serve_metrics"] = metrics
        return gen, metrics

    # -------------------------------------------------------------- trace
    def trace(self):
        """Offline MegaScan: simulate (or load) -> align -> detect.

        Returns the :class:`repro.core.tracing.detect.Diagnosis`; its
        summary (plus ground truth, when simulated) lands in
        ``results["diagnosis"]`` and the aligned events are exported via
        the shared ``trace_out`` / ``trace.out`` paths.
        """
        from repro.core.simkit.engine import FaultModel
        from repro.core.simkit.workload import ModelProfile, Topology
        from repro.core.tracing import (
            ClockModel,
            align_clocks,
            apply_alignment,
            detect,
            simulate_trace,
        )
        from repro.core.tracing.chrome import save_chrome
        from repro.core.tracing.tracer import load_jsonl, load_trace

        t = self.run_cfg.trace
        topo = Topology(dp=t.dp, pp=t.pp, tp=t.tp)
        truth = None
        if t.detect:
            # offline triage of a saved run: chrome JSON or streamed JSONL
            events = load_trace(t.detect)
        elif t.load:
            events = load_jsonl(t.load)
        else:
            faults = FaultModel(
                compute_slowdown={t.slow_rank: t.slow_factor},
                jitter=0.01, seed=self.run_cfg.seed,
            )
            events, truth = simulate_trace(
                topo, ModelProfile(), n_micro=t.n_micro, n_iters=t.n_iters,
                faults=faults, clocks=ClockModel(seed=self.run_cfg.seed),
            )
        sc = self.run_cfg.scan
        aligned = apply_alignment(events, align_clocks(events))
        diag = detect(
            aligned, topo,
            slow_ratio=sc.slow_ratio, candidate_frac=sc.candidate_frac,
            skew_margin=sc.skew_margin, late_frac=sc.late_frac,
            degrade_ratio=sc.degrade_ratio,
        )
        self.results["diagnosis"] = diag.summary()
        if truth is not None:
            self.results["truth"] = {
                "slow_ranks": truth["slow_ranks"],
                "detected": diag.slow_ranks == truth["slow_ranks"],
            }
        # aligned events flow through the session tracer so the shared
        # trace_out export (Session.finalize) sees them like any workload
        self.tracer.enabled = True
        self.tracer.events.extend(aligned)
        if t.out:
            out = Path(t.out)
            out.mkdir(parents=True, exist_ok=True)
            save_chrome(aligned, out / "trace.json")
            (out / "diagnosis.json").write_text(
                json.dumps(diag.summary(), indent=1))
            self.results["out"] = str(out)
        return diag

    # ------------------------------------------------------------- dryrun
    def dryrun(self):
        """Compile-analysis cells.  NOTE: ``repro.launch.dryrun`` must be
        imported (its XLA_FLAGS lines run) before jax initialises a backend
        — the CLI guarantees this ordering; direct Session users must
        import it first themselves."""
        from repro.launch.dryrun import run_cells

        d = self.run_cfg.dryrun
        result = run_cells(
            arch=self.run_cfg.arch or None,
            shape=d.shape or None,
            run_all=d.all,
            multi_pod=d.multi_pod,
            profile=d.profile or None,
            grad_accum=d.grad_accum,
            out=d.out,
            save_hlo=d.save_hlo,
            smoke=self.run_cfg.smoke,
            host_mesh=d.host_mesh,
        )
        self.results["dryrun"] = result
        return result
