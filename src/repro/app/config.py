"""Typed run configuration with uniform layering (the `repro.app` spine).

A :class:`RunConfig` describes one run of one workload (``train`` / ``serve``
/ ``trace`` / ``dryrun``) plus which of the four MegatronApp modules attach
to it.  Values layer, most specific last:

1. dataclass defaults (this file),
2. workload defaults (:data:`WORKLOAD_DEFAULTS`),
3. a JSON config file (``--config run.json`` — nested dicts mirror the
   section structure),
4. dotted overrides (``--set serve.spec_k=6 --set modules=scan,scope``),
   values coerced to the target field's annotated type.

This module is deliberately jax-free: the CLI builds a RunConfig before any
backend initialisation (the dryrun workload must set ``XLA_FLAGS`` first).
"""

from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import dataclass, field
from pathlib import Path

WORKLOADS = ("train", "serve", "trace", "dryrun")


@dataclass
class TrainSection:
    """Training-workload knobs (0 = derive from smoke/full at run time)."""

    steps: int = 100
    seq_len: int = 0               # 0 -> 128 smoke / 4096 full
    global_batch: int = 0          # 0 -> 8 smoke / 256 full
    lr: float = 3e-4
    schedule: str = "cosine"       # cosine | wsd | constant
    warmup_steps: int = 0          # 0 -> max(steps // 10, 5)
    grad_accum: int = 1
    ckpt_dir: str = ""             # "" = no checkpointing
    ckpt_every: int = 50
    log_every: int = 0             # 0 -> max(steps // 10, 1)


@dataclass
class ParallelSection:
    """Parallelization plan for the train workload (``parallel.*``).

    ``pp > 1`` routes the block stack through the MegaDPP pipeline executor
    on a (stage, data, model) mesh; ``schedule`` picks the traversal
    (``1f1b``/``dfc``/``bfc``/``wave``) and ``wave=0`` with ``schedule=wave``
    lets the MegaDPP planner choose the wave width under ``dpp.memory_cap_gib``.
    ``fbd_backward`` attaches MegaFBD's decoupled backward as the gradient
    path.  ``dp``/``tp`` compose with ``pp`` on the one mesh: ``dp > 1``
    shards the ``n_micro`` microbatches across dp groups (``n_micro % dp``
    must be 0) with the gradient sync riding the pipelined backward's
    data-axis all-reduce, and ``tp > 1`` slices heads/kv-heads/ffn inside
    every stage's body (dense GQA families; each must divide by ``tp``).
    ``train.grad_accum > 1`` stacks macrobatch accumulation on top — each
    accumulation is one full pipeline pass.
    """

    dp: int = 1
    tp: int = 1
    pp: int = 1
    n_micro: int = 0               # 0 -> 2*pp*dp when pp>1
    n_chunks: int = 1
    schedule: str = "1f1b"         # 1f1b | dfc | bfc | wave
    wave: int = 0                  # 0 = planner chooses (schedule=wave)
    fbd_backward: bool = False


@dataclass
class ServeSection:
    """Serving-workload knobs (mirrors the legacy launcher flag set)."""

    continuous: bool = False       # MegaServe continuous batching vs lockstep
    batch: int = 4                 # static path: lockstep batch size
    prompt_len: int = 32           # static path: shared prompt length
    max_new: int = 16
    temperature: float = 0.0
    requests: int = 16             # continuous path: workload size
    rate: float = 100.0            # Poisson arrival rate, requests/s
    slots: int = 4
    block_size: int = 16
    num_blocks: int = 0            # 0 = size pool for zero preemption
    prompt_lens: tuple[int, ...] = (16, 32, 64, 128, 256)
    decode_path: str = "auto"      # auto | paged | gathered
    prefill_path: str = "auto"     # auto | flash | dense
    spec_decode: bool = False
    spec_k: int = 4
    drafter: str = "ngram"         # ngram | random
    chunked_prefill: bool = False  # stream long prompts chunk-by-chunk
    chunk_len: int = 0             # 0 = 2*block_size; else multiple of it
    traffic: str = "poisson"       # poisson | bursty | diurnal


@dataclass
class RouterSection:
    """MegaRoute front-end (``--replicas > 1`` or any ``--set router.*``).

    A router fronts ``replicas`` MegaServe engines, placing each arrival
    via ``policy`` (``round_robin`` / ``least_kv`` / ``jsq``) with optional
    SLO-aware admission: ``slo_ttft_s > 0`` sheds (or, with ``shed=False``,
    least-bad-admits) requests whose estimated TTFT busts the SLO.
    ``prefill_replicas = k > 0`` disaggregates: the first ``k`` replicas
    prefill only, their KV migrating to the decode tier after each first
    token.
    """

    replicas: int = 1
    policy: str = "round_robin"    # round_robin | least_kv | jsq
    prefill_replicas: int = 0      # > 0 -> disaggregated prefill/decode
    slo_ttft_s: float = 0.0        # 0 = no admission control
    shed: bool = True              # shed SLO-busting requests vs least-bad


@dataclass
class ScanSection:
    """MegaScan plugin: always-on tracing of every workload step."""

    rank: int = 0
    # sync=True wraps the step with block_until_ready so scope durations are
    # faithful (the CPU analogue of the paper's CUDA-event bracketing) at
    # the cost of serializing async dispatch; off by default so the default
    # CLI path keeps the launcher's original pipelined throughput
    sync: bool = False
    # --- online detection (OnlineDetector hook; --detect-online) ----------
    detect_online: bool = False
    detect_every: int = 8          # detection pass every N workload steps
    detect_window: int = 64        # sliding window, in steps of TraceEvents
    # re-align window clocks before each pass: required when events carry
    # real per-rank clocks (gathered multi-host traces), a pure cost on a
    # single-tracer session whose events already share one monotonic clock
    detect_align: bool = False
    # --- detect() thresholds, shared by the online hook and the offline
    # trace workload (--set scan.slow_ratio=... etc.)
    slow_ratio: float = 1.25       # stage 1: dur > ratio * DP-peer median
    candidate_frac: float = 0.25   # stage 1: slow-op fraction -> candidate
    skew_margin: float = 0.05      # stage 2: last-start gap vs span
    late_frac: float = 0.4         # stage 2: late-start fraction -> confirm
    degrade_ratio: float = 1.6     # stage 3: bw < global median / ratio


@dataclass
class ScopeSection:
    """MegaScope plugin: probe / perturbation specs as compact strings.

    ``probes``: ``"pattern[:compressor]"`` (default compressor ``stats``).
    ``perturbs``: ``"pattern:kind:amount[:layer]"``.
    """

    probes: tuple[str, ...] = ("mlp_hidden:stats",)
    perturbs: tuple[str, ...] = ()


@dataclass
class FbdSection:
    """MegaFBD plugin: heterogeneous-cluster placement model."""

    n_virtual: int = 8             # virtual ranks to place
    n_devices: int = 8             # physical devices in the speed model
    slow_frac: float = 0.5         # fraction of devices that are slow
    slow_speed: float = 0.4        # their relative speed


@dataclass
class DppSection:
    """MegaDPP plugin: pipeline-planning topology + budget."""

    dp: int = 1
    pp: int = 4
    tp: int = 1
    n_micro: int = 8
    n_chunks: int = 2
    memory_cap_gib: float = 8.0


@dataclass
class ObsSection:
    """Live telemetry (the ``metrics`` plugin + per-rank event synthesis).

    ``metrics_out`` streams flat JSONL samples every ``every`` steps;
    ``prom_out`` writes a Prometheus text-format snapshot at finalize.
    ``peak_tflops`` > 0 turns the measured model-flops/s series into an MFU
    estimate.  ``rank_events`` synthesizes per-DP-rank fwd/bwd/all-reduce
    events (topology ``dp``/``pp``/``tp``) into the trace each step — what
    the online detector analyses on a single-host run — and ``slow_rank``
    >= 0 additionally *induces* a live straggler at ``slow_factor`` speed
    (simkit's ``compute_slowdown`` applied to the real loop).
    """

    metrics_out: str = ""          # JSONL time-series path ("" = off)
    prom_out: str = ""             # Prometheus text snapshot path ("" = off)
    every: int = 1                 # sample/export cadence, in steps
    peak_tflops: float = 0.0       # hardware peak for MFU (0 = no estimate)
    rank_events: bool = False      # synthesize per-rank events each step
    dp: int = 2                    # synthesized topology
    pp: int = 1
    tp: int = 1
    slow_rank: int = -1            # induce a straggler on this rank (< 0 off)
    slow_factor: float = 0.5       # its relative speed (0.5 = half)


@dataclass
class FtChaosSection:
    """Declarative chaos injection (``--set ft.chaos.*``; see
    :class:`repro.ft.chaos.ChaosSpec`).  All defaults mean "nothing fails"."""

    crash_at_step: int = -1        # raise a real crash at this step (< 0 off)
    nan_at_step: int = -1          # poison this step's batch to a NaN loss
    slow_rank_from: int = -1       # downclock slow_rank from this step on
    slow_rank: int = 1
    slow_factor: float = 0.5       # its relative speed (0.5 = half)
    degrade_link: str = ""         # directed DP link "src-dst" ("" = healthy)
    degrade_factor: float = 0.25   # its relative bandwidth


@dataclass
class FtSection:
    """Fault-tolerance controller (the ``ft`` module plugin).

    Subscribes to the scan plugin's online ``DetectionUpdate``s, decides via
    ``MitigationPolicy`` (thresholds below), and executes: REPLAN switches
    on int8 gradient compression for a degraded DP link or re-resolves the
    MegaDPP schedule around a slow pipeline stage; EXCLUDE_RESTART rolls
    back through the Checkpointer.  The loop itself becomes supervised:
    crash -> restore-latest -> resume (bounded by ``max_restarts``), with
    in-band NaN/grad-spike guards.
    """

    max_restarts: int = 3
    backoff_s: float = 0.0         # restart backoff base (doubles per restart)
    guard_nan: bool = True         # nonfinite loss/grad -> guard_action
    guard_spike: float = 0.0       # >0: grad_norm > this x running median
    guard_action: str = "rollback"  # rollback | skip
    slow_frac_soft: float = 0.3    # policy: slow-op fraction -> REPLAN
    slow_frac_hard: float = 0.7    # policy: -> EXCLUDE_RESTART
    min_evidence: int = 8          # collective instances before acting
    chaos: FtChaosSection = field(default_factory=FtChaosSection)


@dataclass
class TraceSection:
    """Offline MegaScan workload: simulate (or load) -> align -> detect."""

    load: str = ""                 # JSONL trace to analyse ("" = simulate)
    detect: str = ""               # trace file (chrome JSON or JSONL) to
                                   # align + detect + summarize (--detect)
    dp: int = 2
    pp: int = 2
    tp: int = 2
    n_micro: int = 8
    n_iters: int = 3
    slow_rank: int = 5             # simulated ground truth
    slow_factor: float = 0.5
    out: str = ""                  # directory for trace.json + diagnosis.json


@dataclass
class RuntimeSection:
    """Cross-workload runtime plumbing (``runtime.*``).

    ``compile_cache`` names a directory for the persistent executable cache
    (:class:`repro.core.compile_cache.CompileCache`): AOT-compiled step
    executables — train steps, decode/prefill/verify/chunk serving buckets —
    are serialized there keyed on (model config, mesh, bucket shapes,
    donation signature), so a restarted process skips XLA compilation
    entirely on unchanged configs.  Empty = no persistence (in-process jit
    caching only).
    """

    compile_cache: str = ""        # "" = no on-disk executable cache


@dataclass
class DryrunSection:
    """Compile-analysis workload (lower/compile cells on production meshes)."""

    shape: str = ""
    all: bool = False
    multi_pod: str = "off"         # off | on | both
    profile: str = ""
    grad_accum: int = 1
    out: str = "artifacts/dryrun"
    save_hlo: bool = False
    host_mesh: bool = False        # small host mesh instead of 16x16 (smoke)


@dataclass
class RunConfig:
    """One workload run: arch + mesh + module toggles + per-section knobs."""

    workload: str = "train"
    arch: str = ""
    smoke: bool = False
    seed: int = 0
    modules: tuple[str, ...] = ("scan",)
    mesh: str = "auto"             # auto | auto-mp | host | pod1 | pod2
    trace_out: str = ""            # chrome-trace export path (any workload)
    parallel: ParallelSection = field(default_factory=ParallelSection)
    train: TrainSection = field(default_factory=TrainSection)
    serve: ServeSection = field(default_factory=ServeSection)
    router: RouterSection = field(default_factory=RouterSection)
    scan: ScanSection = field(default_factory=ScanSection)
    obs: ObsSection = field(default_factory=ObsSection)
    ft: FtSection = field(default_factory=FtSection)
    scope: ScopeSection = field(default_factory=ScopeSection)
    fbd: FbdSection = field(default_factory=FbdSection)
    dpp: DppSection = field(default_factory=DppSection)
    trace: TraceSection = field(default_factory=TraceSection)
    dryrun: DryrunSection = field(default_factory=DryrunSection)
    runtime: RuntimeSection = field(default_factory=RuntimeSection)

    @classmethod
    def for_workload(cls, workload: str, **top) -> "RunConfig":
        """Defaults + workload defaults + keyword top-level fields."""
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; one of {WORKLOADS}")
        cfg = cls(workload=workload)
        for path, value in WORKLOAD_DEFAULTS.get(workload, {}).items():
            set_by_path(cfg, path, value)
        for k, v in top.items():
            set_by_path(cfg, k, v)
        return cfg

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


#: Layer 2: per-workload defaults applied over the dataclass defaults.
#: Tracing *and* live metrics are on by default for every live workload —
#: the repo's documented unification of the old split (train silently off,
#: serve on), extended by the observability PR: the ``metrics`` plugin owns
#: the session MetricsRegistry the instrumented loops publish into.
WORKLOAD_DEFAULTS: dict[str, dict[str, object]] = {
    "train": {"modules": ("scan", "metrics")},
    "serve": {"modules": ("scan", "metrics")},
    "trace": {"modules": ()},      # the workload *is* MegaScan, offline
    "dryrun": {"modules": ()},     # compile analysis: nothing to attach to
}


# ---------------------------------------------------------------------------
# layering machinery
# ---------------------------------------------------------------------------


def _resolve_types(obj) -> dict[str, type]:
    # annotations are strings under `from __future__ import annotations`
    return typing.get_type_hints(type(obj))


def coerce(value, target: type):
    """Coerce a string (or JSON scalar/list) to an annotated field type."""
    origin = typing.get_origin(target)
    if origin is tuple:
        items = value.split(",") if isinstance(value, str) else list(value)
        items = [x for x in items if x != ""] if isinstance(value, str) else items
        elem = (typing.get_args(target) or (str,))[0]
        return tuple(coerce(x, elem) for x in items)
    if target is bool:
        if isinstance(value, bool):
            return value
        s = str(value).strip().lower()
        if s in ("1", "true", "yes", "on"):
            return True
        if s in ("0", "false", "no", "off"):
            return False
        raise ValueError(f"cannot parse {value!r} as bool")
    if target in (int, float, str):
        return target(value)
    return value


def set_by_path(cfg: RunConfig, path: str, value) -> None:
    """Set ``a.b`` on a RunConfig, coercing ``value`` to the field's type.

    Unknown sections/fields raise ``KeyError`` — a typo in ``--set`` fails
    loudly instead of silently configuring nothing.
    """
    obj = cfg
    parts = path.split(".")
    for p in parts[:-1]:
        types = _resolve_types(obj)
        if p not in types or not dataclasses.is_dataclass(types[p]):
            raise KeyError(f"unknown config section {p!r} in {path!r}")
        obj = getattr(obj, p)
    leaf = parts[-1]
    types = _resolve_types(obj)
    if leaf not in types:
        raise KeyError(
            f"unknown config field {path!r}; "
            f"{type(obj).__name__} has {sorted(types)}"
        )
    if dataclasses.is_dataclass(types[leaf]):
        raise KeyError(f"{path!r} is a section, not a field")
    setattr(obj, leaf, coerce(value, types[leaf]))


def apply_dict(cfg: RunConfig, data: dict, prefix: str = "") -> None:
    """Apply a nested dict (e.g. a parsed JSON config file) as overrides."""
    for k, v in data.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            apply_dict(cfg, v, prefix=f"{path}.")
        else:
            set_by_path(cfg, path, v)


def apply_sets(cfg: RunConfig, sets: list[str] | tuple[str, ...]) -> None:
    """Apply ``key=value`` dotted overrides (the ``--set`` flag)."""
    for s in sets:
        if "=" not in s:
            raise ValueError(f"--set expects key=value, got {s!r}")
        key, _, val = s.partition("=")
        set_by_path(cfg, key.strip(), val.strip())


def parse_modules(spec: str | tuple[str, ...]) -> tuple[str, ...]:
    """Parse a ``--modules`` list; ``none``/empty disables everything."""
    if isinstance(spec, str):
        spec = tuple(x.strip() for x in spec.split(",") if x.strip())
    mods = tuple(spec)
    if mods in (("none",), ("off",)):
        return ()
    from repro.app.plugins import PLUGIN_REGISTRY  # local: keeps config jax-free

    for m in mods:
        if m not in PLUGIN_REGISTRY:
            raise ValueError(
                f"unknown module {m!r}; registered: {sorted(PLUGIN_REGISTRY)}"
            )
    return mods


def build_run_config(
    workload: str,
    *,
    config_json: str | None = None,
    sets: list[str] | tuple[str, ...] = (),
    **top,
) -> RunConfig:
    """Full layering pipeline: defaults -> workload -> JSON -> ``--set`` ->
    explicit keyword (CLI flag) overrides."""
    cfg = RunConfig.for_workload(workload)
    if config_json:
        apply_dict(cfg, json.loads(Path(config_json).read_text()))
    apply_sets(cfg, sets)
    for k, v in top.items():
        if k == "modules":
            v = parse_modules(v)
        set_by_path(cfg, k.replace("__", "."), v)
    cfg.modules = parse_modules(cfg.modules)
    return cfg
