"""`python -m repro` — the single CLI for every workload.

    python -m repro train  --arch qwen2-0.5b --smoke --steps 20
    python -m repro serve  --arch qwen2-0.5b --smoke --continuous
    python -m repro trace  --out artifacts/megascan
    python -m repro dryrun --arch qwen3-14b --shape train_4k

Shared surface (every subcommand): ``--modules scan,metrics,scope,dpp,fbd``
toggles the module plugins (``none`` disables all), ``--set a.b=v``
applies dotted typed overrides onto the :class:`repro.app.config.RunConfig`,
``--config run.json`` layers a JSON file underneath them, and
``--trace-out`` exports the run's MegaScan events as a chrome trace —
uniformly, since serving and training emit the same ``TraceEvent``s.

Layering order (most specific last): dataclass defaults -> workload
defaults -> ``--config`` JSON -> ``--set`` overrides -> explicit flags.

This module imports neither jax nor any model code at import time: the
``dryrun`` workload must set ``XLA_FLAGS`` (via importing
``repro.launch.dryrun``) before the backend initialises.
"""

from __future__ import annotations

import argparse
import json
import logging
import sys

from repro.app.config import build_run_config

# (flag, dest RunConfig path, argparse kwargs) — only flags the user actually
# passed are applied (argparse.SUPPRESS), so they override --config/--set
_SHARED = [
    ("--arch", "arch", dict(type=str)),
    ("--smoke", "smoke", dict(action="store_true")),
    ("--seed", "seed", dict(type=int)),
    ("--modules", "modules", dict(
        type=str, metavar="M1,M2",
        help="module plugins to attach (scan,metrics,ft,scope,fbd,dpp; "
             "'none' = off)")),
    ("--mesh", "mesh", dict(
        choices=("auto", "auto-mp", "host", "pod1", "pod2"))),
    ("--trace-out", "trace_out", dict(
        type=str, help="export this run's TraceEvents as a chrome trace "
                       "(a .jsonl path streams instead; non-.jsonl paths "
                       "also stream a .jsonl sidecar while running)")),
    ("--metrics-out", "obs.metrics_out", dict(
        type=str, help="stream the metrics registry as JSONL time series")),
    ("--compile-cache", "runtime.compile_cache", dict(
        type=str, metavar="DIR",
        help="persist AOT-compiled step executables under DIR, keyed on "
             "(model config, mesh, bucket, donation signature); a restarted "
             "process with the same config skips XLA compilation entirely")),
    ("--detect-online", "scan.detect_online", dict(
        action="store_true",
        help="run MegaScan's straggler detector over a sliding window of "
             "TraceEvents during the run (see --set scan.* thresholds)")),
]

_TRAIN = [
    ("--pp", "parallel.pp", dict(
        type=int, help="pipeline stages (>1 routes blocks through MegaDPP)")),
    ("--pp-schedule", "parallel.schedule", dict(
        choices=("1f1b", "dfc", "bfc", "wave"))),
    ("--n-micro", "parallel.n_micro", dict(
        type=int, help="pipeline microbatches per step (0 = 2*pp)")),
    ("--steps", "train.steps", dict(type=int)),
    ("--global-batch", "train.global_batch", dict(type=int)),
    ("--seq-len", "train.seq_len", dict(type=int)),
    ("--lr", "train.lr", dict(type=float)),
    ("--schedule", "train.schedule", dict(choices=("cosine", "wsd", "constant"))),
    ("--grad-accum", "train.grad_accum", dict(type=int)),
    ("--ckpt-dir", "train.ckpt_dir", dict(type=str)),
    ("--ckpt-every", "train.ckpt_every", dict(type=int)),
    ("--max-restarts", "ft.max_restarts", dict(
        type=int, help="bounded restarts for the supervised loop "
                       "(the ft module; see --set ft.* / ft.chaos.*)")),
    ("--chaos-crash-at", "ft.chaos.crash_at_step", dict(
        type=int, metavar="STEP",
        help="chaos: inject a real crash at this step (needs --ckpt-dir "
             "and the ft module; one of the --set ft.chaos.* knobs)")),
    ("--multi-pod", "mesh", dict(action="store_const", const="auto-mp")),
]

_SERVE = [
    ("--continuous", "serve.continuous", dict(action="store_true")),
    ("--batch", "serve.batch", dict(type=int)),
    ("--prompt-len", "serve.prompt_len", dict(type=int)),
    ("--max-new", "serve.max_new", dict(type=int)),
    ("--temperature", "serve.temperature", dict(type=float)),
    ("--requests", "serve.requests", dict(type=int)),
    ("--rate", "serve.rate", dict(type=float)),
    ("--slots", "serve.slots", dict(type=int)),
    ("--block-size", "serve.block_size", dict(type=int)),
    ("--num-blocks", "serve.num_blocks", dict(type=int)),
    ("--prompt-lens", "serve.prompt_lens", dict(type=str)),
    ("--decode-path", "serve.decode_path",
     dict(choices=("auto", "paged", "gathered"))),
    ("--prefill-path", "serve.prefill_path",
     dict(choices=("auto", "flash", "dense"),
          help="flash = the paged flash-prefill kernel (auto picks it "
               "where the Pallas kernel is real; dense one-shot otherwise)")),
    ("--spec-decode", "serve.spec_decode", dict(action="store_true")),
    ("--spec-k", "serve.spec_k", dict(type=int)),
    ("--drafter", "serve.drafter", dict(choices=("ngram", "random"))),
    ("--chunked-prefill", "serve.chunked_prefill", dict(
        action="store_true",
        help="stream long prompts chunk-by-chunk so decode interleaves")),
    ("--chunk-len", "serve.chunk_len", dict(
        type=int, help="prefill chunk length (0 = 2*block_size; must be a "
                       "multiple of block_size)")),
    ("--traffic", "serve.traffic", dict(
        choices=("poisson", "bursty", "diurnal"),
        help="arrival process for --continuous workloads")),
    ("--replicas", "router.replicas", dict(
        type=int, help="MegaRoute: front N engine replicas with a router")),
    ("--router-policy", "router.policy", dict(
        choices=("round_robin", "least_kv", "jsq"))),
    ("--prefill-replicas", "router.prefill_replicas", dict(
        type=int, help="disaggregate: first K replicas prefill-only, KV "
                       "migrates to the decode tier after the first token")),
    ("--slo-ttft", "router.slo_ttft_s", dict(
        type=float, help="SLO-aware admission: shed/redirect requests whose "
                         "estimated TTFT exceeds this (0 = off)")),
]

_TRACE = [
    ("--load", "trace.load", dict(type=str, help="analyse a JSONL trace")),
    ("--detect", "trace.detect", dict(
        type=str, metavar="TRACE",
        help="load a saved trace (chrome .json or streamed .jsonl), run "
             "align + detect, print the diagnosis summary")),
    ("--out", "trace.out", dict(type=str)),
    ("--slow-rank", "trace.slow_rank", dict(type=int)),
    ("--slow-factor", "trace.slow_factor", dict(type=float)),
    ("--dp", "trace.dp", dict(type=int)),
    ("--pp", "trace.pp", dict(type=int)),
    ("--tp", "trace.tp", dict(type=int)),
    ("--n-micro", "trace.n_micro", dict(type=int)),
    ("--iters", "trace.n_iters", dict(type=int)),
]

_DRYRUN = [
    ("--shape", "dryrun.shape", dict(type=str)),
    ("--all", "dryrun.all", dict(action="store_true")),
    ("--multi-pod", "dryrun.multi_pod", dict(choices=("off", "on", "both"))),
    ("--profile", "dryrun.profile", dict(type=str)),
    ("--grad-accum", "dryrun.grad_accum", dict(type=int)),
    ("--out", "dryrun.out", dict(type=str)),
    ("--save-hlo", "dryrun.save_hlo", dict(action="store_true")),
    ("--host-mesh", "dryrun.host_mesh", dict(
        action="store_true",
        help="compile on a small host mesh (CPU smoke) instead of 16x16")),
]

_WORKLOAD_FLAGS = {"train": _TRAIN, "serve": _SERVE, "trace": _TRACE,
                   "dryrun": _DRYRUN}


def _add_flags(ap: argparse.ArgumentParser, flags) -> None:
    # the dest encodes the RunConfig path ("train.steps" -> "train__steps");
    # build_run_config reverses the mapping
    for flag, path, kw in flags:
        ap.add_argument(flag, dest=path.replace(".", "__"),
                        default=argparse.SUPPRESS, **kw)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="MegatronApp repro: one CLI for every workload; "
                    "module plugins toggle with --modules.",
    )
    sub = ap.add_subparsers(dest="workload", required=True)
    for wl, flags in _WORKLOAD_FLAGS.items():
        p = sub.add_parser(wl)
        p.add_argument("--config", default=None,
                       help="JSON RunConfig overlay (nested sections)")
        p.add_argument("--set", dest="sets", action="append", default=[],
                       metavar="KEY=VALUE",
                       help="dotted typed override, e.g. serve.spec_k=6")
        _add_flags(p, _SHARED)
        _add_flags(p, flags)
    return ap


def _parse(argv) -> tuple[str, "RunConfig"]:
    args = build_parser().parse_args(argv)
    workload = args.workload
    flag_overrides = {
        k: v for k, v in vars(args).items()
        if k not in ("workload", "config", "sets")
    }
    cfg = build_run_config(
        workload, config_json=args.config, sets=args.sets, **flag_overrides
    )
    return workload, cfg


def _print_results(results: dict) -> None:
    # plugin reports + workload metrics, JSON-ish, stable ordering
    drop = ("history",)  # printed by the workload itself
    view = {k: v for k, v in results.items() if k not in drop}
    if view:
        print(json.dumps(view, indent=1, default=str))


def run(argv: list[str]) -> dict:
    """Parse + run; returns ``session.results`` (tests use this directly)."""
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    workload, cfg = _parse(argv)

    if workload == "dryrun":
        # MUST precede any jax backend init: sets XLA_FLAGS (forced host
        # device count + SPMD dump dir) at module import
        import repro.launch.dryrun  # noqa: F401

    if workload == "train" and cfg.parallel.pp > 1:
        # pipeline meshes need pp*dp*tp devices; on a CPU-only host, force
        # the host platform to expose that many (inert on real fleets, and
        # a no-op if the user already set the flag).  Like dryrun, this must
        # precede backend init — nothing above imports jax.
        import os

        world = cfg.parallel.pp * cfg.parallel.dp * cfg.parallel.tp
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={world}"
            ).strip()

    from repro.app.session import Session

    try:
        session = Session(cfg)
        out = session.run()
    except (ValueError, KeyError) as e:
        # config/workload guards (unknown arch, wrong arch family, bad knob
        # combos) exit cleanly from the CLI instead of dumping a traceback
        msg = e.args[0] if e.args and isinstance(e.args[0], str) else str(e)
        raise SystemExit(msg) from e
    if workload == "dryrun":
        failed = [t for t, v in out.items() if "error" in v]
        if failed:
            raise SystemExit(f"{len(failed)} cell(s) failed: {failed}")

    if workload == "train":
        _, history = out
        for h in history:
            print(f"step {h['step']:>5}  loss {h['loss']:.4f}  "
                  f"lr {h.get('lr', 0):.2e}")
    elif workload == "serve":
        met = session.results.get("serve_metrics", {})
        if cfg.serve.continuous:
            outs, _ = out
            sc = session.results.get("serve_config", {})
            routed = sc.get("replicas", 1) > 1 or sc.get("policy")
            print(f"arch={session.model_cfg.name} continuous "
                  f"slots={sc.get('num_slots', cfg.serve.slots)} "
                  f"blocks={sc.get('num_blocks')}x{sc.get('block_size')} "
                  f"requests={len(outs)} "
                  f"decode_path={session.results.get('decode_path')}"
                  + (f" spec_k={cfg.serve.spec_k} drafter={cfg.serve.drafter}"
                     if cfg.serve.spec_decode else "")
                  + (f" replicas={sc.get('replicas')}"
                     f" policy={sc.get('policy')}" if routed else "")
                  + (f" traffic={sc.get('traffic')}"
                     if sc.get("traffic", "poisson") != "poisson" else ""))
            keys = ["generated_tokens", "wall_s", "tokens_per_s",
                    "ttft_p50_s", "ttft_p99_s", "queue_wait_p50_s",
                    "queue_wait_p99_s", "latency_p50_s",
                    "latency_p99_s", "preemptions", "steps"]
            if routed:
                keys += ["shed", "shed_rate", "redirects", "migrations",
                         "placed_per_replica", "replica_tokens", "load_skew"]
            if cfg.serve.spec_decode:
                keys += ["spec_proposed", "spec_accepted", "spec_accept_rate"]
            for k in keys:
                v = met.get(k)
                print(f"  {k:16s} {v:.4f}" if isinstance(v, float)
                      else f"  {k:16s} {v}")
            for rid in list(outs)[:2]:
                print(f"  req {rid}: {outs[rid][:12]}...")
        else:
            gen, _ = out
            s = cfg.serve
            print(f"arch={cfg.arch} batch={s.batch} prompt={s.prompt_len} "
                  f"new={s.max_new}")
            print(f"prefill: {met['prefill_s']*1e3:.1f} ms "
                  f"({met['prefill_tok_s']:.0f} tok/s)")
            print(f"decode : {met['decode_s']*1e3:.1f} ms "
                  f"({met['decode_tok_s']:.0f} tok/s)")
            for b in range(min(s.batch, 2)):
                print(f"  seq {b}: {[int(t) for t in gen[b][:12]]}...")
    elif workload == "trace":
        print(json.dumps(session.results.get("diagnosis", {}), indent=1))
        if "truth" in session.results:
            t = session.results["truth"]
            print(f"slow-rank detection: "
                  f"{'CORRECT' if t['detected'] else 'MISMATCH'} "
                  f"(truth={t['slow_ranks']})")
    _print_results({k: v for k, v in session.results.items()
                    if k in ("scan", "metrics", "ft", "scope", "fbd", "dpp",
                             "parallel", "trace_out")})
    return session.results


def main(argv: list[str] | None = None) -> None:
    run(sys.argv[1:] if argv is None else list(argv))


if __name__ == "__main__":
    main()
