"""``python -m repro`` — see :mod:`repro.app.cli`."""

from repro.app.cli import main

if __name__ == "__main__":
    main()
