"""OnlineDetector: MegaScan's 3-stage diagnosis over a sliding window.

The offline pipeline (``trace`` workload) is gather -> align -> detect,
after the run.  The online detector runs the identical analysis
incrementally: each workload step pushes its freshly-emitted
``TraceEvent``s; every ``every``-th push the window is re-aligned
(``align_clocks``), collectives are re-matched (``reconstruct_collectives``
runs inside ``detect``), and the 3-stage detector produces a
:class:`~repro.core.tracing.detect.Diagnosis`.  Only the *delta* against
the previous verdict is returned — a rank turning slow, a link degrading,
a recovery — which is what a failover controller (or a human watching the
trace's instant events) actually acts on.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core.simkit.workload import Topology
from repro.core.tracing.align import align_clocks, apply_alignment
from repro.core.tracing.detect import Diagnosis, detect
from repro.core.tracing.events import TraceEvent

_ANALYZED_KINDS = ("compute", "coll", "p2p")


@dataclass
class DetectionUpdate:
    """One online verdict: the full diagnosis plus what changed since the
    previous one (the actionable part)."""

    step: int
    diagnosis: Diagnosis
    new_slow_ranks: list[int] = field(default_factory=list)
    cleared_slow_ranks: list[int] = field(default_factory=list)
    new_degraded_links: list[tuple[int, int]] = field(default_factory=list)

    @property
    def changed(self) -> bool:
        return bool(
            self.new_slow_ranks or self.cleared_slow_ranks
            or self.new_degraded_links
        )


class OnlineDetector:
    """Sliding-window streaming wrapper around MegaScan's ``detect()``.

    ``push(events)`` is called once per workload step with that step's
    events; a detection pass runs every ``every`` pushes over the last
    ``window`` steps.  ``thresholds`` feeds through to ``detect()``
    (``slow_ratio`` / ``candidate_frac`` / ``skew_margin`` / ``late_frac``
    / ``degrade_ratio``).  ``align=True`` (default) re-aligns the window's
    clocks before detecting — a no-op for single-clock hosts, required for
    real per-rank clocks.
    """

    def __init__(
        self,
        topo: Topology,
        *,
        every: int = 8,
        window: int = 64,
        min_events: int = 16,
        align: bool = True,
        thresholds: dict | None = None,
    ):
        if every < 1 or window < 1:
            raise ValueError(f"every/window must be >= 1, got {every}/{window}")
        self.topo = topo
        self.every = every
        self.min_events = min_events
        self.align = align
        self.thresholds = dict(thresholds or {})
        self._window: deque[list[TraceEvent]] = deque(maxlen=window)
        self._step = 0
        self._slow: set[int] = set()
        self._links: set[tuple[int, int]] = set()
        #: one ``Diagnosis.summary()`` (+ step) per completed detection pass
        self.history: list[dict] = []

    def push(self, events: list[TraceEvent]) -> DetectionUpdate | None:
        """Feed one step's events; returns an update when a pass ran."""
        self._step += 1
        self._window.append(
            [e for e in events if e.kind in _ANALYZED_KINDS]
        )
        if self._step % self.every:
            return None
        flat = [e for step_events in self._window for e in step_events]
        if len(flat) < self.min_events:
            return None
        if self.align:
            flat = apply_alignment(flat, align_clocks(flat))
        diag = detect(flat, self.topo, **self.thresholds)
        slow = set(diag.slow_ranks)
        links = {tuple(l) for l in diag.degraded_links}
        update = DetectionUpdate(
            step=self._step,
            diagnosis=diag,
            new_slow_ranks=sorted(slow - self._slow),
            cleared_slow_ranks=sorted(self._slow - slow),
            new_degraded_links=sorted(links - self._links),
        )
        self._slow, self._links = slow, links
        self.history.append({"step": self._step, **diag.summary()})
        return update
