"""Per-rank event synthesis for single-host runs (+ induced stragglers).

On a GPU cluster every rank runs its own MegaScan tracer, so the online
detector sees genuinely per-rank timings.  A single-host CPU run executes
one SPMD program — there is exactly one wall clock — so, like
``core.dpp.executor.emit_pipeline_events`` does for pipeline bubble
structure, this module *scales a model of the step into the measured
wall*: per data-parallel rank, a fwd + bwd compute pair followed by the
gradient all-reduce that closes the step.

The straggler part is real, not simulated: with ``slow_rank >= 0`` the
train loop sleeps inside the step scope (simkit's ``compute_slowdown``
fault, applied to the live run), and the measured excess is attributed to
the slow rank's compute here — its all-reduce then *starts* late by
exactly that excess, which is the signature MegaScan's stage 1 + stage 2
confirm on.  End-to-end, a slowed rank in a host-mesh run produces an
``OnlineDetector`` diagnosis naming it while the run is still going.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.simkit.workload import Topology
from repro.core.tracing.events import TraceEvent

# healthy step budget: fwd 30%, bwd 50%, gradient all-reduce the last 20%
_FWD_FRAC, _BWD_FRAC = 0.3, 0.5
# nominal P2P ring payload per step (only the bytes/duration *ratio* feeds
# stage 3's effective-bandwidth comparison)
_P2P_BYTES = 1 << 20


@dataclass(frozen=True)
class RankEventSpec:
    """Topology + straggler model for synthesized per-rank events.

    ``slow_rank`` / ``slow_factor`` mirror simkit's ``FaultModel.
    compute_slowdown`` semantics: the rank runs at ``slow_factor`` of full
    speed (0.5 = half), ``slow_rank < 0`` disables induction.
    """

    dp: int = 2
    pp: int = 1
    tp: int = 1
    slow_rank: int = -1
    slow_factor: float = 0.5
    # degraded directed link (FaultModel.link_slowdown semantics): when set,
    # a ring of P2P sends is synthesized each step with this edge running at
    # ``degrade_factor`` of the healthy bandwidth — stage 3's signature
    degrade_link: tuple[int, int] | None = None
    degrade_factor: float = 0.25

    @property
    def world(self) -> int:
        return self.dp * self.pp * self.tp

    def topology(self) -> Topology:
        return Topology(dp=self.dp, pp=self.pp, tp=self.tp)

    def extra_seconds(self, base: float) -> float:
        """Sleep that stretches a ``base``-seconds step to ``base /
        slow_factor`` — the live analogue of a downclocked rank."""
        if self.slow_rank < 0 or not 0.0 < self.slow_factor < 1.0:
            return 0.0
        return base * (1.0 / self.slow_factor - 1.0)


def emit_rank_events(
    events: list[TraceEvent],
    spec: RankEventSpec,
    *,
    ts: float,
    wall: float,
    extra: float = 0.0,
    step: int = 0,
) -> None:
    """Append one step's per-rank fwd/bwd/all-reduce events into ``events``.

    ``[ts, ts + wall]`` is the measured step window; ``extra`` of it was
    induced straggler sleep.  Healthy ranks split ``wall - extra`` into the
    canonical fwd/bwd/all-reduce budget; the slow rank's compute stretches
    by ``extra`` (split pro rata over fwd/bwd) and its all-reduce — which
    every rank finishes together, at ``ts + wall`` — therefore starts late.
    """
    base = max(wall - extra, 1e-9)
    group = tuple(range(spec.world))
    fwd, bwd = _FWD_FRAC * base, _BWD_FRAC * base
    compute = fwd + bwd
    for r in range(spec.world):
        e_r = extra if r == spec.slow_rank else 0.0
        f_r = fwd + e_r * (fwd / compute)
        b_r = bwd + e_r * (bwd / compute)
        events.append(TraceEvent(
            "fwd", r, ts, f_r, "compute",
            {"op": "fwd", "mb": step, "phase": "F"},
        ))
        events.append(TraceEvent(
            "bwd", r, ts + f_r, b_r, "compute",
            {"op": "bwd", "mb": step, "phase": "B"},
        ))
        start = ts + f_r + b_r
        events.append(TraceEvent(
            "allreduce_grads", r, start, max(ts + wall - start, 1e-9), "coll",
            {"op": "allreduce", "group": group, "mb": step, "phase": "G"},
        ))
    if spec.degrade_link is not None and spec.world >= 2:
        # a ring of activation-sized P2P sends, concurrent with compute:
        # healthy edges move _P2P_BYTES in 10% of the step, the degraded
        # edge takes 1/degrade_factor as long for the same payload — the
        # effective-bandwidth dip stage 3 flags against the ring median
        healthy_dur = 0.1 * base
        for r in range(spec.world):
            dst = (r + 1) % spec.world
            slow = 1.0 / spec.degrade_factor if (r, dst) == spec.degrade_link else 1.0
            events.append(TraceEvent(
                "p2p_send", r, ts, healthy_dur * slow, "p2p",
                {"dir": "send", "peer": dst, "bytes": _P2P_BYTES, "mb": step},
            ))
