"""Metrics primitives: counters, gauges, and streaming-quantile histograms.

Everything here is host-side pure python — safe to update from inside the
training loop's dispatch path (no jax imports, no allocation beyond a few
floats per metric).  Histograms estimate P50/P95/P99 with the P² algorithm
(Jain & Chlamtac, CACM 1985): five markers per quantile, O(1) per
observation, no sample buffer to grow over a long run.
"""

from __future__ import annotations

import math


class P2Quantile:
    """Streaming estimate of one quantile via the P² algorithm.

    Keeps 5 marker heights whose positions track the desired quantile's
    ideal rank; markers move by parabolic (fallback linear) interpolation.
    Exact for the first 5 observations, O(1) memory and time after.
    """

    __slots__ = ("q", "count", "_h", "_n", "_d", "_dn")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self.count = 0
        self._h: list[float] = []                      # marker heights
        self._n = [0.0, 1.0, 2.0, 3.0, 4.0]            # marker positions
        self._d = [0.0, 2 * q, 4 * q, 2 + 2 * q, 4.0]  # desired positions
        self._dn = [0.0, q / 2, q, (1 + q) / 2, 1.0]   # desired increments

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        h = self._h
        if len(h) < 5:
            h.append(x)
            h.sort()
            return
        n, d = self._n, self._d
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            d[i] += self._dn[i]
        for i in (1, 2, 3):
            diff = d[i] - n[i]
            if (diff >= 1.0 and n[i + 1] - n[i] > 1.0) or (
                diff <= -1.0 and n[i - 1] - n[i] < -1.0
            ):
                s = 1.0 if diff > 0 else -1.0
                hp = h[i] + s / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + s) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1]) / (n[i] - n[i - 1])
                )
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:  # parabolic prediction left the bracket: move linearly
                    j = i + int(s)
                    h[i] = h[i] + s * (h[j] - h[i]) / (n[j] - n[i])
                n[i] += s

    @property
    def value(self) -> float:
        if not self._h:
            return float("nan")
        if self.count < 5:  # still exact: nearest rank over what we have
            xs = sorted(self._h)
            return xs[min(len(xs) - 1, round(self.q * (len(xs) - 1)))]
        return self._h[2]


class Counter:
    """Monotonically-increasing total (events, tokens, preemptions)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        self.value += float(n)


class Gauge:
    """Last-written value (queue depth, occupancy, current lr)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


_QUANTILES = (0.5, 0.95, 0.99)
_QLABEL = {0.5: "p50", 0.95: "p95", 0.99: "p99"}


class Histogram:
    """Streaming distribution: count/sum/min/max + P² P50/P95/P99."""

    __slots__ = ("count", "sum", "min", "max", "_q")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._q = {q: P2Quantile(q) for q in _QUANTILES}

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for est in self._q.values():
            est.observe(v)

    def quantile(self, q: float) -> float:
        return self._q[q].value

    def stats(self) -> dict[str, float]:
        if not self.count:
            return {"count": 0}
        out = {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
        }
        for q, est in self._q.items():
            out[_QLABEL[q]] = est.value
        return out


class MetricsRegistry:
    """Named metrics, get-or-create: ``reg.histogram("train.step_time_s")``.

    A name is bound to one metric type for the registry's lifetime —
    re-requesting it as a different type raises, so a typo'd publisher
    fails loudly instead of splitting a series.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, "
                f"requested as {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    def kind_of(self, name: str) -> str:
        return type(self._metrics[name]).__name__.lower()

    def snapshot(self) -> dict[str, float | dict[str, float]]:
        """Point-in-time view: scalars for counters/gauges, stats dicts for
        histograms; sorted by name so exports are stable."""
        out: dict[str, float | dict[str, float]] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.stats() if isinstance(m, Histogram) else m.value
        return out
