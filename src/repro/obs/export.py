"""Exporters: JSONL time series, Prometheus text format, chrome counters.

Three sinks for one :class:`repro.obs.metrics.MetricsRegistry`:

* :class:`JsonlExporter` — one flat JSON object per sample, flushed per
  write, so a mid-run crash still leaves every completed row on disk;
* :func:`prometheus_text` — the text exposition format (``# TYPE`` lines,
  ``quantile`` labels, ``_count``/``_sum`` for histograms) ready to drop
  behind any scrape endpoint or push gateway;
* :func:`counter_events` — chrome ``ph: "C"`` counter ``TraceEvent``s that
  merge into the shared ``--trace-out`` export, rendering metric tracks in
  Perfetto alongside the MegaScan spans.
"""

from __future__ import annotations

import json
import re
from pathlib import Path

from repro.core.tracing.events import TraceEvent
from repro.obs.metrics import MetricsRegistry

_PROM_SAFE = re.compile(r"[^a-zA-Z0-9_]")
_PROM_Q = {"p50": "0.5", "p95": "0.95", "p99": "0.99"}


def flatten_snapshot(snapshot: dict) -> dict[str, float]:
    """Flatten a registry snapshot to scalar series: histogram stats expand
    to ``name.p50`` / ``name.count`` / ... leaves."""
    flat: dict[str, float] = {}
    for name, v in snapshot.items():
        if isinstance(v, dict):
            for stat, sv in v.items():
                flat[f"{name}.{stat}"] = sv
        else:
            flat[name] = v
    return flat


class JsonlExporter:
    """Append-per-sample JSONL time series (crash-usable: flushed per row)."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "w")
        self.rows = 0

    def write(self, row: dict) -> None:
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()
        self.rows += 1

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def prometheus_text(registry: MetricsRegistry, prefix: str = "repro_") -> str:
    """Render a registry in the Prometheus text exposition format."""
    lines: list[str] = []
    for name, value in registry.snapshot().items():
        pn = prefix + _PROM_SAFE.sub("_", name)
        if isinstance(value, dict):  # histogram -> summary with quantiles
            lines.append(f"# TYPE {pn} summary")
            for label, q in _PROM_Q.items():
                if label in value:
                    lines.append(f'{pn}{{quantile="{q}"}} {value[label]}')
            lines.append(f"{pn}_count {value.get('count', 0)}")
            lines.append(f"{pn}_sum {value.get('sum', 0.0)}")
        else:
            kind = registry.kind_of(name)
            lines.append(f"# TYPE {pn} {'counter' if kind == 'counter' else 'gauge'}")
            lines.append(f"{pn} {value}")
    return "\n".join(lines) + "\n"


def counter_events(
    snapshot: dict, *, ts: float, rank: int = 0
) -> list[TraceEvent]:
    """One chrome counter ``TraceEvent`` per scalar series at time ``ts``.

    Accepts either a raw registry snapshot or an already-flat dict; all
    series flatten to ``kind="counter"`` events whose single ``value`` arg
    becomes the counter track's sample (``chrome.to_chrome`` maps the kind
    to ``ph: "C"``).  Histogram bookkeeping leaves (count/sum/min/max) are
    skipped — quantiles and means are the tracks worth plotting.
    """
    out = []
    for name, v in flatten_snapshot(snapshot).items():
        stat = name.rsplit(".", 1)[-1]
        if stat in ("count", "sum", "min", "max"):
            continue
        out.append(TraceEvent(name, rank, ts, 0.0, "counter", {"value": v}))
    return out


__all__ = [
    "JsonlExporter",
    "counter_events",
    "flatten_snapshot",
    "prometheus_text",
]
