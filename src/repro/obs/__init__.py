"""Live telemetry (the observability layer MegaScan feeds at runtime).

The paper's MegaScan is post-hoc: gather traces, align clocks, run the
3-stage detector.  Production trainers (MegaScale, TorchTitan) argue the
same analysis must run *during* the run — fast failover needs the diagnosis
before the job dies.  This package is that online layer:

* :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of counters, gauges
  and histograms with streaming P50/P95/P99 quantiles (the P² algorithm, so
  a million step times cost five floats, not a list);
* :mod:`repro.obs.detector` — :class:`OnlineDetector`, MegaScan's
  ``reconstruct_collectives`` + ``detect()`` over a sliding window of recent
  ``TraceEvent``s, emitting ``Diagnosis`` deltas while the workload runs;
* :mod:`repro.obs.export` — JSONL time series, Prometheus text format, and
  chrome ``counter`` events that merge into the shared ``--trace-out`` so
  metric tracks render in Perfetto alongside the spans;
* :mod:`repro.obs.inject` — per-rank event synthesis (and optional induced
  straggler) so a single-host run exercises the online detector end to end.

Wired into every workload through the ``metrics`` module plugin and the
``scan`` plugin's ``--detect-online`` hook (see ``repro.app.plugins``).
"""

from repro.obs.detector import DetectionUpdate, OnlineDetector
from repro.obs.export import (
    JsonlExporter,
    counter_events,
    flatten_snapshot,
    prometheus_text,
)
from repro.obs.inject import RankEventSpec, emit_rank_events
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry, P2Quantile

__all__ = [
    "Counter",
    "DetectionUpdate",
    "Gauge",
    "Histogram",
    "JsonlExporter",
    "MetricsRegistry",
    "OnlineDetector",
    "P2Quantile",
    "RankEventSpec",
    "counter_events",
    "emit_rank_events",
    "flatten_snapshot",
    "prometheus_text",
]
