"""Post-partitioning HLO analysis: collective-traffic accounting.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
optimized HLO text and sum per-device bytes moved for every collective op,
using ring-algorithm byte counts:

  all-gather        : result_bytes * (g-1)/g     (bytes received per device)
  reduce-scatter    : result_bytes * (g-1)       (operand = result * g)
  all-reduce        : 2 * bytes * (g-1)/g        (reduce-scatter + all-gather)
  all-to-all        : bytes * (g-1)/g
  collective-permute: bytes
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.1 = bf16[2,4096,896]{2,1,0} all-gather(bf16[...] %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype == "token" or dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    grad_ar_bytes: float = 0.0  # all-reduces on the backward (grad-sync) path

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def tpu_adjusted_bytes(self) -> float:
        """XLA:CPU lacks the reduce-scatter-creator pass TPU pipelines run, so
        gradient partial-sums compile to full-size all-reduce (2x bytes) here.
        Counting those at reduce-scatter cost gives the TPU-expected volume."""
        return self.total_bytes - self.grad_ar_bytes / 2

    def summary(self) -> dict:
        return {
            "counts": dict(self.counts),
            "bytes_by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
            "total_bytes": self.total_bytes,
            "grad_ar_bytes": float(self.grad_ar_bytes),
            "tpu_adjusted_bytes": float(self.tpu_adjusted_bytes),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        tuple_inner, dtype, dims, kind = m.groups()
        if "-done" in line.split("=", 1)[1][:120] and kind not in line:
            continue
        if tuple_inner is not None:
            size = sum(
                _shape_bytes(dt, dm) for dt, dm in _SHAPE_RE.findall(tuple_inner)
            )
        else:
            size = _shape_bytes(dtype, dims)

        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 1)

        if kind == "all-gather":
            moved = size * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = size * (g - 1)
        elif kind == "all-reduce":
            moved = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            moved = size * (g - 1) / g
        else:  # collective-permute
            moved = size
        stats.counts[kind] += 1
        stats.bytes_by_kind[kind] += moved
        if kind == "all-reduce" and "transpose(jvp" in line:
            stats.grad_ar_bytes += moved
    return stats


def op_histogram(hlo_text: str, ops: tuple[str, ...] = _COLLECTIVES) -> dict:
    out: dict[str, int] = defaultdict(int)
    for op in ops:
        out[op] = len(re.findall(rf"\b{op}(?:-start)?\(", hlo_text))
    return dict(out)
