"""Deprecated training launcher — use ``python -m repro train``.

This module is a thin shim kept so existing invocations keep working with
identical outputs (the flag set is unchanged; the new CLI accepts it
verbatim):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke

delegates to

    PYTHONPATH=src python -m repro train --arch qwen2-0.5b --smoke

Mesh selection, sharding-rule installation, chrome-trace export and module
toggles now live in ``repro.app`` (Session + plugins).
"""

from __future__ import annotations

import sys
import warnings


def main(argv: list[str] | None = None) -> None:
    warnings.warn(
        "python -m repro.launch.train is deprecated; use "
        "`python -m repro train` (same flags, plus --modules/--set)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.app.cli import main as cli_main

    cli_main(["train"] + (sys.argv[1:] if argv is None else list(argv)))


if __name__ == "__main__":
    main()
