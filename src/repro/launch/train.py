"""Production training launcher.

Builds the mesh (production 16x16 / 2x16x16 when the device fleet provides it,
else a host-device mesh), installs the architecture's sharding profile, and
runs the jitted train loop with checkpointing and MegaScan tracing.

    # on a real fleet (or with --xla_force_host_platform_device_count set):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --steps 100

    # CPU smoke (reduced config, host mesh):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 20
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config
from repro.core.tracing.chrome import save_chrome
from repro.core.tracing.tracer import Tracer
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.parallel.profiles import rules_for
from repro.parallel.sharding import axis_rules
from repro.train.loop import LoopConfig, train
from repro.train.optim import OptimizerConfig


def pick_mesh(multi_pod: bool):
    n = len(jax.devices())
    if multi_pod and n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    return make_host_mesh()


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=("cosine", "wsd", "constant"))
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = pick_mesh(args.multi_pod)
    rules = rules_for(cfg, "train")
    seq = args.seq_len or (128 if args.smoke else 4096)
    batch = args.global_batch or (8 if args.smoke else 256)
    # minicpm trains with WSD per its paper
    schedule = "wsd" if (cfg.name.startswith("minicpm") and args.schedule == "cosine") \
        else args.schedule

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    ocfg = OptimizerConfig(lr=args.lr, schedule=schedule,
                           warmup_steps=max(args.steps // 10, 5),
                           total_steps=args.steps)
    loop = LoopConfig(n_steps=args.steps, log_every=max(args.steps // 10, 1),
                      ckpt_dir=args.ckpt_dir, grad_accum=args.grad_accum)
    tracer = Tracer(0, enabled=True)

    print(f"arch={cfg.name} mesh={dict(mesh.shape)} tokens/step={batch * seq}")
    with mesh, axis_rules(mesh, rules):
        state, history = train(cfg, ocfg, data, loop, tracer=tracer)
    for h in history:
        print(f"step {h['step']:>5}  loss {h['loss']:.4f}  lr {h.get('lr', 0):.2e}")
    if args.trace_out:
        save_chrome(tracer.events, args.trace_out)
        print(f"trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
