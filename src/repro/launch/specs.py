"""ShapeDtypeStruct input stand-ins + sharding construction for every
(architecture x shape) dry-run cell.  No device allocation happens here."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import get_model
from repro.parallel.profiles import rules_for
from repro.parallel.sharding import AxisRules, logical_to_spec
from repro.serve.engine import cache_axes
from repro.train.train_step import TrainState, init_train_state, train_state_axes

_BATCH_AXES: dict[str, tuple[str | None, ...]] = {
    "tokens": ("batch", "seq_act"),
    "targets": ("batch", "seq_act"),
    "loss_mask": ("batch", "seq_act"),
    "embeds": ("batch", "seq_act", "embed_act"),
    "mrope_position_ids": (None, "batch", "seq_act"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    out = {"targets": _sds((B, S), jnp.int32)}
    if cfg.input_kind == "tokens":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:
        out["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            out["tokens"] = _sds((B, S), jnp.int32)
        if cfg.input_kind == "embeds_mrope":
            out["mrope_position_ids"] = _sds((3, B, S), jnp.int32)
    return out


def shardings_of(tree_specs: Any, tree_axes: Any, mesh: Mesh, rules: AxisRules) -> Any:
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, logical_to_spec(a, s.shape, mesh, rules)),
        tree_specs,
        tree_axes,
        is_leaf=lambda t: is_axes(t) or isinstance(t, jax.ShapeDtypeStruct),
    )


def batch_shardings(specs: dict, mesh: Mesh, rules: AxisRules) -> dict:
    return {
        k: NamedSharding(mesh, logical_to_spec(_BATCH_AXES[k], v.shape, mesh, rules))
        for k, v in specs.items()
    }


def probe_pair(cfg: ModelConfig) -> tuple[ModelConfig, ModelConfig, int]:
    """Two reduced-depth *unrolled* configs for HLO cost extrapolation.

    HLO cost analysis counts while-loop (lax.scan) bodies once, so the full
    compile under-reports repeated-layer FLOPs/bytes/collectives.  We compile
    two shallow unrolled probes and extrapolate affinely:

        corrected = f(small) + (n_units_full - 1) * (f(large) - f(small))

    where a "unit" is one scanned group (layer, moe layer, griffin pattern
    group, or encoder+decoder layer pair).
    """
    if cfg.family in ("dense", "rwkv6"):
        return (
            cfg.replace(num_layers=1, scan_unroll=True),
            cfg.replace(num_layers=2, scan_unroll=True),
            cfg.num_layers,
        )
    if cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        return (
            cfg.replace(num_layers=fk + 1, scan_unroll=True),
            cfg.replace(num_layers=fk + 2, scan_unroll=True),
            cfg.num_layers - fk,
        )
    if cfg.family == "griffin":
        pat = len(cfg.griffin.pattern)
        n_full, rem = divmod(cfg.num_layers, pat)
        return (
            cfg.replace(num_layers=pat + rem, scan_unroll=True),
            cfg.replace(num_layers=2 * pat + rem, scan_unroll=True),
            n_full,
        )
    if cfg.family == "encdec":
        return (
            cfg.replace(num_layers=1, num_encoder_layers=1, scan_unroll=True),
            cfg.replace(num_layers=2, num_encoder_layers=2, scan_unroll=True),
            cfg.num_layers,
        )
    raise ValueError(cfg.family)


@dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    step: Callable
    in_specs: tuple
    in_shardings: tuple
    donate_argnums: tuple[int, ...]
    kind: str
    meta: dict


def _bf16_params_specs(cfg: ModelConfig) -> Any:
    m = get_model(cfg)
    p = jax.eval_shape(lambda k: m.init(cfg, k), jax.random.PRNGKey(0))
    return jax.tree.map(lambda s: _sds(s.shape, jnp.bfloat16), p)


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    profile: str | None = None,
    grad_accum: int = 1,
    ocfg=None,
) -> Cell:
    """Build the step fn + ShapeDtypeStruct args + shardings for a cell."""
    from repro.parallel.sharding import axis_rules
    from repro.serve.engine import make_decode_step, make_prefill_step
    from repro.train.optim import OptimizerConfig
    from repro.train.train_step import make_train_step

    rules = rules_for(cfg, shape.kind, profile)
    m = get_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        state_specs = jax.eval_shape(
            lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0)
        )
        state_shard = shardings_of(state_specs, train_state_axes(cfg), mesh, rules)
        bspecs = train_batch_specs(cfg, B, S)
        bshard = batch_shardings(bspecs, mesh, rules)
        step = make_train_step(cfg, ocfg or OptimizerConfig(), grad_accum=grad_accum)

        def wrapped(state, batch):
            with axis_rules(mesh, rules):
                return step(state, batch)

        return Cell(
            step=wrapped,
            in_specs=(state_specs, bspecs),
            in_shardings=(state_shard, bshard),
            donate_argnums=(0,),
            kind="train",
            meta={"tokens": B * S, "rules": "train"},
        )

    params_specs = _bf16_params_specs(cfg)
    params_axes = m.param_axes(cfg)
    params_shard = shardings_of(params_specs, params_axes, mesh, rules)

    if shape.kind == "prefill":
        cache_specs = jax.eval_shape(
            lambda: m.init_cache(cfg, B, S)
            if cfg.family != "encdec"
            else m.init_cache(cfg, B, S, S)
        )
        cshard = shardings_of(cache_specs, cache_axes(cache_specs), mesh, rules)
        bspecs = train_batch_specs(cfg, B, S)
        bspecs.pop("targets")
        bshard = batch_shardings(bspecs, mesh, rules)
        step = make_prefill_step(cfg)

        def wrapped(params, batch, cache):
            with axis_rules(mesh, rules):
                return step(params, batch, cache)

        return Cell(
            step=wrapped,
            in_specs=(params_specs, bspecs, cache_specs),
            in_shardings=(params_shard, bshard, cshard),
            donate_argnums=(2,),
            kind="prefill",
            meta={"tokens": B * S},
        )

    # decode: one new token against a cache of seq_len
    cache_specs = jax.eval_shape(
        lambda: m.init_cache(cfg, B, S)
        if cfg.family != "encdec"
        else m.init_cache(cfg, B, S, S)
    )
    cshard = shardings_of(cache_specs, cache_axes(cache_specs), mesh, rules)
    if cfg.input_kind == "tokens" or cfg.family == "encdec":
        tok_specs = _sds((B,), jnp.int32)
    else:
        tok_specs = _sds((B, 1, cfg.d_model), jnp.bfloat16)
    tok_shard = NamedSharding(
        mesh, logical_to_spec(("batch",) + (None,) * (tok_specs.ndim - 1),
                              tok_specs.shape, mesh, rules)
    )
    pos_specs = _sds((), jnp.int32)
    pos_shard = NamedSharding(mesh, logical_to_spec((), (), mesh, rules))
    step = make_decode_step(cfg)

    def wrapped(params, cache, tokens, pos):
        with axis_rules(mesh, rules):
            return step(params, cache, tokens, pos)

    return Cell(
        step=wrapped,
        in_specs=(params_specs, cache_specs, tok_specs, pos_specs),
        in_shardings=(params_shard, cshard, tok_shard, pos_shard),
        donate_argnums=(1,),
        kind="decode",
        meta={"tokens": B},
    )
