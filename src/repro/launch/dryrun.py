import os
import tempfile

# REPRO_DRYRUN_DEVICES: forced host device count (default 512 = enough for
# the 2x16x16 multi-pod mesh; CPU smoke runs with --host-mesh set a small
# count — some container kernels cannot stand up 512 device threads)
_N_DEV = int(os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
_DUMP_DIR = tempfile.mkdtemp(prefix="xla_spmd_dump_")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEV} "
    f"--xla_dump_to={_DUMP_DIR} "
    "--xla_dump_hlo_pass_re=spmd-partitioning"
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory / cost / collective analyses.

The two lines above MUST stay first: jax locks the device count on first init.

Usage (via the unified CLI — `python -m repro.launch.dryrun` still works as
a deprecation shim with identical flags):

    PYTHONPATH=src python -m repro dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro dryrun --all --multi-pod both \
        --out artifacts/dryrun
"""

import json
import time
import traceback
from pathlib import Path


def _spmd_dump_snapshot() -> set[str]:
    return {f for f in os.listdir(_DUMP_DIR) if "after_spmd-partitioning" in f}


def _read_new_spmd_dump(before: set[str]) -> str | None:
    """Post-partitioning / pre-float-normalization HLO of the last compile.

    The CPU backend's float-normalization pass upconverts bf16 to f32 *after*
    SPMD partitioning, which would inflate collective-byte accounting 2x; this
    dump has the true (bf16) collective dtypes.
    """
    new = sorted(_spmd_dump_snapshot() - before)
    if not new:
        return None
    return (Path(_DUMP_DIR) / new[-1]).read_text()


def _compile_cell(cfg, shape, mesh, profile, grad_accum):
    import jax

    from repro.launch.specs import input_specs

    cell = input_specs(cfg, shape, mesh, profile=profile, grad_accum=grad_accum)
    snap = _spmd_dump_snapshot()
    with mesh:
        jitted = jax.jit(
            cell.step,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.in_specs)
        compiled = lowered.compile()
    return cell, compiled, _read_new_spmd_dump(snap)


def _cost_analysis(compiled) -> dict:
    # older jaxlibs return [per-device dict], newer a flat dict
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, (list, tuple)) else cost


def _cost_vector(compiled, spmd_hlo: str | None = None) -> dict:
    from repro.launch.hlo_analysis import collective_stats

    cost = _cost_analysis(compiled)
    colls = collective_stats(spmd_hlo if spmd_hlo else compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": colls.total_bytes,
        "collective_bytes_tpu": colls.tpu_adjusted_bytes,
        "collective_bytes_by_kind": dict(colls.bytes_by_kind),
        "collective_counts": dict(colls.counts),
    }


def _extrapolate(small: dict, large: dict, n_units: int) -> dict:
    out: dict = {}
    for k in ("flops", "bytes_accessed", "collective_bytes", "collective_bytes_tpu"):
        marg = large[k] - small[k]
        out[k] = small[k] + (n_units - 1) * marg
    out["collective_bytes_by_kind"] = {
        k: small["collective_bytes_by_kind"].get(k, 0.0)
        + (n_units - 1)
        * (large["collective_bytes_by_kind"].get(k, 0.0)
           - small["collective_bytes_by_kind"].get(k, 0.0))
        for k in set(small["collective_bytes_by_kind"]) | set(large["collective_bytes_by_kind"])
    }
    return out


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    profile: str | None = None,
    grad_accum: int = 1,
    save_hlo: str | None = None,
    smoke: bool = False,
    probes: bool = True,
    host_mesh: bool = False,
) -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.launch.hlo_analysis import collective_stats
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.specs import input_specs, probe_pair
    from repro.models.model import active_param_count

    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    # host_mesh: lower/compile on a small host mesh instead of the 16x16
    # production shape — the CPU-smoke path of `python -m repro dryrun`
    mesh = (make_host_mesh() if host_mesh
            else make_production_mesh(multi_pod=multi_pod))
    t0 = time.time()
    snap = _spmd_dump_snapshot()
    cell = input_specs(cfg, shape, mesh, profile=profile, grad_accum=grad_accum)

    with mesh:
        jitted = jax.jit(
            cell.step,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(_read_new_spmd_dump(snap) or hlo)

    corrected = None
    if probes and not smoke:
        # HLO cost analysis counts scan bodies once; extrapolate true totals
        # from two shallow unrolled probes (see specs.probe_pair).
        cfg_s, cfg_l, n_units = probe_pair(cfg)
        _, comp_s, dump_s = _compile_cell(cfg_s, shape, mesh, profile, grad_accum)
        _, comp_l, dump_l = _compile_cell(cfg_l, shape, mesh, profile, grad_accum)
        corrected = _extrapolate(
            _cost_vector(comp_s, dump_s), _cost_vector(comp_l, dump_l), n_units
        )

    # keep the dump dir bounded over long sweeps
    for f in os.listdir(_DUMP_DIR):
        try:
            os.unlink(os.path.join(_DUMP_DIR, f))
        except OSError:
            pass

    # analytic "useful" FLOPs: 6*N*D train, 2*N*D forward-only
    n_active = active_param_count(cfg)
    tok = cell.meta["tokens"]
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tok

    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "profile": profile or ("decode" if shape.kind == "decode" else "fsdp_cp"),
        "grad_accum": grad_accum,
        "devices": int(len(mesh.devices.reshape(-1))),
        "tokens": cell.meta["tokens"],
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": colls.summary(),
        "hlo_len": len(hlo),
        "n_active_params": n_active,
        "model_flops": float(model_flops),
        "corrected": corrected,
    }
    if save_hlo:
        Path(save_hlo).write_text(hlo)
        result["hlo_path"] = save_hlo
    return result


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import applicable_shapes, get_config, list_archs

    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s.name))
    return cells


def run_cells(
    *,
    arch: str | None = None,
    shape: str | None = None,
    run_all: bool = False,
    multi_pod: str = "off",
    profile: str | None = None,
    grad_accum: int = 1,
    out: str = "artifacts/dryrun",
    save_hlo: bool = False,
    smoke: bool = False,
    host_mesh: bool = False,
) -> dict:
    """Run a sweep of (arch x shape x pod) cells; the `python -m repro
    dryrun` workload body.  Always finishes the sweep and returns
    {tag: result-or-{"error": ...}} — exit policy is the CLI's job."""
    cells = all_cells() if run_all else [(arch, shape)]
    pods = {"off": [False], "on": [True], "both": [False, True]}[multi_pod]
    outdir = Path(out)
    outdir.mkdir(parents=True, exist_ok=True)

    results: dict[str, dict] = {}
    for arch_i, shape_i in cells:
        for mp in pods:
            tag = f"{arch_i}__{shape_i}__{'pod2' if mp else 'pod1'}"
            if profile:
                tag += f"__{profile}"
            if host_mesh:
                tag += "__host"
            dest = outdir / f"{tag}.json"
            try:
                res = run_cell(
                    arch_i, shape_i, mp,
                    profile=profile,
                    grad_accum=grad_accum,
                    save_hlo=str(outdir / f"{tag}.hlo") if save_hlo else None,
                    smoke=smoke,
                    host_mesh=host_mesh,
                )
                results[tag] = res
                dest.write_text(json.dumps(res, indent=1))
                corr = res.get("corrected") or {}
                print(
                    f"OK   {tag}: flops/dev={corr.get('flops', res['flops_per_device']):.3e} "
                    f"peak={res['memory']['peak_est_bytes']/2**30:.2f}GiB "
                    f"coll={corr.get('collective_bytes', res['collectives']['total_bytes'])/2**30:.3f}GiB "
                    f"compile={res['compile_s']:.1f}s",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 - record and continue
                results[tag] = {"error": f"{type(e).__name__}: {e}"}
                dest.with_suffix(".err").write_text(traceback.format_exc())
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    return results


def main(argv: list[str] | None = None) -> None:
    """Deprecated launcher: delegates to `python -m repro dryrun` (the flags
    are identical).  Kept so existing invocations keep working."""
    import sys
    import warnings

    warnings.warn(
        "python -m repro.launch.dryrun is deprecated; use "
        "`python -m repro dryrun`",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.app.cli import main as cli_main

    cli_main(["dryrun"] + (sys.argv[1:] if argv is None else list(argv)))


if __name__ == "__main__":
    main()
