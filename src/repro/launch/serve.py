"""Batched serving launcher: prefill a batch of prompts, decode with batched
steps, optional MegaScope probes per token.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.parallel.profiles import rules_for
from repro.parallel.sharding import axis_rules
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.serve.sampler import sample


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.input_kind != "tokens" and cfg.family != "encdec":
        raise SystemExit(f"{cfg.name} needs a modality frontend; serve tokens archs")
    m = get_model(cfg)
    mesh = make_host_mesh()
    rules = rules_for(cfg, "decode")

    with mesh, axis_rules(mesh, rules):
        params = m.init(cfg, jax.random.PRNGKey(0))
        B, P = args.batch, args.prompt_len
        cache_len = P + args.max_new
        cache = (m.init_cache(cfg, B, cache_len, P) if cfg.family == "encdec"
                 else m.init_cache(cfg, B, cache_len))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 2, cfg.vocab_size)
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch["embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, P, cfg.d_model), jnp.bfloat16)

        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg, temperature=args.temperature))

        t0 = time.perf_counter()
        cache, logits = prefill(params, batch, cache)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        tok = sample(logits, temperature=args.temperature)

        outs = [tok]
        t0 = time.perf_counter()
        for i in range(args.max_new - 1):
            cache, logits, tok = decode(params, cache, tok, jnp.int32(P + i))
            outs.append(tok)
        jax.block_until_ready(outs[-1])
        t_decode = time.perf_counter() - t0

    gen = jnp.stack(outs, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} new={args.max_new}")
    print(f"prefill: {t_prefill*1e3:.1f} ms ({B*P/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({B*(args.max_new-1)/max(t_decode,1e-9):.0f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq {b}: {[int(t) for t in gen[b][:12]]}...")


if __name__ == "__main__":
    main()
