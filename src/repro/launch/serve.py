"""Deprecated serving launcher — use ``python -m repro serve``.

This module is a thin shim kept so existing invocations keep working with
identical outputs (the flag set is unchanged; the new CLI accepts it
verbatim):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --continuous --requests 16 --rate 100 --slots 4 --max-new 16

delegates to

    PYTHONPATH=src python -m repro serve --arch qwen2-0.5b --smoke \
        --continuous --requests 16 --rate 100 --slots 4 --max-new 16

Engine construction now lives in ``repro.app.session.Session.serve`` /
``MegaServe.from_session`` (module plugins supply the tracer/collector).
"""

from __future__ import annotations

import sys
import warnings


def main(argv: list[str] | None = None) -> None:
    warnings.warn(
        "python -m repro.launch.serve is deprecated; use "
        "`python -m repro serve` (same flags, plus --modules/--set)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.app.cli import main as cli_main

    cli_main(["serve"] + (sys.argv[1:] if argv is None else list(argv)))


if __name__ == "__main__":
    main()
