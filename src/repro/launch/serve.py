"""Serving launcher.

Static lockstep batch (the original path):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 32 --max-new 16

MegaServe continuous batching (paged KV cache + request scheduler) over a
mixed-length Poisson-arrival workload:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --continuous --requests 16 --rate 100 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.parallel.profiles import rules_for
from repro.parallel.sharding import axis_rules
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.serve.sampler import sample


def _run_continuous(cfg, args) -> None:
    from dataclasses import replace

    from repro.serve import MegaServe, get_drafter
    from repro.serve.server import make_poisson_workload

    m = get_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    specs, prompts, serve_cfg = make_poisson_workload(
        cfg,
        n=args.requests, rate=args.rate,
        prompt_lens=tuple(int(x) for x in args.prompt_lens.split(",")),
        max_new_range=(max(1, args.max_new // 4), args.max_new),
        num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.num_blocks, seed=args.seed,
    )
    serve_cfg = replace(
        serve_cfg, decode_path=args.decode_path,
        spec_decode=args.spec_decode, spec_k=args.spec_k,
    )
    drafter = None
    if args.spec_decode and args.drafter != "ngram":
        drafter = get_drafter(args.drafter, vocab_size=cfg.vocab_size,
                              seed=args.seed)
    srv = MegaServe(cfg, params, serve_cfg, drafter=drafter)
    for s in specs:
        srv.submit(prompts[s.rid], s.max_new, arrival=s.arrival)
    outs = srv.drain()
    met = srv.metrics()
    print(f"arch={cfg.name} continuous slots={args.slots} "
          f"blocks={serve_cfg.num_blocks}x{serve_cfg.block_size} "
          f"requests={len(specs)} decode_path={srv.decode_path}"
          + (f" spec_k={args.spec_k} drafter={args.drafter}"
             if args.spec_decode else ""))
    keys = ["generated_tokens", "wall_s", "tokens_per_s", "ttft_p50_s",
            "ttft_p99_s", "latency_p50_s", "latency_p99_s", "preemptions",
            "steps"]
    if args.spec_decode:
        keys += ["spec_proposed", "spec_accepted", "spec_accept_rate"]
    for k in keys:
        v = met[k]
        print(f"  {k:16s} {v:.4f}" if isinstance(v, float) else f"  {k:16s} {v}")
    for rid in list(outs)[:2]:
        print(f"  req {rid}: {outs[rid][:12]}...")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # MegaServe continuous batching
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching via MegaServe (paged KV cache)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="physical KV blocks (0 = size for zero preemption)")
    ap.add_argument("--prompt-lens", default="16,32,64,128,256")
    ap.add_argument("--decode-path", default="auto",
                    choices=("auto", "paged", "gathered"),
                    help="paged = no-gather block-pool decode (default when "
                         "supported); gathered = dense-view oracle")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: draft + batched paged "
                         "verification (attention-only families)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per step")
    ap.add_argument("--drafter", default="ngram",
                    choices=("ngram", "random"),
                    help="draft proposer (random = adversarial baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.continuous:
        if cfg.input_kind != "tokens":
            raise SystemExit(f"{cfg.name}: continuous serving needs token archs")
        if args.temperature != 0.0:
            raise SystemExit(
                "--continuous decodes greedily (preemption-by-recompute "
                "requires deterministic decode); drop --temperature"
            )
        _run_continuous(cfg, args)
        return
    if cfg.input_kind != "tokens" and cfg.family != "encdec":
        raise SystemExit(f"{cfg.name} needs a modality frontend; serve tokens archs")
    m = get_model(cfg)
    mesh = make_host_mesh()
    rules = rules_for(cfg, "decode")

    with mesh, axis_rules(mesh, rules):
        params = m.init(cfg, jax.random.PRNGKey(0))
        B, P = args.batch, args.prompt_len
        cache_len = P + args.max_new
        cache = (m.init_cache(cfg, B, cache_len, P) if cfg.family == "encdec"
                 else m.init_cache(cfg, B, cache_len))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 2, cfg.vocab_size)
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch["embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, P, cfg.d_model), jnp.bfloat16)

        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg, temperature=args.temperature))

        t0 = time.perf_counter()
        cache, logits = prefill(params, batch, cache)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        tok = sample(logits, temperature=args.temperature)

        outs = [tok]
        t0 = time.perf_counter()
        for i in range(args.max_new - 1):
            cache, logits, tok = decode(params, cache, tok, jnp.int32(P + i))
            outs.append(tok)
        jax.block_until_ready(outs[-1])
        t_decode = time.perf_counter() - t0

    gen = jnp.stack(outs, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} new={args.max_new}")
    print(f"prefill: {t_prefill*1e3:.1f} ms ({B*P/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({B*(args.max_new-1)/max(t_decode,1e-9):.0f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq {b}: {[int(t) for t in gen[b][:12]]}...")


if __name__ == "__main__":
    main()
