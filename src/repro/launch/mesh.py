"""Production mesh construction (assignment-mandated shapes).

``make_production_mesh`` is a function, not a module-level constant, so
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over whatever local devices exist (CPU tests).

    Defaults to data=4/model=2 (not 2x4): this jaxlib's CPU backend
    reproducibly segfaults compiling SPMD programs on a 2x4 data/model
    mesh, while the transposed shape compiles fine.
    """
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    if (data, model) == (2, 4):
        # fail loudly instead of letting jaxlib take the whole process down
        raise ValueError(
            "host mesh shape data=2 x model=4 is known to segfault this "
            "jaxlib's CPU backend while compiling SPMD programs; use the "
            "transposed make_host_mesh(data=4, model=2) (the default) instead"
        )
    return jax.make_mesh((data, model), ("data", "model"))


def make_pipeline_mesh(pp: int, dp: int = 1, tp: int = 1) -> jax.sharding.Mesh:
    """(stage, data, model) mesh for (composed) pipeline-parallel training.

    Uses the first ``pp*dp*tp`` local devices, so a pp=2 smoke run works on
    the 8-device forced-host CPU fleet without consuming all of it.  All
    three axes are live inside ``core.dpp.executor.pipeline_apply``'s
    ``shard_map``: ``stage`` carries the ring ppermute, ``data`` shards the
    microbatch axis (one pipeline per dp group; parameter cotangents
    all-reduce over it in backward), and ``model`` slices heads/ffn inside
    each stage's block when the plan's tp > 1.  Outside the pipelined
    section ``data`` / ``model`` keep their usual logical-axis rule
    meanings.
    """
    need = pp * dp * tp
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(
            f"pipeline mesh stage={pp} x data={dp} x model={tp} needs "
            f"{need} devices, have {len(devs)} (for CPU smoke set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need})"
        )
    arr = np.asarray(devs[:need]).reshape(pp, dp, tp)
    return jax.sharding.Mesh(arr, ("stage", "data", "model"))
