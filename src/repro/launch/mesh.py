"""Production mesh construction (assignment-mandated shapes).

``make_production_mesh`` is a function, not a module-level constant, so
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over whatever local devices exist (CPU tests).

    Defaults to data=4/model=2 (not 2x4): this jaxlib's CPU backend
    reproducibly segfaults compiling SPMD programs on a 2x4 data/model
    mesh, while the transposed shape compiles fine.
    """
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
