"""Hierarchical on-line compression of captured tensors (MegaScope §6.1).

Compression happens *in-graph* on device — the TPU-native version of the
paper's host-side aggregation: only the compressed representation travels to
the host, so capture bandwidth is bounded regardless of model size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stats_of(x: jax.Array) -> dict[str, jax.Array]:
    xf = x.astype(jnp.float32)
    return {
        "mean": xf.mean(),
        "std": xf.std(),
        "min": xf.min(),
        "max": xf.max(),
        "l2": jnp.sqrt(jnp.sum(xf * xf)),
        "sparsity": (jnp.abs(xf) < 1e-6).mean(),
    }


def histogram(x: jax.Array, bins: int = 32, lo: float = -8.0, hi: float = 8.0):
    xf = x.astype(jnp.float32).reshape(-1)
    edges = jnp.linspace(lo, hi, bins + 1)
    idx = jnp.clip(jnp.searchsorted(edges, xf) - 1, 0, bins - 1)
    counts = jnp.zeros((bins,), jnp.int32).at[idx].add(1)
    return {"hist": counts, "edges": edges}


def subsample(x: jax.Array, k: int = 64) -> jax.Array:
    """Strided slice of the trailing dim (cheap deterministic sketch)."""
    flat = x.reshape(-1, x.shape[-1])
    r_stride = max(flat.shape[0] // k, 1)
    c_stride = max(x.shape[-1] // k, 1)
    return flat[::r_stride][:k, ::c_stride][:, :k]


def channel_profile(x: jax.Array) -> dict[str, jax.Array]:
    """Per-channel mean/max over all other dims (distribution-drift view)."""
    xf = x.astype(jnp.float32)
    red = tuple(range(xf.ndim - 1))
    return {"ch_mean": xf.mean(red), "ch_absmax": jnp.abs(xf).max(red)}


def full(x: jax.Array) -> jax.Array:
    return x


COMPRESSORS = {
    "stats": stats_of,
    "hist": histogram,
    "sample": subsample,
    "channels": channel_profile,
    "full": full,
}
