"""PCA projection of hidden states (MegaScope Fig. 6 — token trajectories)."""

from __future__ import annotations

import numpy as np


def pca_fit(x: np.ndarray, k: int = 2) -> dict:
    """x [n, d] -> components [k, d], mean [d], explained variance ratio."""
    x = np.asarray(x, np.float32)
    mu = x.mean(0)
    xc = x - mu
    _, s, vt = np.linalg.svd(xc, full_matrices=False)
    var = (s ** 2) / max(len(x) - 1, 1)
    return {
        "components": vt[:k],
        "mean": mu,
        "explained": (var[:k] / var.sum()).tolist() if var.sum() > 0 else [0.0] * k,
    }


def pca_project(x: np.ndarray, fit: dict) -> np.ndarray:
    return (np.asarray(x, np.float32) - fit["mean"]) @ fit["components"].T
