from repro.core.scope.collector import PerturbSpec, ProbeSpec, ScopeCollector
from repro.core.scope.compress import COMPRESSORS, stats_of
from repro.core.scope.pca import pca_fit, pca_project
from repro.core.scope.generation import GenerationRecord, generate_with_scope
from repro.core.scope.dashboard import write_dashboard

__all__ = [
    "ProbeSpec",
    "PerturbSpec",
    "ScopeCollector",
    "COMPRESSORS",
    "stats_of",
    "pca_fit",
    "pca_project",
    "GenerationRecord",
    "generate_with_scope",
    "write_dashboard",
]
