"""Token-by-token generation with synchronized introspection (MegaScope §6.2,
Fig. 4): each decode step records the chosen token, its probability, the
top-k decision distribution, and all registered probe captures."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scope.collector import ScopeCollector
from repro.models import get_model


@dataclass
class GenerationRecord:
    step: int
    token: int
    prob: float
    topk_tokens: list[int]
    topk_probs: list[float]
    captures: dict[str, Any] = field(default_factory=dict)


def generate_with_scope(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,     # [B, S] (B=1 recommended for viz)
    n_steps: int,
    scope: ScopeCollector | None = None,
    top_k: int = 8,
) -> tuple[list[GenerationRecord], jax.Array]:
    model = get_model(cfg)
    B, S = prompt_tokens.shape
    cache = model.init_cache(cfg, B, S + n_steps)
    scope = scope or ScopeCollector()

    cache, logits = model.prefill(
        cfg, params, {"tokens": prompt_tokens}, cache, scope
    )
    records: list[GenerationRecord] = []
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(n_steps):
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        tk_p, tk_i = jax.lax.top_k(probs[0], top_k)
        captures = jax.tree.map(np.asarray, scope.drain())
        records.append(GenerationRecord(
            step=i,
            token=int(tok[0]),
            prob=float(probs[0, tok[0]]),
            topk_tokens=[int(t) for t in tk_i],
            topk_probs=[float(p) for p in tk_p],
            captures=captures,
        ))
        toks.append(tok)
        cache, logits = model.decode_step(
            cfg, params, cache, tok, jnp.int32(S + i), scope
        )
    return records, jnp.stack(toks, axis=1)
