"""Token-by-token generation with synchronized introspection (MegaScope §6.2,
Fig. 4): each decode step records the chosen token, its probability, the
top-k decision distribution, and all registered probe captures."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scope.collector import ScopeCollector
from repro.models import layers as L
from repro.models import lm


@dataclass
class GenerationRecord:
    step: int
    token: int
    prob: float
    topk_tokens: list[int]
    topk_probs: list[float]
    captures: dict[str, Any] = field(default_factory=dict)


def _flat_captures(aux: dict) -> dict[str, Any]:
    """Flatten ``lm.forward``'s aux captures (grouped by segment, with
    scanned-layer leaves stacked over a leading layer axis) into the flat
    ``{"tag.compress": value}`` record layout.  Most models have one segment,
    so keys are plain; when a later segment repeats a key, that occurrence is
    disambiguated with its segment prefix (``"seg1/tag.compress"``)."""
    out: dict[str, Any] = {}
    for seg, caps in aux.get("captures", {}).items():
        for k, v in caps.items():
            out[k if k not in out else f"{seg}/{k}"] = v
    return out


def generate_with_scope(
    cfg: ModelConfig,
    params,
    prompt_tokens: jax.Array,     # [B, S] (B=1 recommended for viz)
    n_steps: int,
    scope: ScopeCollector | None = None,
    top_k: int = 8,
) -> tuple[list[GenerationRecord], jax.Array]:
    if cfg.input_kind != "tokens":
        raise ValueError(f"{cfg.name}: generate_with_scope serves token archs")
    B, S = prompt_tokens.shape
    cache = lm.init_cache(cfg, B, S + n_steps)
    scope = scope or ScopeCollector()

    # lm.forward is called directly (not through model.prefill/decode_step)
    # because probe captures ride its aux: tags inside the layer scan can
    # only escape through scan ys, which the thin wrappers discard
    hidden, cache, aux = lm.forward(
        cfg, params, {"tokens": prompt_tokens},
        cache=cache, cache_pos=jnp.int32(0), collector=scope,
    )
    logits = L.logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    records: list[GenerationRecord] = []
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(n_steps):
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        tk_p, tk_i = jax.lax.top_k(probs[0], top_k)
        captures = jax.tree.map(
            np.asarray, {**_flat_captures(aux), **scope.drain()}
        )
        records.append(GenerationRecord(
            step=i,
            token=int(tok[0]),
            prob=float(probs[0, tok[0]]),
            topk_tokens=[int(t) for t in tk_i],
            topk_probs=[float(p) for p in tk_p],
            captures=captures,
        ))
        toks.append(tok)
        hidden, cache, aux = lm.forward(
            cfg, params, {"tokens": tok.reshape(-1, 1)},
            cache=cache, cache_pos=jnp.int32(S + i), collector=scope,
        )
        logits = L.logits_fn(params, cfg, hidden)[:, 0]
    return records, jnp.stack(toks, axis=1)
