"""Self-contained HTML dashboard writer (MegaScope Figs. 4-6 offline).

No server dependency: captured data is embedded as JSON and rendered with a
small inline script — attention heatmaps on <canvas>, per-token top-k bars,
and the PCA token-trajectory scatter."""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

_TEMPLATE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>MegaScope</title>
<style>
 body {{ font-family: ui-monospace, monospace; background:#111; color:#ddd; margin:20px; }}
 h2 {{ color:#8cf; }}
 .tok {{ display:inline-block; padding:2px 6px; margin:2px; background:#223;
        border-radius:4px; cursor:pointer; }}
 .tok.sel {{ background:#46a; }}
 canvas {{ border:1px solid #444; image-rendering: pixelated; margin:6px; }}
 .bar {{ height:14px; background:#4a8; margin:1px 0; }}
 .row {{ display:flex; gap:24px; flex-wrap:wrap; }}
 table {{ border-collapse:collapse; }} td,th {{ padding:2px 8px; border:1px solid #333; }}
</style></head><body>
<h1>MegaScope dashboard</h1>
<div id="meta"></div>
<h2>Token-by-token decoding</h2>
<div id="tokens"></div>
<div class="row">
 <div><h2>Top-k decision distribution</h2><div id="topk"></div></div>
 <div><h2>Attention heatmap</h2><canvas id="attn" width="256" height="256"></canvas></div>
 <div><h2>PCA trajectory</h2><canvas id="pca" width="300" height="300"></canvas></div>
</div>
<h2>Captured probe statistics</h2>
<div id="probes"></div>
<script>
const DATA = {data_json};
const tokens = document.getElementById('tokens');
let sel = 0;
function draw() {{
  tokens.innerHTML = '';
  DATA.records.forEach((r, i) => {{
    const s = document.createElement('span');
    s.className = 'tok' + (i === sel ? ' sel' : '');
    s.textContent = `${{r.token}} (${{r.prob.toFixed(3)}})`;
    s.onclick = () => {{ sel = i; draw(); }};
    tokens.appendChild(s);
  }});
  const r = DATA.records[sel] || {{topk_tokens: [], topk_probs: []}};
  const tk = document.getElementById('topk');
  tk.innerHTML = '';
  r.topk_tokens.forEach((t, i) => {{
    const d = document.createElement('div');
    d.innerHTML = `<span style="display:inline-block;width:80px">${{t}}</span>`;
    const b = document.createElement('div');
    b.className = 'bar'; b.style.width = (r.topk_probs[i] * 300) + 'px';
    b.title = r.topk_probs[i].toFixed(4);
    d.appendChild(b); tk.appendChild(d);
  }});
  if (DATA.attention) heat('attn', DATA.attention);
  if (DATA.pca) scatter('pca', DATA.pca);
  const pr = document.getElementById('probes');
  pr.innerHTML = '';
  const tbl = document.createElement('table');
  tbl.innerHTML = '<tr><th>probe</th><th>value(s)</th></tr>';
  Object.entries(r.captures || {{}}).forEach(([k, v]) => {{
    const row = document.createElement('tr');
    row.innerHTML = `<td>${{k}}</td><td>${{JSON.stringify(v).slice(0, 120)}}</td>`;
    tbl.appendChild(row);
  }});
  pr.appendChild(tbl);
}}
function heat(id, m) {{
  const c = document.getElementById(id), g = c.getContext('2d');
  const h = m.length, w = m[0].length; let mx = 1e-9;
  m.forEach(row => row.forEach(v => mx = Math.max(mx, v)));
  const img = g.createImageData(w, h);
  for (let i = 0; i < h; i++) for (let j = 0; j < w; j++) {{
    const v = m[i][j] / mx, o = 4 * (i * w + j);
    img.data[o] = 30 + 225 * v; img.data[o+1] = 40 + 120 * v;
    img.data[o+2] = 80; img.data[o+3] = 255;
  }}
  createImageBitmap(img).then(b => g.drawImage(b, 0, 0, c.width, c.height));
}}
function scatter(id, pts) {{
  const c = document.getElementById(id), g = c.getContext('2d');
  g.clearRect(0, 0, c.width, c.height);
  let xs = pts.map(p => p[0]), ys = pts.map(p => p[1]);
  const x0 = Math.min(...xs), x1 = Math.max(...xs) + 1e-9;
  const y0 = Math.min(...ys), y1 = Math.max(...ys) + 1e-9;
  g.strokeStyle = '#4a8'; g.beginPath();
  pts.forEach((p, i) => {{
    const x = 10 + 280 * (p[0] - x0) / (x1 - x0);
    const y = 10 + 280 * (p[1] - y0) / (y1 - y0);
    if (i === 0) g.moveTo(x, y); else g.lineTo(x, y);
    g.fillStyle = '#8cf'; g.fillRect(x - 2, y - 2, 4, 4);
  }});
  g.stroke();
}}
document.getElementById('meta').textContent = DATA.meta || '';
draw();
</script></body></html>
"""


def _to_jsonable(x):
    if isinstance(x, dict):
        return {k: _to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_to_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return np.round(x.astype(np.float64), 5).tolist()
    if hasattr(x, "item") and getattr(x, "ndim", 1) == 0:
        return round(float(x.item()), 6)
    if hasattr(x, "tolist"):
        return _to_jsonable(np.asarray(x))
    return x


def write_dashboard(
    path: str | Path,
    records: list,
    *,
    attention: np.ndarray | None = None,   # [T, T] one head's probs
    pca_points: np.ndarray | None = None,  # [n, 2]
    meta: str = "",
) -> Path:
    data = {
        "records": [
            {
                "step": r.step, "token": r.token, "prob": r.prob,
                "topk_tokens": r.topk_tokens, "topk_probs": r.topk_probs,
                "captures": _to_jsonable(r.captures),
            }
            for r in records
        ],
        "attention": _to_jsonable(attention) if attention is not None else None,
        "pca": _to_jsonable(pca_points) if pca_points is not None else None,
        "meta": meta,
    }
    out = Path(path)
    out.write_text(_TEMPLATE.format(data_json=json.dumps(data)))
    return out
