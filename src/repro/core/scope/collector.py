"""MegaScope probe collector + perturbation injection (§6.1-6.2).

Probes are registered declaratively (observation points = tag-name patterns +
compression mode); the collector is threaded through the model as a
``repro.models.hooks.Collector`` and captures compressed representations that
flow out of layer scans via the forward's aux outputs.

Layer selection is post-hoc: inside ``lax.scan`` the layer index is traced, so
all layers capture (uniform ys) and the stacked [L, ...] output is sliced by
the viewer — compression keeps that cheap.

Perturbations implement the paper's controlled experiments:
  * ``gaussian``  — additive noise (reduced-precision emulation)
  * ``bitflip``   — random mantissa/exponent bit flips (storage-fault studies)
  * ``offset``    — constant shift on inter-layer tensors (cross-device
                    quantization error / persistent link-jitter emulation)
  * ``zero_mask`` — channel masking
  * ``attn_uniform`` — replace attention probabilities with uniform weights
Layer targeting uses traced-safe ``jnp.where`` on the scan layer index.
"""

from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.scope.compress import COMPRESSORS
from repro.models.hooks import Collector


@dataclass(frozen=True)
class ProbeSpec:
    pattern: str                  # fnmatch over tag names ("attn_*", "mlp_hidden")
    compress: str = "stats"       # COMPRESSORS key
    kwargs: tuple = ()            # extra args for the compressor


@dataclass(frozen=True)
class PerturbSpec:
    pattern: str
    kind: str                     # gaussian | bitflip | offset | zero_mask | attn_uniform
    amount: float = 0.0           # sigma / flip prob / offset / mask frac
    layer: int | None = None      # None = all layers


class ScopeCollector(Collector):
    def __init__(
        self,
        probes: list[ProbeSpec] = (),
        perturbs: list[PerturbSpec] = (),
        rng: jax.Array | None = None,
    ):
        self.probes = list(probes)
        self.perturbs = list(perturbs)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._buf: dict[str, Any] = {}
        self._counter = 0

    # ------------------------------------------------------------- capture
    def tag(self, name: str, value: jax.Array, **meta: Any) -> jax.Array:
        layer = meta.get("layer")
        for spec in self.perturbs:
            if fnmatch.fnmatch(name, spec.pattern):
                value = self._apply_perturb(spec, value, layer)
        for spec in self.probes:
            if fnmatch.fnmatch(name, spec.pattern):
                fn = COMPRESSORS[spec.compress]
                self._buf[f"{name}.{spec.compress}"] = fn(value, *spec.kwargs)
        return value

    def drain(self) -> dict[str, Any]:
        out, self._buf = self._buf, {}
        return out

    # ----------------------------------------------------------- perturbs
    def _key(self) -> jax.Array:
        self._counter += 1
        return jax.random.fold_in(self.rng, self._counter)

    def _apply_perturb(
        self, spec: PerturbSpec, value: jax.Array, layer
    ) -> jax.Array:
        out = self._perturb_value(spec, value)
        if spec.layer is None or layer is None:
            return out
        sel = jnp.asarray(layer) == spec.layer
        return jnp.where(sel, out, value)

    def _perturb_value(self, spec: PerturbSpec, value: jax.Array) -> jax.Array:
        kind, amt = spec.kind, spec.amount
        if kind == "gaussian":
            return value + amt * jax.random.normal(
                self._key(), value.shape, jnp.float32
            ).astype(value.dtype)
        if kind == "offset":
            return value + jnp.asarray(amt, value.dtype)
        if kind == "zero_mask":
            keep = jax.random.bernoulli(self._key(), 1.0 - amt, value.shape[-1:])
            return value * keep.astype(value.dtype)
        if kind == "bitflip":
            return _bitflip(value, amt, self._key())
        if kind == "attn_uniform":
            # value: attention probabilities [..., T]; mix toward uniform
            u = jnp.ones_like(value) / value.shape[-1]
            return (1.0 - amt) * value + amt * u
        raise ValueError(kind)


def _bitflip(value: jax.Array, prob: float, key: jax.Array) -> jax.Array:
    """Flip each bit of the binary representation with probability ``prob``
    (the paper's storage-fault robustness study)."""
    dt = value.dtype
    if dt == jnp.float32:
        idt, nbits = jnp.uint32, 32
    elif dt in (jnp.bfloat16, jnp.float16):
        idt, nbits = jnp.uint16, 16
    else:
        return value
    bits = jax.lax.bitcast_convert_type(value, idt)
    flips = jax.random.bernoulli(key, prob, value.shape + (nbits,))
    weights = (2 ** jnp.arange(nbits, dtype=jnp.uint32)).astype(idt)
    mask = (flips.astype(idt) * weights).sum(-1).astype(idt)
    return jax.lax.bitcast_convert_type(bits ^ mask, dt)
