"""Chrome Tracing Format export/import (loadable in chrome://tracing and
Perfetto — §3.2, Fig. 1).  Each rank maps to a process; compute and
communication map to separate threads so overlap is visible."""

from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable

from repro.core.tracing.events import TraceEvent

_TID = {"compute": 0, "coll": 1, "p2p": 2, "marker": 3}


def to_chrome(events: Iterable[TraceEvent]) -> dict:
    out = []
    ranks = set()
    for e in events:
        ranks.add(e.rank)
        if e.kind == "counter":
            # metric samples (repro.obs.export.counter_events) render as
            # counter tracks in Perfetto, alongside the span threads
            out.append({
                "name": e.name,
                "ph": "C",
                "pid": e.rank,
                "ts": e.ts * 1e6,
                "cat": e.kind,
                "args": {k: (list(v) if isinstance(v, tuple) else v)
                         for k, v in e.args.items()},
            })
            continue
        out.append({
            "name": e.name,
            "ph": "X" if e.dur > 0 else "i",
            "pid": e.rank,
            "tid": _TID.get(e.kind, 4),
            "ts": e.ts * 1e6,           # Chrome expects microseconds
            "dur": e.dur * 1e6,
            "cat": e.kind,
            "args": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in e.args.items()},
        })
    meta = []
    for r in sorted(ranks):
        meta.append({"name": "process_name", "ph": "M", "pid": r,
                     "args": {"name": f"rank {r}"}})
        for kind, tid in _TID.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": r, "tid": tid,
                         "args": {"name": kind}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def save_chrome(events: Iterable[TraceEvent], path: str | Path) -> None:
    Path(path).write_text(json.dumps(to_chrome(events)))


def from_chrome(doc: dict) -> list[TraceEvent]:
    tid_rev = {v: k for k, v in _TID.items()}
    out = []
    for e in doc.get("traceEvents", []):
        if e.get("ph") == "C":
            out.append(TraceEvent(
                e["name"], e["pid"], e["ts"] / 1e6, 0.0, "counter",
                dict(e.get("args", {})),
            ))
            continue
        if e.get("ph") not in ("X", "i"):
            continue
        args = dict(e.get("args", {}))
        if isinstance(args.get("group"), list):
            args["group"] = tuple(args["group"])
        out.append(TraceEvent(
            e["name"], e["pid"], e["ts"] / 1e6, e.get("dur", 0.0) / 1e6,
            tid_rev.get(e.get("tid", 0), e.get("cat", "compute")), args,
        ))
    return out
