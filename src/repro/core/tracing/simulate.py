"""Distributed-trace simulator: simkit timeline -> per-rank local-clock traces.

Gives MegaScan a cluster-free test bed with controllable ground truth: inject
down-clocked ranks / degraded links / jitter (FaultModel) and per-rank clock
offset + drift + read noise (ClockModel); the analysis pipeline must recover
them.  (DESIGN.md §2: the CUDA-event signal is the only thing replaced; the
merge/align/detect pipeline is identical for simulated and real traces.)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simkit.engine import Engine, FaultModel
from repro.core.simkit.workload import ModelProfile, Topology, build_training_step
from repro.core.tracing.events import TraceEvent


@dataclass
class ClockModel:
    offset_sigma: float = 5e-3     # initial offset spread across ranks (s)
    drift_sigma: float = 2e-5      # clock drift (s per s)
    read_noise: float = 2e-6       # per-timestamp measurement noise (s)
    seed: int = 0


def simulate_trace(
    topo: Topology,
    prof: ModelProfile,
    *,
    n_micro: int = 8,
    n_iters: int = 1,
    schedule: str = "1f1b",
    faults: FaultModel | None = None,
    clocks: ClockModel | None = None,
    async_p2p: bool = False,
) -> tuple[list[TraceEvent], dict]:
    """Returns (per-rank local-clock events, ground truth dict)."""
    clocks = clocks or ClockModel()
    faults = faults or FaultModel()
    rng = np.random.default_rng(clocks.seed)
    offsets = rng.normal(0.0, clocks.offset_sigma, topo.world)
    drifts = rng.normal(0.0, clocks.drift_sigma, topo.world)
    offsets[0] = 0.0
    drifts[0] = 0.0

    engine = Engine(faults=faults)
    events: list[TraceEvent] = []
    t_base = 0.0
    for it in range(n_iters):
        order = build_training_step(
            topo, prof, n_micro=n_micro, schedule=schedule, async_p2p=async_p2p
        )
        res = engine.run(order)
        for rec in res.records:
            r = rec.rank
            kind = {
                "compute": "compute",
                "allreduce": "coll", "allgather": "coll",
                "reducescatter": "coll", "alltoall": "coll",
                "send": "p2p", "recv": "p2p",
            }[rec.kind]
            args = dict(rec.meta)
            args["iter"] = it
            if kind == "coll":
                task = rec.tid
                args.setdefault("op", task.split("_")[0])
                # group recorded by the workload builder
            if kind == "p2p":
                args["dir"] = "send" if rec.kind == "send" else "recv"
            ts_true = t_base + rec.start
            te_true = t_base + rec.end
            ts_loc = (ts_true + offsets[r] + drifts[r] * ts_true
                      + rng.normal(0.0, clocks.read_noise))
            te_loc = (te_true + offsets[r] + drifts[r] * te_true
                      + rng.normal(0.0, clocks.read_noise))
            ev = TraceEvent(
                rec.tid, r, float(ts_loc), max(float(te_loc - ts_loc), 0.0),
                kind, args,
            )
            events.append(ev)
        t_base += res.makespan + 1e-3

    # attach group/bytes/peer args from the task definitions
    order_flat = {}
    for lst in order.values():
        for t in lst:
            order_flat[t.tid] = t
    for e in events:
        t = order_flat.get(e.name)
        if t is None:
            continue
        if t.group:
            e.args["group"] = t.group
        if t.bytes:
            e.args["bytes"] = t.bytes
        if t.peer is not None:
            e.args["peer"] = t.peer
        e.args.setdefault("op", t.tid.split("_")[0].rstrip("0123456789"))

    truth = {
        "offsets": offsets.tolist(),
        "drifts": drifts.tolist(),
        "slow_ranks": sorted(faults.compute_slowdown),
        "degraded_links": sorted(faults.link_slowdown),
        "makespan": res.makespan,
    }
    events.sort(key=lambda e: (e.ts, e.rank))
    return events, truth
