from repro.core.tracing.events import TraceEvent
from repro.core.tracing.tracer import (
    AsyncTraceWriter,
    Tracer,
    gather_traces,
    load_jsonl,
    load_trace,
)
from repro.core.tracing.chrome import from_chrome, to_chrome
from repro.core.tracing.align import (
    CollectiveInstance,
    align_clocks,
    apply_alignment,
    reconstruct_collectives,
)
from repro.core.tracing.detect import Diagnosis, detect
from repro.core.tracing.simulate import ClockModel, simulate_trace

__all__ = [
    "TraceEvent",
    "Tracer",
    "AsyncTraceWriter",
    "gather_traces",
    "load_jsonl",
    "load_trace",
    "to_chrome",
    "from_chrome",
    "CollectiveInstance",
    "reconstruct_collectives",
    "align_clocks",
    "apply_alignment",
    "Diagnosis",
    "detect",
    "ClockModel",
    "simulate_trace",
]
