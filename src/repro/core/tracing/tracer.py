"""Runtime tracer (MegaScan's ``tracers.scope``) + async rank-0 gathering.

On a GPU cluster the paper brackets operations with CUDA events; here the
host monotonic clock brackets dispatch of jit-compiled blocks (our CPU test
runs call ``jax.block_until_ready`` inside the scope for faithful durations).
Persistence runs on a background thread so tracing never stalls the training
loop (§3.2 "Log pre-processing").
"""

from __future__ import annotations

import json
import queue
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Callable, Iterable

from repro.core.tracing.events import TraceEvent


class Tracer:
    def __init__(
        self,
        rank: int,
        enabled: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.rank = rank
        self.enabled = enabled
        self.clock = clock
        self.events: list[TraceEvent] = []

    @contextmanager
    def scope(self, name: str, kind: str = "compute", **args: Any):
        if not self.enabled:
            yield self
            return
        t0 = self.clock()
        try:
            yield self
        finally:
            t1 = self.clock()
            self.events.append(
                TraceEvent(name, self.rank, t0, t1 - t0, kind, dict(args))
            )

    def record(self, name: str, ts: float, dur: float, kind: str = "compute",
               **args: Any) -> None:
        if self.enabled:
            self.events.append(TraceEvent(name, self.rank, ts, dur, kind, dict(args)))

    def instant(self, name: str, **args: Any) -> None:
        self.record(name, self.clock(), 0.0, "marker", **args)

    def clear(self) -> None:
        self.events = []


def gather_traces(tracers: Iterable[Tracer]) -> list[TraceEvent]:
    """Rank-0 gather: merge per-rank buffers, time-ordered."""
    out: list[TraceEvent] = []
    for t in tracers:
        out.extend(t.events)
    out.sort(key=lambda e: (e.ts, e.rank))
    return out


class AsyncTraceWriter:
    """Background JSONL persistence (keeps the training path stall-free).

    Streaming semantics: rows are flushed every ``flush_every`` writes and
    whenever the queue goes idle for ``idle_s``, so a mid-run crash leaves
    every completed step's events readable on disk (``load_jsonl``) instead
    of losing the whole end-of-run export.  ``mode="w"`` truncates at open —
    the per-run streaming default; ``"a"`` appends across runs.
    """

    def __init__(
        self,
        path: str | Path,
        *,
        mode: str = "a",
        flush_every: int = 64,
        idle_s: float = 0.2,
    ):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._mode = mode
        self._flush_every = max(flush_every, 1)
        self._idle_s = idle_s
        self._q: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        with open(self.path, self._mode) as f:
            pending = 0
            while True:
                try:
                    item = self._q.get(timeout=self._idle_s)
                except queue.Empty:
                    if pending:
                        f.flush()
                        pending = 0
                    continue
                if item is None:
                    f.flush()
                    break
                f.write(json.dumps(item.to_json()) + "\n")
                pending += 1
                if pending >= self._flush_every:
                    f.flush()
                    pending = 0

    def submit(self, events: Iterable[TraceEvent]) -> None:
        for e in events:
            self._q.put(e)

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()


def load_jsonl(path: str | Path) -> list[TraceEvent]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(TraceEvent.from_json(json.loads(line)))
    return out


def load_trace(path: str | Path) -> list[TraceEvent]:
    """Load a saved trace whatever its format: a chrome-trace JSON document
    (object with ``traceEvents``, or a bare event array) or the streamed
    JSONL that ``AsyncTraceWriter`` produces.  A whole-file parse
    discriminates the formats (JSONL rows are also objects, so sniffing the
    first character would misfire), so ``trace --detect`` accepts either
    the ``--trace-out`` export or its ``.jsonl`` streaming sidecar."""
    text = Path(path).read_text()
    try:
        # a chrome trace is ONE JSON value spanning the file (a JSONL file
        # with 2+ rows fails here: trailing data after the first object)
        doc = json.loads(text)
    except json.JSONDecodeError:
        return [
            TraceEvent.from_json(json.loads(line))
            for line in text.splitlines() if line.strip()
        ]
    if isinstance(doc, list):
        doc = {"traceEvents": doc}
    if "traceEvents" in doc:
        from repro.core.tracing.chrome import from_chrome

        return from_chrome(doc)
    return [TraceEvent.from_json(doc)]  # single-row JSONL
