"""Runtime tracer (MegaScan's ``tracers.scope``) + async rank-0 gathering.

On a GPU cluster the paper brackets operations with CUDA events; here the
host monotonic clock brackets dispatch of jit-compiled blocks (our CPU test
runs call ``jax.block_until_ready`` inside the scope for faithful durations).
Persistence runs on a background thread so tracing never stalls the training
loop (§3.2 "Log pre-processing").
"""

from __future__ import annotations

import json
import queue
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Callable, Iterable

from repro.core.tracing.events import TraceEvent


class Tracer:
    def __init__(
        self,
        rank: int,
        enabled: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.rank = rank
        self.enabled = enabled
        self.clock = clock
        self.events: list[TraceEvent] = []

    @contextmanager
    def scope(self, name: str, kind: str = "compute", **args: Any):
        if not self.enabled:
            yield self
            return
        t0 = self.clock()
        try:
            yield self
        finally:
            t1 = self.clock()
            self.events.append(
                TraceEvent(name, self.rank, t0, t1 - t0, kind, dict(args))
            )

    def record(self, name: str, ts: float, dur: float, kind: str = "compute",
               **args: Any) -> None:
        if self.enabled:
            self.events.append(TraceEvent(name, self.rank, ts, dur, kind, dict(args)))

    def instant(self, name: str, **args: Any) -> None:
        self.record(name, self.clock(), 0.0, "marker", **args)

    def clear(self) -> None:
        self.events = []


def gather_traces(tracers: Iterable[Tracer]) -> list[TraceEvent]:
    """Rank-0 gather: merge per-rank buffers, time-ordered."""
    out: list[TraceEvent] = []
    for t in tracers:
        out.extend(t.events)
    out.sort(key=lambda e: (e.ts, e.rank))
    return out


class AsyncTraceWriter:
    """Background JSONL persistence (keeps the training path stall-free)."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._q: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        with open(self.path, "a") as f:
            while True:
                item = self._q.get()
                if item is None:
                    break
                f.write(json.dumps(item.to_json()) + "\n")

    def submit(self, events: Iterable[TraceEvent]) -> None:
        for e in events:
            self._q.put(e)

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()


def load_jsonl(path: str | Path) -> list[TraceEvent]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(TraceEvent.from_json(json.loads(line)))
    return out
