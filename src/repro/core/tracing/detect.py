"""Multi-stage straggler detection (MegaScan §3.2 "Anomaly analysis").

Core insight (paper): the true fault source is the slowest member of *every*
synchronous group it joins; collaterally-slowed ranks merely wait.

Stage 1 — cross-DP peer comparison: ranks with identical (pp, tp) coordinates
execute identical kernel sequences; per (op, microbatch, chunk, pp, tp) the
duration is compared across DP peers; ranks with an excessive fraction of
slow ops become candidates.

Stage 2 — collective start-skew: a genuine source *starts* its collectives
consistently later than peers (its preceding compute is slow).

Stage 3 — P2P effective bandwidth: payload/duration per directed edge;
degraded edges (impaired PCIe/NIC path) are flagged even when start-time
comparison is uninformative due to pipeline asynchrony.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from statistics import median

from repro.core.simkit.workload import Topology
from repro.core.tracing.align import CollectiveInstance, reconstruct_collectives
from repro.core.tracing.events import TraceEvent


@dataclass
class Diagnosis:
    slow_ranks: list[int]
    candidate_ranks: list[int]
    degraded_links: list[tuple[int, int]]
    rank_scores: dict[int, dict] = field(default_factory=dict)
    link_bandwidth: dict[tuple[int, int], float] = field(default_factory=dict)
    evidence: dict = field(default_factory=dict)

    def summary(self) -> dict:
        return {
            "slow_ranks": self.slow_ranks,
            "candidates": self.candidate_ranks,
            "degraded_links": [list(l) for l in self.degraded_links],
            "rank_scores": {str(k): v for k, v in self.rank_scores.items()},
        }


def _stage1_peer_comparison(
    events: list[TraceEvent], topo: Topology, slow_ratio: float
) -> dict[int, float]:
    """Fraction of a rank's compute ops that are slow vs its DP peers."""
    groups: dict[tuple, dict[int, float]] = defaultdict(dict)
    for e in events:
        if e.kind != "compute":
            continue
        d, p, t = topo.coords(e.rank)
        key = (p, t, e.args.get("op", e.name), e.args.get("mb"), e.args.get("chunk"))
        groups[key][e.rank] = groups[key].get(e.rank, 0.0) + e.dur

    slow_count: dict[int, int] = defaultdict(int)
    total_count: dict[int, int] = defaultdict(int)
    for key, per_rank in groups.items():
        if len(per_rank) < 2:
            continue
        # statistics.median: the groups are tiny (one value per DP peer),
        # where numpy's per-call overhead dominates the online pass
        med = float(median(per_rank.values()))
        for r, dur in per_rank.items():
            total_count[r] += 1
            if dur > slow_ratio * med:
                slow_count[r] += 1
    return {
        r: slow_count[r] / total_count[r] for r in total_count if total_count[r] > 0
    }


def _stage2_start_skew(
    instances: list[CollectiveInstance], skew_margin: float
) -> dict[int, float]:
    """Fraction of collectives in which the rank is the distinctly-last
    starter (evidence it is the source rather than a victim)."""
    late: dict[int, int] = defaultdict(int)
    total: dict[int, int] = defaultdict(int)
    for inst in instances:
        if len(inst.members) < 2:
            continue
        starts = inst.starts
        order = sorted(starts.items(), key=lambda kv: kv[1])
        last_rank, last_t = order[-1]
        second_t = order[-2][1]
        span = max(inst.members[last_rank].dur, 1e-9)
        for r in starts:
            total[r] += 1
        if (last_t - second_t) > skew_margin * span:
            late[last_rank] += 1
    return {r: late[r] / total[r] for r in total if total[r]}


def _stage3_p2p_bandwidth(
    events: list[TraceEvent], degrade_ratio: float, warmup_only: bool = False
) -> tuple[dict[tuple[int, int], float], list[tuple[int, int]]]:
    per_edge: dict[tuple[int, int], list[float]] = defaultdict(list)
    for e in events:
        if e.kind != "p2p" or e.args.get("dir") != "send":
            continue
        if warmup_only and e.args.get("mb", 0) > 0:
            continue
        peer = e.args.get("peer")
        nbytes = e.args.get("bytes", 0)
        if peer is None or not nbytes or e.dur <= 0:
            continue
        per_edge[(e.rank, peer)].append(nbytes / e.dur)

    bw = {edge: float(median(v)) for edge, v in per_edge.items() if v}
    if not bw:
        return {}, []
    global_med = float(median(bw.values()))
    degraded = [e for e, b in bw.items() if b < global_med / degrade_ratio]
    return bw, degraded


def detect(
    events: list[TraceEvent],
    topo: Topology,
    *,
    slow_ratio: float = 1.25,
    candidate_frac: float = 0.25,
    skew_margin: float = 0.05,
    late_frac: float = 0.4,
    degrade_ratio: float = 1.6,
    instances: list[CollectiveInstance] | None = None,
) -> Diagnosis:
    if instances is None:
        instances = reconstruct_collectives(events)

    slow_frac = _stage1_peer_comparison(events, topo, slow_ratio)
    candidates = sorted(r for r, f in slow_frac.items() if f >= candidate_frac)

    late = _stage2_start_skew(instances, skew_margin)
    confirmed = sorted(
        r for r in candidates if late.get(r, 0.0) >= late_frac
    )
    # Degenerate-but-real case: every DP peer group has exactly one member
    # (dp=1) — stage 1 is silent, fall back to stage-2 evidence alone.
    if not slow_frac and late:
        confirmed = sorted(r for r, f in late.items() if f >= max(late_frac, 0.6))

    bw, degraded = _stage3_p2p_bandwidth(events, degrade_ratio)

    scores = {}
    for r in set(list(slow_frac) + list(late)):
        scores[r] = {
            "slow_op_frac": round(slow_frac.get(r, 0.0), 4),
            "late_start_frac": round(late.get(r, 0.0), 4),
        }
    return Diagnosis(
        slow_ranks=confirmed,
        candidate_ranks=candidates,
        degraded_links=sorted(degraded),
        rank_scores=scores,
        link_bandwidth=bw,
        evidence={"n_instances": len(instances), "n_events": len(events)},
    )
