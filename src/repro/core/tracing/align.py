"""Dependency reconstruction + cross-rank clock alignment (MegaScan §3.2).

Dependency reconstruction: events of the *same* synchronous communication
instance are matched by (participant-set, op) occurrence order — each rank's
i-th event for a given group key belongs to instance i (the paper's
"single pass over the events").

Timeline alignment: all participants of a synchronous collective logically
finish at the same moment, so every matched instance is an anchor.  We fit a
per-rank linear clock model offset_r(t) = a_r + b_r * t against a reference
rank by least squares over anchors (offset + drift), then optionally apply a
piecewise correction between consecutive anchors so residual error stays
bounded by the inter-anchor interval — dense collectives (TP traffic) give
dense anchors and correspondingly tight alignment.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core.tracing.events import TraceEvent


@dataclass
class CollectiveInstance:
    key: tuple              # (group ranks, op name)
    seq: int                # occurrence index
    members: dict[int, TraceEvent]  # rank -> event

    @property
    def ends(self) -> dict[int, float]:
        return {r: e.end for r, e in self.members.items()}

    @property
    def starts(self) -> dict[int, float]:
        return {r: e.ts for r, e in self.members.items()}


def reconstruct_collectives(events: list[TraceEvent]) -> list[CollectiveInstance]:
    per_key: dict[tuple, dict[int, list[TraceEvent]]] = defaultdict(lambda: defaultdict(list))
    for e in events:
        if e.kind != "coll":
            continue
        group = tuple(e.args.get("group", ()))
        if not group:
            continue
        key = (group, e.args.get("op", e.name.split("#")[0]))
        per_key[key][e.rank].append(e)
    for ranks in per_key.values():
        for lst in ranks.values():
            lst.sort(key=lambda e: e.ts)

    out: list[CollectiveInstance] = []
    for key, ranks in per_key.items():
        n = min(len(v) for v in ranks.values())
        if set(ranks) != set(key[0]):
            # missing members: match only ranks that logged events
            pass
        for i in range(n):
            out.append(CollectiveInstance(key, i, {r: v[i] for r, v in ranks.items()}))
    # annotate events with their instance id (related_sync_op)
    for idx, inst in enumerate(out):
        for e in inst.members.values():
            e.args["related_sync_op"] = idx
    return out


@dataclass
class Alignment:
    """Per-rank clock correction: local_time -> global_time."""
    linear: dict[int, tuple[float, float]]              # rank -> (a, b)
    anchors: dict[int, np.ndarray] = field(default_factory=dict)   # rank -> [n,2] (t_local, resid)

    def correct(self, rank: int, t: float | np.ndarray):
        a, b = self.linear.get(rank, (0.0, 0.0))
        t = np.asarray(t, dtype=np.float64)
        g = t - (a + b * t)
        anc = self.anchors.get(rank)
        if anc is not None and len(anc) >= 2:
            g = g - np.interp(t, anc[:, 0], anc[:, 1])
        return g


def align_clocks(
    events: list[TraceEvent],
    ref_rank: int = 0,
    *,
    piecewise: bool = True,
    instances: list[CollectiveInstance] | None = None,
) -> Alignment:
    if instances is None:
        instances = reconstruct_collectives(events)

    # anchor observations: rank r's event end vs the instance's consensus end.
    # Consensus = min over members (the true completion is when the slowest
    # arrives; offsets shift each observation, min is a robust first pass,
    # then we iterate once against the corrected consensus).
    ranks = sorted({e.rank for e in events})
    obs: dict[int, list[tuple[float, float]]] = {r: [] for r in ranks}

    # iteration 0: offsets zero; consensus = median of member ends
    lin = {r: (0.0, 0.0) for r in ranks}
    for _ in range(3):
        for r in ranks:
            obs[r] = []
        for inst in instances:
            if len(inst.members) < 2:
                continue
            corr_ends = {
                r: e.end - (lin[r][0] + lin[r][1] * e.end)
                for r, e in inst.members.items()
            }
            consensus = float(np.median(list(corr_ends.values())))
            for r, e in inst.members.items():
                # local end - consensus ~= a_r + b_r * t  (in local time)
                obs[r].append((e.end, e.end - consensus - 0.0))
        new_lin = {}
        for r in ranks:
            if r == ref_rank or not obs[r]:
                new_lin[r] = (0.0, 0.0)
                continue
            pts = np.asarray(obs[r], dtype=np.float64)
            t, d = pts[:, 0], pts[:, 1]
            if len(pts) >= 2 and (t.max() - t.min()) > 1e-9:
                A = np.stack([np.ones_like(t), t], axis=1)
                coef, *_ = np.linalg.lstsq(A, d, rcond=None)
                new_lin[r] = (float(coef[0]), float(coef[1]))
            else:
                new_lin[r] = (float(np.median(d)), 0.0)
        # re-reference so ref_rank is exactly zero
        lin = new_lin

    align = Alignment(linear=lin)
    if piecewise:
        for r in ranks:
            if r == ref_rank or not obs[r]:
                continue
            pts = np.asarray(sorted(obs[r]), dtype=np.float64)
            t = pts[:, 0]
            a, b = lin[r]
            resid = pts[:, 1] - (a + b * t)
            # moving-median residual as the piecewise correction
            if len(t) >= 4:
                k = max(len(t) // 16, 1)
                sm = np.convolve(resid, np.ones(2 * k + 1) / (2 * k + 1), mode="same")
                align.anchors[r] = np.stack([t, sm], axis=1)
    return align


def apply_alignment(events: list[TraceEvent], align: Alignment) -> list[TraceEvent]:
    out = []
    for e in events:
        ts = float(align.correct(e.rank, e.ts))
        te = float(align.correct(e.rank, e.end))
        out.append(TraceEvent(e.name, e.rank, ts, max(te - ts, 0.0), e.kind, dict(e.args)))
    return out
