"""In-memory columnar trace analytics (MegaScan §3.2 "Fast data retrieval").

The paper loads the merged Chrome trace into Perfetto and runs SQL; offline we
provide the equivalent queries over numpy columns.  The exported trace.json
stays Perfetto-compatible, so the paper's interop path also works.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tracing.events import TraceEvent


@dataclass
class TraceTable:
    rank: np.ndarray
    ts: np.ndarray
    dur: np.ndarray
    kind: np.ndarray          # unicode
    name: np.ndarray
    nbytes: np.ndarray
    peer: np.ndarray          # -1 when absent
    mb: np.ndarray            # microbatch, -1 when absent
    phase: np.ndarray

    def __len__(self) -> int:
        return len(self.ts)

    def where(self, mask: np.ndarray) -> "TraceTable":
        return TraceTable(**{
            k: getattr(self, k)[mask] for k in self.__dataclass_fields__
        })


def to_table(events: list[TraceEvent]) -> TraceTable:
    n = len(events)
    get = lambda e, k, d: e.args.get(k, d)
    return TraceTable(
        rank=np.array([e.rank for e in events], np.int32),
        ts=np.array([e.ts for e in events], np.float64),
        dur=np.array([e.dur for e in events], np.float64),
        kind=np.array([e.kind for e in events]),
        name=np.array([e.name for e in events]),
        nbytes=np.array([get(e, "bytes", 0) for e in events], np.int64),
        peer=np.array([get(e, "peer", -1) for e in events], np.int32),
        mb=np.array([get(e, "mb", -1) for e in events], np.int32),
        phase=np.array([str(get(e, "phase", "")) for e in events]),
    )


# --------------------------------------------------------------- queries ---


def bandwidth_by_edge(t: TraceTable) -> dict[tuple[int, int], dict]:
    """SELECT src, dst, median(bytes/dur), count(*) FROM p2p GROUP BY edge."""
    m = (t.kind == "p2p") & (t.nbytes > 0) & (t.dur > 0) & (t.peer >= 0)
    out: dict[tuple[int, int], list[float]] = {}
    for r, p, b, d in zip(t.rank[m], t.peer[m], t.nbytes[m], t.dur[m]):
        out.setdefault((int(r), int(p)), []).append(b / d)
    return {
        e: {"median_bps": float(np.median(v)), "n": len(v),
            "min_bps": float(np.min(v))}
        for e, v in out.items()
    }


def utilization_by_rank(t: TraceTable) -> dict[int, dict]:
    """Busy-time fractions per rank, split compute vs communication."""
    span = t.ts.max() + t.dur.max() - t.ts.min() if len(t) else 1.0
    out = {}
    for r in np.unique(t.rank):
        m = t.rank == r
        comp = float(t.dur[m & (t.kind == "compute")].sum())
        comm = float(t.dur[m & ((t.kind == "coll") | (t.kind == "p2p"))].sum())
        out[int(r)] = {
            "compute_frac": comp / span,
            "comm_frac": comm / span,
            "idle_frac": max(0.0, 1.0 - (comp + comm) / span),
        }
    return out


def slow_ops(t: TraceTable, ratio: float = 1.5) -> list[dict]:
    """Ops >= ratio x the median duration of their (name-class) group."""
    base = np.array([n.split("_")[0] for n in t.name])
    rows = []
    for cls in np.unique(base):
        m = (base == cls) & (t.kind == "compute")
        if m.sum() < 3:
            continue
        med = float(np.median(t.dur[m]))
        for i in np.nonzero(m)[0]:
            if t.dur[i] > ratio * med:
                rows.append({
                    "name": str(t.name[i]), "rank": int(t.rank[i]),
                    "dur": float(t.dur[i]), "median": med,
                    "ratio": float(t.dur[i] / med),
                })
    return sorted(rows, key=lambda r: -r["ratio"])


def iteration_breakdown(t: TraceTable) -> dict[str, float]:
    """Total seconds by phase (F/B/G) and comm kind — the per-iteration view
    the Chrome-trace timeline shows visually."""
    out = {}
    for ph in ("F", "B", "G"):
        out[f"compute_{ph}"] = float(t.dur[(t.phase == ph) & (t.kind == "compute")].sum())
    out["collective"] = float(t.dur[t.kind == "coll"].sum())
    out["p2p"] = float(t.dur[t.kind == "p2p"].sum())
    return out
