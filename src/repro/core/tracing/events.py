"""Trace event model (MegaScan §3.2 "Workload tracing").

Events carry the metadata the paper attaches via ``tracers.scope``: microbatch
index, communication volume, peer rank / participating-rank list — everything
dependency reconstruction and fault diagnosis need downstream.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any


@dataclass
class TraceEvent:
    name: str
    rank: int
    ts: float          # start, seconds in the *local* (per-rank) clock
    dur: float
    kind: str = "compute"  # compute | coll | p2p | marker
    args: dict = field(default_factory=dict)
    # well-known args:
    #   mb: microbatch index        chunk: model-chunk index
    #   bytes: payload bytes        group: tuple of participating ranks
    #   peer: peer rank (p2p)       op: operator name
    #   phase: F | B | G            dir: send | recv

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "rank": self.rank,
            "ts": self.ts,
            "dur": self.dur,
            "kind": self.kind,
            "args": {
                k: (list(v) if isinstance(v, tuple) else v)
                for k, v in self.args.items()
            },
        }

    @classmethod
    def from_json(cls, d: dict) -> "TraceEvent":
        args = dict(d.get("args", {}))
        if "group" in args and isinstance(args["group"], list):
            args["group"] = tuple(args["group"])
        return cls(d["name"], d["rank"], d["ts"], d["dur"], d.get("kind", "compute"), args)
