"""Task-graph builders for the discrete-event engine.

Training: one 3-D-parallel (DP x PP x TP) iteration.  The pipeline traversal
order is pluggable — MegaDPP's scheduler emits the (model_chunk, microbatch)
visit order per rank (DFC / BFC / 1F1B / custom) and this module lowers it
into engine tasks: stage compute (with per-layer TP collectives folded in),
inter-stage P2P sends/recvs, and the DP gradient all-reduce after the last
backward.  Rank layout follows Megatron order:
rank = dp * (PP*TP) + pp * TP + tp.

Serving: ``serving_workload`` lowers a MegaServe request trace (Poisson
arrivals, mixed lengths) under a batching policy — "continuous" (slot
admission + immediate refill; an idealized pool-less model of
``repro.serve.scheduler`` that admits into every free slot per tick and
never preempts) or "static" (length-bucketed lockstep batches, mirroring
``repro.serve.server.StaticRunner``) — into engine tasks, so scheduler
policies can be evaluated offline without touching jax.
Request ``i``'s arrival is modeled as a duration-``arrival`` task on virtual
rank ``1 + i``; serving compute lives on rank 0 and every admission depends
on the matching arrival task.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.core.simkit.engine import Task


@dataclass(frozen=True)
class Topology:
    dp: int = 1
    pp: int = 1
    tp: int = 1

    @property
    def world(self) -> int:
        return self.dp * self.pp * self.tp

    def rank(self, d: int, p: int, t: int) -> int:
        return d * self.pp * self.tp + p * self.tp + t

    def coords(self, rank: int) -> tuple[int, int, int]:
        d, rem = divmod(rank, self.pp * self.tp)
        p, t = divmod(rem, self.tp)
        return d, p, t


@dataclass(frozen=True)
class ModelProfile:
    """Per-(stage, microbatch) cost profile in seconds/bytes."""

    fwd_time: float = 1e-3
    bwd_time: float = 2e-3
    tp_bytes: int = 32 << 20         # TP collective payload per stage pass
    p2p_bytes: int = 16 << 20        # boundary activation tensor
    grad_bytes: int = 256 << 20      # DP gradient sync per rank
    act_bytes: int = 64 << 20        # activation stash per in-flight microbatch
    n_chunks: int = 1                # virtual model chunks per stage (interleaving)


# One pipeline step per rank: (kind, microbatch, chunk) with kind F or B
Step = tuple[str, int, int]


def sched_1f1b(n_micro: int, n_chunks: int, pp: int, stage: int) -> list[Step]:
    """Classic 1F1B (non-interleaved when n_chunks == 1)."""
    warmup = min(pp - stage - 1, n_micro) if n_chunks == 1 else pp - stage - 1
    steps: list[Step] = []
    if n_chunks == 1:
        fwd = list(range(n_micro))
        bwd = list(range(n_micro))
        fi = bi = 0
        for _ in range(warmup):
            steps.append(("F", fwd[fi], 0))
            fi += 1
        while bi < n_micro:
            if fi < n_micro:
                steps.append(("F", fwd[fi], 0))
                fi += 1
            steps.append(("B", bwd[bi], 0))
            bi += 1
        return steps
    # interleaved: fall back to depth-first over chunks
    return sched_dfc(n_micro, n_chunks)


def sched_dfc(n_micro: int, n_chunks: int) -> list[Step]:
    """Depth-First Computation: same microbatch through all chunks first,
    backward as early as possible (low activation memory)."""
    steps: list[Step] = []
    for m in range(n_micro):
        for c in range(n_chunks):
            steps.append(("F", m, c))
        for c in reversed(range(n_chunks)):
            steps.append(("B", m, c))
    return steps


def sched_bfc(n_micro: int, n_chunks: int) -> list[Step]:
    """Breadth-First Computation: all microbatches through one chunk first —
    earlier gradient readiness per chunk, relaxed send deadlines, but the
    activation stash peaks at n_micro x n_chunks."""
    steps: list[Step] = []
    for c in range(n_chunks):
        for m in range(n_micro):
            steps.append(("F", m, c))
    for c in reversed(range(n_chunks)):
        for m in range(n_micro):
            steps.append(("B", m, c))
    return steps


SCHEDULES = {"1f1b": sched_1f1b, "dfc": sched_dfc, "bfc": sched_bfc}

#: Every named traversal ``make_order`` accepts — the simkit schedule
#: comparison surface (benchmarks sweep this list).  "zb" is the ZB-inspired
#: B/W split from ``core.dpp.schedule.sched_zb_split``; it is stage-dependent
#: like 1f1b.
SCHEDULE_NAMES = ("1f1b", "dfc", "bfc", "zb")


def make_order(
    schedule: str | list[Step],
    n_micro: int,
    n_chunks: int,
    pp: int,
    stage: int,
) -> list[Step]:
    if isinstance(schedule, list):
        return schedule
    if schedule == "1f1b":
        return sched_1f1b(n_micro, n_chunks, pp, stage)
    if schedule == "zb":
        # local import: dpp.schedule imports this module's primitives
        from repro.core.dpp.schedule import sched_zb_split

        return sched_zb_split(n_micro, n_chunks, pp, stage)
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; one of {SCHEDULE_NAMES}"
        )
    return SCHEDULES[schedule](n_micro, n_chunks)


def build_training_step(
    topo: Topology,
    prof: ModelProfile,
    *,
    n_micro: int,
    schedule: str | dict[int, list[Step]] = "1f1b",
    async_p2p: bool = False,
    tp_per_layer_colls: int = 2,
) -> dict[int, list[Task]]:
    """Lower one training iteration to per-rank ordered task lists.

    ``schedule`` is either a named traversal or a per-stage map of explicit
    (kind, microbatch, chunk) sequences (MegaDPP emits these).
    """
    order: dict[int, list[Task]] = {r: [] for r in range(topo.world)}

    def stage_steps(p: int) -> list[Step]:
        if isinstance(schedule, dict):
            return schedule[p]
        return make_order(schedule, n_micro, prof.n_chunks, topo.pp, p)

    # ZB-style schedules split backward into B (activation grad, on the
    # critical path) and W (weight grad, dependency-free filler)
    has_w = any(
        k == "W" for p in range(topo.pp) for (k, _, _) in stage_steps(p)
    )
    bwd_time = prof.bwd_time * (0.5 if has_w else 1.0)

    for d in range(topo.dp):
        for p in range(topo.pp):
            steps = stage_steps(p)
            for t in range(topo.tp):
                r = topo.rank(d, p, t)
                tp_group = tuple(topo.rank(d, p, tt) for tt in range(topo.tp))
                for kind, m, c in steps:
                    base = f"d{d}p{p}c{c}m{m}"
                    if kind == "F":
                        deps: list[str] = []
                        if p > 0:
                            deps.append(f"recvF_{base}_t{t}")
                            order[r].append(Task(
                                tid=f"recvF_{base}_t{t}", rank=r,
                                bytes=prof.p2p_bytes // topo.tp, kind="recv",
                                deps=(f"sendF_d{d}p{p-1}c{c}m{m}_t{t}",),
                                peer=topo.rank(d, p - 1, t),
                                blocking=not async_p2p,
                                meta={"mb": m, "chunk": c, "phase": "F"},
                            ))
                        order[r].append(Task(
                            tid=f"F_{base}_t{t}", rank=r,
                            duration=prof.fwd_time, kind="compute",
                            deps=tuple(deps),
                            alloc=prof.act_bytes,
                            meta={"mb": m, "chunk": c, "phase": "F", "op": "fwd"},
                        ))
                        if topo.tp > 1:
                            order[r].append(Task(
                                tid=f"arF_{base}_t{t}", rank=r,
                                bytes=prof.tp_bytes * tp_per_layer_colls,
                                kind="allreduce",
                                deps=(f"F_{base}_t{t}",),
                                coll_id=f"arF_{base}", group=tp_group,
                                meta={"mb": m, "chunk": c, "phase": "F"},
                            ))
                        if p < topo.pp - 1:
                            dep = (
                                f"arF_{base}_t{t}" if topo.tp > 1 else f"F_{base}_t{t}"
                            )
                            order[r].append(Task(
                                tid=f"sendF_{base}_t{t}", rank=r,
                                bytes=prof.p2p_bytes // topo.tp, kind="send",
                                deps=(dep,),
                                peer=topo.rank(d, p + 1, t),
                                blocking=not async_p2p,
                                meta={"mb": m, "chunk": c, "phase": "F"},
                            ))
                    elif kind == "W":  # deferred weight-grad (ZB filler)
                        order[r].append(Task(
                            tid=f"W_{base}_t{t}", rank=r,
                            duration=prof.bwd_time * 0.5, kind="compute",
                            deps=(f"B_{base}_t{t}",),
                            meta={"mb": m, "chunk": c, "phase": "W", "op": "wgrad"},
                        ))
                    else:  # backward
                        deps = [f"F_{base}_t{t}"]
                        if p < topo.pp - 1:
                            deps.append(f"recvB_{base}_t{t}")
                            order[r].append(Task(
                                tid=f"recvB_{base}_t{t}", rank=r,
                                bytes=prof.p2p_bytes // topo.tp, kind="recv",
                                deps=(f"sendB_d{d}p{p+1}c{c}m{m}_t{t}",),
                                peer=topo.rank(d, p + 1, t),
                                blocking=not async_p2p,
                                meta={"mb": m, "chunk": c, "phase": "B"},
                            ))
                        order[r].append(Task(
                            tid=f"B_{base}_t{t}", rank=r,
                            duration=bwd_time, kind="compute",
                            deps=tuple(deps),
                            free=prof.act_bytes,
                            meta={"mb": m, "chunk": c, "phase": "B", "op": "bwd"},
                        ))
                        if topo.tp > 1:
                            order[r].append(Task(
                                tid=f"arB_{base}_t{t}", rank=r,
                                bytes=prof.tp_bytes * tp_per_layer_colls,
                                kind="allreduce",
                                deps=(f"B_{base}_t{t}",),
                                coll_id=f"arB_{base}", group=tp_group,
                                meta={"mb": m, "chunk": c, "phase": "B"},
                            ))
                        if p > 0:
                            dep = (
                                f"arB_{base}_t{t}" if topo.tp > 1 else f"B_{base}_t{t}"
                            )
                            order[r].append(Task(
                                tid=f"sendB_{base}_t{t}", rank=r,
                                bytes=prof.p2p_bytes // topo.tp, kind="send",
                                deps=(dep,),
                                peer=topo.rank(d, p - 1, t),
                                blocking=not async_p2p,
                                meta={"mb": m, "chunk": c, "phase": "B"},
                            ))

    # DP gradient all-reduce (issued after the rank's last backward)
    if topo.dp > 1:
        for p in range(topo.pp):
            for t in range(topo.tp):
                for d in range(topo.dp):
                    r = topo.rank(d, p, t)
                    dp_group = tuple(topo.rank(dd, p, t) for dd in range(topo.dp))
                    last_b = [tt.tid for tt in order[r] if tt.kind == "compute"][-1]
                    order[r].append(Task(
                        tid=f"grad_ar_p{p}t{t}_d{d}", rank=r,
                        bytes=prof.grad_bytes, kind="allreduce",
                        deps=(last_b,),
                        coll_id=f"grad_ar_p{p}t{t}", group=dp_group,
                        meta={"phase": "G"},
                    ))
    return order


# ---------------------------------------------------------------------------
# MegaServe: offline serving-policy evaluation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestSpec:
    rid: int
    arrival: float          # seconds
    prompt_len: int
    max_new: int


@dataclass(frozen=True)
class ServeProfile:
    """Serving cost model (seconds)."""

    prefill_time_per_token: float = 50e-6
    decode_step_base: float = 2e-3       # fixed cost of one engine step
    decode_step_per_seq: float = 0.2e-3  # marginal cost per active slot


def poisson_requests(
    n: int,
    rate: float,
    *,
    prompt_lens: Sequence[int] = (16, 32, 64, 128, 256),
    max_new_range: tuple[int, int] = (4, 48),
    seed: int = 0,
) -> list[RequestSpec]:
    """Poisson arrivals at ``rate``/s with mixed prompt/generation lengths;
    ``max_new_range`` is inclusive on both ends."""
    import numpy as np

    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        out.append(RequestSpec(
            rid=i,
            arrival=t,
            prompt_len=int(rng.choice(prompt_lens)),
            max_new=int(rng.integers(*max_new_range, endpoint=True)),
        ))
    return out


def _spec_of(rng, i: int, t: float, prompt_lens, max_new_range) -> RequestSpec:
    return RequestSpec(
        rid=i,
        arrival=t,
        prompt_len=int(rng.choice(prompt_lens)),
        max_new=int(rng.integers(*max_new_range, endpoint=True)),
    )


def bursty_requests(
    n: int,
    rate: float,
    *,
    burst_mult: float = 8.0,
    burst_frac: float = 0.2,
    burst_dwell_s: float = 0.2,
    prompt_lens: Sequence[int] = (16, 32, 64, 128, 256),
    max_new_range: tuple[int, int] = (4, 48),
    seed: int = 0,
) -> list[RequestSpec]:
    """Markov-modulated Poisson arrivals (MMPP-2): the process alternates
    between a *calm* state and a *burst* state whose rate is ``burst_mult``
    times higher, with exponential dwell times sized so a ``burst_frac``
    fraction of time is spent bursting and the long-run average rate is
    ``rate``/s.  Inter-arrival CV > 1 for any ``burst_mult`` > 1 — the
    traffic shape that separates load-aware routing policies from
    round-robin (a burst lands on whichever replica is unlucky).
    """
    import numpy as np

    if burst_mult <= 1.0 or not (0.0 < burst_frac < 1.0):
        raise ValueError(
            f"bursty_requests needs burst_mult > 1 and 0 < burst_frac < 1 "
            f"(got {burst_mult}, {burst_frac})"
        )
    rng = np.random.default_rng(seed)
    calm_rate = rate / (1.0 - burst_frac + burst_frac * burst_mult)
    rates = (calm_rate, calm_rate * burst_mult)
    # exponential state-holding times with the stationary split burst_frac
    dwell = (burst_dwell_s * (1.0 - burst_frac) / burst_frac, burst_dwell_s)
    state = 0
    t = 0.0
    t_switch = float(rng.exponential(dwell[state]))
    out: list[RequestSpec] = []
    while len(out) < n:
        dt = float(rng.exponential(1.0 / rates[state]))
        if t + dt >= t_switch:
            # no arrival before the state flips: restart the (memoryless)
            # exponential clock at the switch with the new state's rate
            t = t_switch
            state = 1 - state
            t_switch = t + float(rng.exponential(dwell[state]))
            continue
        t += dt
        out.append(_spec_of(rng, len(out), t, prompt_lens, max_new_range))
    return out


def diurnal_requests(
    n: int,
    rate: float,
    *,
    period_s: float = 10.0,
    depth: float = 0.8,
    prompt_lens: Sequence[int] = (16, 32, 64, 128, 256),
    max_new_range: tuple[int, int] = (4, 48),
    seed: int = 0,
) -> list[RequestSpec]:
    """Non-homogeneous Poisson arrivals whose rate follows a sinusoid —
    ``rate(t) = rate * (1 + depth * sin(2 pi t / period_s))`` — the
    compressed "millions of users across timezones" diurnal cycle.  Sampled
    by thinning against the peak rate, so the realized arrival density
    tracks the sinusoid exactly in expectation.
    """
    import math

    import numpy as np

    if not (0.0 < depth <= 1.0):
        raise ValueError(f"diurnal depth must be in (0, 1], got {depth}")
    rng = np.random.default_rng(seed)
    peak = rate * (1.0 + depth)
    t = 0.0
    out: list[RequestSpec] = []
    while len(out) < n:
        t += float(rng.exponential(1.0 / peak))
        lam = rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period_s))
        if float(rng.uniform()) * peak <= lam:
            out.append(_spec_of(rng, len(out), t, prompt_lens, max_new_range))
    return out


def serving_workload(
    requests: Sequence[RequestSpec],
    *,
    policy: str = "continuous",
    num_slots: int = 4,
    batch_size: int | None = None,
    prof: ServeProfile = ServeProfile(),
) -> dict[int, list[Task]]:
    """Lower a request trace under a batching policy to engine task lists.

    The policy decisions (admission order, batch formation) are simulated
    here against ``prof``; the engine then reproduces the timeline from the
    emitted dependency structure, so altering link/fault models or profiles
    re-times the same policy.  Decode tasks carry ``meta={"tokens": k}`` =
    useful tokens emitted that step; sum them for throughput.
    """
    arrive = {
        r.rid: Task(
            tid=f"arrive_r{r.rid}", rank=1 + i, duration=r.arrival,
            kind="compute", meta={"phase": "arrive", "rid": r.rid},
        )
        for i, r in enumerate(requests)
    }
    serve: list[Task] = []
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))

    if policy == "continuous":
        now = 0.0
        waiting = list(reqs)
        slots: dict[int, list] = {}      # slot -> [rid, remaining]
        step = 0
        while waiting or slots:
            if not slots and waiting and waiting[0].arrival > now:
                now = waiting[0].arrival
            free = [s for s in range(num_slots) if s not in slots]
            for s in free:
                nxt = next((r for r in waiting if r.arrival <= now), None)
                if nxt is None:
                    break
                waiting.remove(nxt)
                dur = nxt.prompt_len * prof.prefill_time_per_token
                serve.append(Task(
                    tid=f"prefill_r{nxt.rid}", rank=0, duration=dur,
                    kind="compute", deps=(f"arrive_r{nxt.rid}",),
                    meta={"phase": "prefill", "rid": nxt.rid, "tokens": 1},
                ))
                now = max(now, nxt.arrival) + dur
                # prefill emits the first token; remaining decode budget:
                slots[s] = [nxt.rid, nxt.max_new - 1]
                if slots[s][1] <= 0:
                    del slots[s]
            if slots:
                active = len(slots)
                dur = prof.decode_step_base + active * prof.decode_step_per_seq
                serve.append(Task(
                    tid=f"dec{step}", rank=0, duration=dur, kind="compute",
                    meta={"phase": "decode", "active": active, "tokens": active},
                ))
                now += dur
                for s in list(slots):
                    slots[s][1] -= 1
                    if slots[s][1] <= 0:
                        del slots[s]
            step += 1
    elif policy == "static":
        # mirrors server.StaticRunner: length-bucketed batches (one prompt
        # length per batch, so no padding cost), buckets processed in
        # ascending length, batch members in arrival order, launch gated on
        # the last member's arrival, lockstep to the slowest budget
        B = batch_size or num_slots
        buckets: dict[int, list[RequestSpec]] = {}
        for r in reqs:
            buckets.setdefault(r.prompt_len, []).append(r)
        b = 0
        for plen in sorted(buckets):
            group = buckets[plen]
            for bi in range(0, len(group), B):
                members = group[bi : bi + B]
                steps = max(r.max_new for r in members)
                serve.append(Task(
                    tid=f"prefill_b{b}", rank=0,
                    duration=len(members) * plen * prof.prefill_time_per_token,
                    kind="compute",
                    deps=tuple(f"arrive_r{r.rid}" for r in members),
                    meta={"phase": "prefill", "batch": b,
                          "tokens": len(members)},
                ))
                for s in range(steps - 1):
                    useful = sum(1 for r in members if r.max_new - 1 > s)
                    serve.append(Task(
                        tid=f"dec_b{b}_s{s}", rank=0,
                        duration=prof.decode_step_base
                        + len(members) * prof.decode_step_per_seq,
                        kind="compute",
                        meta={"phase": "decode", "active": len(members),
                              "tokens": useful},
                    ))
                b += 1
    else:
        raise ValueError(f"unknown serving policy {policy!r}")

    return {0: serve, **{t.rank: [t] for t in arrive.values()}}


def serving_throughput(result) -> dict:
    """Aggregate tokens/s + makespan from a ``serving_workload`` run."""
    tokens = sum(
        r.meta.get("tokens", 0) for r in result.records if r.rank == 0
    )
    return {
        "tokens": tokens,
        "makespan": result.makespan,
        "tokens_per_s": tokens / result.makespan if result.makespan else 0.0,
    }


# ---------------------------------------------------------------------------
# MegaRoute: placement policies + SLO-aware admission (shared with the live
# router — ``repro.serve.router`` imports these, so the offline simkit
# evaluation and the online router run ONE implementation of the decision
# logic; this module must stay jax-free and must not import ``repro.serve``)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlacementView:
    """One replica's load snapshot, as the placement policies see it."""

    queued: int                  # requests waiting for a slot
    queued_prefill_tokens: int   # prompt tokens ahead in that queue
    active: int                  # slots currently decoding
    kv_used_frac: float          # physical KV pool occupancy in [0, 1]


def estimate_ttft(
    view: PlacementView, prompt_len: int, prof: ServeProfile = ServeProfile()
) -> float:
    """Predicted TTFT if a ``prompt_len`` request were enqueued on ``view``'s
    replica now: the prefill work ahead of it (queued prompts + its own)
    plus one engine tick per queued request ahead (admission is one-per-tick
    shaped) at the replica's current decode cost."""
    prefill = (
        (view.queued_prefill_tokens + prompt_len) * prof.prefill_time_per_token
    )
    tick = prof.decode_step_base + view.active * prof.decode_step_per_seq
    return prefill + (view.queued + 1) * tick


def _place_round_robin(views: Sequence[PlacementView], rr: int) -> int:
    return rr % len(views)


def _place_least_kv(views: Sequence[PlacementView], rr: int) -> int:
    return min(
        range(len(views)),
        key=lambda i: (views[i].kv_used_frac, views[i].queued, i),
    )


def _place_jsq(views: Sequence[PlacementView], rr: int) -> int:
    return min(
        range(len(views)),
        key=lambda i: (views[i].queued + views[i].active, i),
    )


#: Placement policies: view snapshots + a round-robin cursor -> replica index.
POLICIES = {
    "round_robin": _place_round_robin,
    "least_kv": _place_least_kv,
    "jsq": _place_jsq,
}


def place(policy: str, views: Sequence[PlacementView], rr: int = 0) -> int:
    if policy not in POLICIES:
        raise ValueError(
            f"unknown router policy {policy!r}; one of {sorted(POLICIES)}"
        )
    return POLICIES[policy](views, rr)


def admission_decision(
    policy: str,
    views: Sequence[PlacementView],
    prompt_len: int,
    *,
    prof: ServeProfile = ServeProfile(),
    rr: int = 0,
    slo_ttft_s: float = 0.0,
    shed: bool = True,
) -> tuple[str, int, float]:
    """SLO-aware admission: returns ``(action, replica, estimated_ttft)``
    with action one of ``admit`` (the policy's pick meets the SLO, or no SLO
    is set), ``redirect`` (the pick would bust it but another replica
    doesn't), or ``shed`` (every replica busts it and shedding is enabled;
    with ``shed=False`` the request is admitted on the least-bad replica)."""
    primary = place(policy, views, rr)
    est = estimate_ttft(views[primary], prompt_len, prof)
    if slo_ttft_s <= 0 or est <= slo_ttft_s:
        return "admit", primary, est
    best = min(
        range(len(views)),
        key=lambda i: estimate_ttft(views[i], prompt_len, prof),
    )
    best_est = estimate_ttft(views[best], prompt_len, prof)
    if best != primary and best_est <= slo_ttft_s:
        return "redirect", best, best_est
    if shed:
        return "shed", best, best_est
    return "admit", best, best_est


class _ReplicaSim:
    """One replica of ``router_workload``: the continuous-batching tick
    model of ``serving_workload`` plus the KV-pool dynamics that make
    placement matter — optimistic admission (a prompt admits whenever its
    prefill footprint fits) and preemption-by-recompute when decode growth
    overruns ``kv_capacity_tokens``, mirroring ``repro.serve.scheduler``.
    An occupancy-blind policy that keeps stuffing a hot replica pays the
    recompute amplification; that is the tail ``least_kv``/``jsq`` avoid.
    Advanced event-driven to each arrival so placement sees load snapshots
    at decision time."""

    def __init__(self, idx: int, num_slots: int, prof: ServeProfile,
                 kv_capacity_tokens: int, speed: float = 1.0):
        self.idx = idx
        self.prof = prof
        self.num_slots = num_slots
        self.kv_cap = kv_capacity_tokens
        self.speed = speed
        self.now = 0.0
        # waiting: [rid, arrival, prefill_tokens, emit_left, first_admission]
        self.waiting: list[list] = []
        # slots: slot -> [rid, emit_left, held_tokens, admit_seq]
        self.slots: dict[int, list] = {}
        self.tasks: list[Task] = []
        self.step = 0
        self.preemptions = 0
        self._seq = 0

    def enqueue(self, spec: RequestSpec) -> None:
        self.waiting.append(
            [spec.rid, spec.arrival, spec.prompt_len, spec.max_new, True])

    def _held(self) -> int:
        return sum(st[2] for st in self.slots.values())

    def view(self) -> PlacementView:
        return PlacementView(
            queued=len(self.waiting),
            queued_prefill_tokens=sum(e[2] for e in self.waiting),
            active=len(self.slots),
            kv_used_frac=self._held() / max(self.kv_cap, 1),
        )

    def _tick(self) -> None:
        for s in [s for s in range(self.num_slots) if s not in self.slots]:
            if not self.waiting:
                break
            rid, arr, ptoks, emit_left, first = self.waiting[0]
            if self.slots and self._held() + ptoks + 1 > self.kv_cap:
                break   # FIFO head-of-line, like the live admit loop
            self.waiting.pop(0)
            dur = ptoks * self.prof.prefill_time_per_token / self.speed
            done = [rid] if emit_left <= 1 else []
            self.tasks.append(Task(
                tid=f"prefill_r{rid}_{self.step}s{s}", rank=self.idx,
                duration=dur, kind="compute", deps=(f"arrive_r{rid}",),
                meta={"phase": "prefill", "rid": rid, "replica": self.idx,
                      "arrival": arr, "first": first, "tokens": 1,
                      "finished": done},
            ))
            self.now += dur
            if not done:  # the prefill emitted one token already
                self._seq += 1
                self.slots[s] = [rid, emit_left - 1, ptoks + 1, self._seq]
        if self.slots:
            active = len(self.slots)
            dur = (self.prof.decode_step_base
                   + active * self.prof.decode_step_per_seq) / self.speed
            fin, pre = [], []
            for s in list(self.slots):
                st = self.slots[s]
                st[1] -= 1
                st[2] += 1
                if st[1] <= 0:
                    fin.append(st[0])
                    del self.slots[s]
            # pool overrun: preempt youngest-admitted slots (LIFO, like
            # Scheduler.ensure_capacity); their held tokens recompute later
            while self._held() > self.kv_cap and len(self.slots) > 1:
                s = max(self.slots, key=lambda k: self.slots[k][3])
                rid_p, emit_left_p, held_p, _ = self.slots.pop(s)
                self.waiting.insert(0, [rid_p, 0.0, held_p, emit_left_p, False])
                pre.append(rid_p)
                self.preemptions += 1
            self.tasks.append(Task(
                tid=f"dec_n{self.idx}_s{self.step}", rank=self.idx,
                duration=dur, kind="compute",
                meta={"phase": "decode", "replica": self.idx,
                      "active": active, "tokens": active, "finished": fin,
                      "preempted": pre},
            ))
            self.now += dur
        self.step += 1

    def advance_to(self, t: float) -> None:
        while self.waiting or self.slots:
            if self.now >= t:
                return
            self._tick()
        self.now = max(self.now, t)


def router_workload(
    requests: Sequence[RequestSpec],
    *,
    policy: str = "round_robin",
    n_replicas: int = 2,
    num_slots: int = 4,
    prof: ServeProfile = ServeProfile(),
    slo_ttft_s: float = 0.0,
    shed: bool = True,
    kv_capacity_tokens: int = 2048,
    replica_speeds: Sequence[float] | None = None,
) -> dict[int, list[Task]]:
    """Lower a request trace through MegaRoute's placement + admission onto
    ``n_replicas`` idealized replicas, as engine task lists — the offline
    policy-evaluation surface (same ``admission_decision`` the live router
    calls).  Ranks: replica ``r`` -> rank ``r``; request ``i``'s arrival ->
    rank ``n_replicas + i``; a shed request becomes a zero-duration ``shed``
    task on its arrival rank, so every request either finishes on a replica
    (``finished`` rid lists on prefill/decode tasks) or is counted shed —
    the conservation law ``router_summary`` checks.  ``replica_speeds``
    models heterogeneous/degraded replicas (a 0.5 entry runs at half speed
    — the straggler-replica scenario where load-aware placement separates
    from round-robin)."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    speeds = list(replica_speeds) if replica_speeds else [1.0] * n_replicas
    if len(speeds) != n_replicas:
        raise ValueError(
            f"replica_speeds has {len(speeds)} entries for {n_replicas} replicas"
        )
    reps = [_ReplicaSim(i, num_slots, prof, kv_capacity_tokens, speeds[i])
            for i in range(n_replicas)]
    arrive: dict[int, list[Task]] = {}
    shed_tasks: dict[int, list[Task]] = {}
    rr = 0
    for i, spec in enumerate(sorted(requests, key=lambda r: (r.arrival, r.rid))):
        rank = n_replicas + i
        arrive[rank] = [Task(
            tid=f"arrive_r{spec.rid}", rank=rank, duration=spec.arrival,
            kind="compute", meta={"phase": "arrive", "rid": spec.rid},
        )]
        for rep in reps:
            rep.advance_to(spec.arrival)
        action, idx, est = admission_decision(
            policy, [rep.view() for rep in reps], spec.prompt_len,
            prof=prof, rr=rr, slo_ttft_s=slo_ttft_s, shed=shed,
        )
        rr += 1
        if action == "shed":
            shed_tasks.setdefault(rank, []).append(Task(
                tid=f"shed_r{spec.rid}", rank=rank, duration=0.0,
                kind="compute", deps=(f"arrive_r{spec.rid}",),
                meta={"phase": "shed", "rid": spec.rid, "est_ttft": est},
            ))
            continue
        reps[idx].enqueue(spec)
    for rep in reps:
        rep.advance_to(float("inf"))
    out = {rep.idx: rep.tasks for rep in reps}
    for rank, tasks in arrive.items():
        out[rank] = tasks + shed_tasks.get(rank, [])
    return out


def router_summary(result, *, n_replicas: int) -> dict:
    """Digest a ``router_workload`` engine run: TTFT percentiles (prefill
    task end minus arrival), shed/finished rid sets (conservation: their
    union must cover every submitted rid), and per-replica token counts
    (load skew)."""

    def pct(xs: list[float], q: float) -> float:
        if not xs:
            return 0.0
        ys = sorted(xs)
        return ys[max(0, min(len(ys) - 1, int(round(q / 100 * (len(ys) - 1)))))]

    submitted: set[int] = set()
    finished: set[int] = set()
    shed: set[int] = set()
    ttfts: list[float] = []
    preemptions = 0
    replica_tokens = [0] * n_replicas
    for rec in result.records:
        phase = rec.meta.get("phase")
        if phase == "arrive":
            submitted.add(rec.meta["rid"])
        elif phase == "shed":
            shed.add(rec.meta["rid"])
        elif phase == "prefill":
            if rec.meta.get("first", True):
                ttfts.append(rec.end - rec.meta["arrival"])
            finished.update(rec.meta.get("finished", ()))
            replica_tokens[rec.rank] += rec.meta.get("tokens", 0)
        elif phase == "decode":
            finished.update(rec.meta.get("finished", ()))
            preemptions += len(rec.meta.get("preempted", ()))
            replica_tokens[rec.rank] += rec.meta.get("tokens", 0)
    skew = (
        max(replica_tokens) / max(min(replica_tokens), 1)
        if replica_tokens else 1.0
    )
    return {
        "submitted": len(submitted),
        "finished": len(finished),
        "shed": len(shed),
        "conserved": submitted == (finished | shed),
        "shed_rate": len(shed) / max(len(submitted), 1),
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "preemptions": preemptions,
        "replica_tokens": replica_tokens,
        "load_skew": skew,
        "makespan": result.makespan,
    }
