"""Discrete-event engine for 3-D-parallel training timelines.

One engine serves three MegatronApp modules:

* **MegaScan** — generates realistic per-rank traces (with injectable
  down-clocked ranks, degraded links, clock offset/drift/jitter) that feed the
  alignment + straggler-detection pipeline;
* **MegaDPP** — evaluates traversal orders (DFC / BFC / 1F1B / best-effort)
  for makespan, communication overlap, and peak activation memory;
* **MegaFBD** — evaluates forward/backward placements on heterogeneous
  devices and demonstrates the deadlock the communication coordinator
  prevents (mismatched collective issue orders block forever — the engine
  detects this).

Execution semantics mirror a blocking runtime (NCCL-style):

* each rank executes its task list **in order**; a task starts when the rank
  is free and all its dependencies have finished;
* a collective starts only when *all* participating ranks have reached it
  (their cursors point at the collective and its deps are met); all members
  finish together;
* point-to-point transfers occupy a (src, dst) link; a link admits at most
  ``link_concurrency`` simultaneous transfers (1 = serialized NCCL-ish,
  >1 = MegaDPP's async P2P library).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Iterable

import numpy as np


@dataclass
class Task:
    tid: str
    rank: int
    duration: float = 0.0          # seconds of pure compute (scaled by speed)
    bytes: int = 0                 # payload for comm tasks
    kind: str = "compute"          # compute|allreduce|allgather|reducescatter|send|recv|alltoall
    deps: tuple[str, ...] = ()
    coll_id: str | None = None     # shared by all members of one collective
    group: tuple[int, ...] = ()    # participating ranks for collectives
    peer: int | None = None        # for send/recv
    meta: dict = field(default_factory=dict)
    alloc: int = 0                 # activation bytes allocated on completion
    free: int = 0                  # activation bytes freed on completion
    blocking: bool = True          # False = async issue (MegaDPP P2P library):
                                   # the rank pays only launch latency; the
                                   # transfer itself occupies the link


@dataclass
class TaskRecord:
    tid: str
    rank: int
    start: float
    end: float
    kind: str
    bytes: int = 0
    meta: dict = field(default_factory=dict)


class DeadlockError(RuntimeError):
    def __init__(self, msg: str, blocked: dict[int, str]):
        super().__init__(msg)
        self.blocked = blocked


@dataclass
class FaultModel:
    """Injectable anomalies (MegaScan ground truth)."""

    compute_slowdown: dict[int, float] = field(default_factory=dict)  # rank -> x
    link_slowdown: dict[tuple[int, int], float] = field(default_factory=dict)
    jitter: float = 0.0            # multiplicative task-duration noise (sigma)
    seed: int = 0

    def speed(self, rank: int) -> float:
        return self.compute_slowdown.get(rank, 1.0)

    def link(self, src: int, dst: int) -> float:
        return self.link_slowdown.get((src, dst), 1.0)

    def merged(
        self,
        *,
        compute_slowdown: dict[int, float] | None = None,
        link_slowdown: dict[tuple[int, int], float] | None = None,
    ) -> "FaultModel":
        """A new model with fresh telemetry folded over this one (newer
        observations win) — how ``Planner.replan`` and the live ft
        controller update the resource picture between iterations."""
        return FaultModel(
            compute_slowdown={**self.compute_slowdown, **(compute_slowdown or {})},
            link_slowdown={**self.link_slowdown, **(link_slowdown or {})},
            jitter=self.jitter,
            seed=self.seed,
        )


@dataclass
class EngineResult:
    records: list[TaskRecord]
    makespan: float
    peak_memory: dict[int, int]          # rank -> peak activation bytes
    per_rank_busy: dict[int, float]

    def by_rank(self) -> dict[int, list[TaskRecord]]:
        out: dict[int, list[TaskRecord]] = {}
        for r in self.records:
            out.setdefault(r.rank, []).append(r)
        for lst in out.values():
            lst.sort(key=lambda t: t.start)
        return out


class Engine:
    def __init__(
        self,
        *,
        link_bandwidth: float = 50e9,      # bytes/s per ICI link
        collective_bandwidth: float = 50e9,
        base_latency: float = 5e-6,        # per-op launch latency
        link_concurrency: int = 1,
        faults: FaultModel | None = None,
    ):
        self.link_bandwidth = link_bandwidth
        self.collective_bandwidth = collective_bandwidth
        self.base_latency = base_latency
        self.link_concurrency = link_concurrency
        self.faults = faults or FaultModel()
        self._rng = np.random.default_rng(self.faults.seed)

    # ------------------------------------------------------------------
    def _task_time(self, t: Task) -> float:
        f = self.faults
        if t.kind == "compute":
            dur = t.duration / f.speed(t.rank)
        elif t.kind in ("send", "recv"):
            bw = self.link_bandwidth * f.link(t.rank, t.peer if t.peer is not None else t.rank)
            dur = t.bytes / bw
        else:  # collectives: slowest member's effective bandwidth bounds it
            slow = min(
                (f.link(r, r2) for r in t.group for r2 in t.group if r != r2),
                default=1.0,
            )
            dur = t.bytes / (self.collective_bandwidth * slow)
        if f.jitter > 0:
            dur *= float(
                np.exp(self._rng.normal(0.0, f.jitter))
            )
        return dur + self.base_latency

    # ------------------------------------------------------------------
    def run(self, order: dict[int, list[Task]]) -> EngineResult:
        """Execute per-rank ordered task lists; returns the timeline."""
        tasks: dict[str, Task] = {}
        for lst in order.values():
            for t in lst:
                if t.tid in tasks:
                    raise ValueError(f"duplicate task id {t.tid}")
                tasks[t.tid] = t

        finish: dict[str, float] = {}
        cursor = {r: 0 for r in order}
        rank_free = {r: 0.0 for r in order}
        records: list[TaskRecord] = []
        mem = {r: 0 for r in order}
        peak = {r: 0 for r in order}
        busy = {r: 0.0 for r in order}
        # collective rendezvous: coll_id -> {rank: ready_time}
        arrivals: dict[str, dict[int, float]] = {}
        # link occupancy for P2P concurrency limits: (src,dst) -> end times
        links: dict[tuple[int, int], list[float]] = {}

        n_total = sum(len(v) for v in order.values())
        n_done = 0
        progressed = True
        while n_done < n_total:
            if not progressed:
                blocked = {
                    r: order[r][c].tid for r, c in cursor.items() if c < len(order[r])
                }
                raise DeadlockError(
                    f"no runnable task ({n_done}/{n_total} done); "
                    f"blocked={blocked}", blocked,
                )
            progressed = False

            for r in order:
                c = cursor[r]
                if c >= len(order[r]):
                    continue
                t = order[r][c]
                if any(d not in finish for d in t.deps):
                    continue
                dep_ready = max((finish[d] for d in t.deps), default=0.0)
                ready = max(dep_ready, rank_free[r])

                if t.coll_id is not None:
                    arr = arrivals.setdefault(t.coll_id, {})
                    arr[r] = ready
                    if set(arr) != set(t.group):
                        # mark arrival but cannot start yet; rank blocks here
                        continue
                    start = max(arr.values())
                    dur = self._task_time(t)
                    end = start + dur
                    for rr in t.group:
                        member = order[rr][cursor[rr]]
                        finish[member.tid] = end
                        records.append(TaskRecord(
                            member.tid, rr, arr[rr], end, member.kind,
                            member.bytes, member.meta,
                        ))
                        rank_free[rr] = end
                        busy[rr] += end - arr[rr]
                        mem[rr] += member.alloc - member.free
                        peak[rr] = max(peak[rr], mem[rr])
                        cursor[rr] += 1
                        n_done += 1
                    del arrivals[t.coll_id]
                    progressed = True
                    continue

                if t.kind in ("send", "recv") and t.peer is not None:
                    edge = (min(t.rank, t.peer), max(t.rank, t.peer))
                    q = links.setdefault(edge, [])
                    # admit when a slot frees up
                    active = [e for e in q if e > ready]
                    if len(active) >= self.link_concurrency:
                        start = sorted(active)[-self.link_concurrency]
                    else:
                        start = ready
                else:
                    start = ready

                dur = self._task_time(t)
                end = start + dur
                if t.kind in ("send", "recv") and t.peer is not None:
                    links.setdefault(edge, []).append(end)
                finish[t.tid] = end
                records.append(TaskRecord(t.tid, r, start, end, t.kind, t.bytes, t.meta))
                if t.blocking:
                    rank_free[r] = end
                    busy[r] += dur
                else:
                    # async issue: the rank only pays the launch latency; the
                    # dependent consumer still waits for the transfer finish
                    rank_free[r] = max(rank_free[r], ready + self.base_latency)
                    busy[r] += self.base_latency
                mem[r] += t.alloc - t.free
                peak[r] = max(peak[r], mem[r])
                cursor[r] += 1
                n_done += 1
                progressed = True

        makespan = max(finish.values(), default=0.0)
        return EngineResult(records, makespan, peak, busy)
