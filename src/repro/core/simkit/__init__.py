from repro.core.simkit.engine import (
    DeadlockError,
    Engine,
    EngineResult,
    FaultModel,
    Task,
    TaskRecord,
)
from repro.core.simkit.workload import (
    ModelProfile,
    Topology,
    build_training_step,
)

__all__ = [
    "DeadlockError",
    "Engine",
    "EngineResult",
    "FaultModel",
    "Task",
    "TaskRecord",
    "ModelProfile",
    "Topology",
    "build_training_step",
]
