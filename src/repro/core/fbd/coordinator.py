"""MegaFBD communication coordinator (§4.2) — faithful bit-vector protocol.

Multiple worker threads (virtual ranks) share one GPU/control thread.  A
collective may launch only once *every* member has posted the same request;
the coordinator tracks readiness in a bit-vector table of shape
[n_groups x n_virtual_ranks] (O(G) state), aligns the flattened table across
control threads with a bitwise-OR all-reduce, and launches ready groups in
ascending group order (no contention / starvation).

``run_fcfs`` models the naive alternative the paper warns about: each control
thread launches its workers' requests first-come-first-served; launching a
not-yet-ready collective blocks the whole control thread — with unlucky
arrival interleavings this deadlocks (test_fbd reproduces it).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class CollectiveRequest:
    group_id: int
    vrank: int


@dataclass
class ThreadProgram:
    """A virtual rank's ordered list of collectives (it blocks on each)."""
    vrank: int
    control: int               # hosting control thread (physical GPU)
    group_ids: list[int] = field(default_factory=list)


class BitVectorCoordinator:
    def __init__(self, groups: dict[int, tuple[int, ...]], n_vranks: int,
                 n_controls: int):
        self.groups = groups
        self.n_vranks = n_vranks
        self.n_controls = n_controls
        # one table per control thread; alignment ORs them together
        self.tables = np.zeros((n_controls, len(groups), n_vranks), dtype=bool)
        self.gids = sorted(groups)
        self.gid_row = {g: i for i, g in enumerate(self.gids)}
        self.expected = np.zeros((len(groups), n_vranks), dtype=bool)
        for g, members in groups.items():
            for v in members:
                self.expected[self.gid_row[g], v] = True

    # step 1: registration
    def register(self, control: int, req: CollectiveRequest) -> None:
        self.tables[control, self.gid_row[req.group_id], req.vrank] = True

    # step 2: alignment (bitwise-OR all-reduce over the flattened tensor)
    def align(self) -> np.ndarray:
        return np.logical_or.reduce(self.tables, axis=0)

    # step 3+4: readiness check, ordered execution
    def ready_groups(self) -> list[int]:
        merged = self.align()
        out = []
        for g in self.gids:  # ascending group order
            row = self.gid_row[g]
            if (merged[row] & self.expected[row]).sum() == self.expected[row].sum() \
                    and self.expected[row].any():
                out.append(g)
        return out

    def complete(self, group_id: int) -> None:
        row = self.gid_row[group_id]
        self.tables[:, row, :] = False
        self.expected[row, :] = False  # single-shot instance

    @property
    def state_bytes(self) -> int:
        return self.tables.size  # O(n_groups) per control thread


def run_with_coordinator(
    programs: list[ThreadProgram],
    groups: dict[int, tuple[int, ...]],
    n_controls: int,
    max_rounds: int = 10_000,
) -> list[int]:
    """Simulate the protocol; returns the global launch order.  Raises
    RuntimeError on no-progress (cannot happen for consistent programs)."""
    n_vranks = len(programs)
    coord = BitVectorCoordinator(groups, n_vranks, n_controls)
    cursor = {p.vrank: 0 for p in programs}
    by_vrank = {p.vrank: p for p in programs}
    launched: list[int] = []
    total = sum(len(p.group_ids) for p in programs)
    done = 0
    rounds = 0
    while done < total:
        rounds += 1
        if rounds > max_rounds:
            raise RuntimeError("coordinator made no progress")
        # every blocked worker registers its next collective
        for p in programs:
            c = cursor[p.vrank]
            if c < len(p.group_ids):
                coord.register(p.control, CollectiveRequest(p.group_ids[c], p.vrank))
        ready = coord.ready_groups()
        if not ready:
            raise RuntimeError(
                f"stuck: no group ready (launched={launched}); inconsistent programs"
            )
        for g in ready:
            launched.append(g)
            coord.complete(g)
            for v in groups[g]:
                cursor[v] += 1
                done += 1
    return launched


def run_fcfs(
    programs: list[ThreadProgram],
    groups: dict[int, tuple[int, ...]],
    n_controls: int,
    arrival_seed: int = 0,
    max_steps: int = 10_000,
) -> list[int] | None:
    """Naive launcher: each control thread launches its workers' requests in
    arrival order; a launch blocks the control thread until all members'
    controls have also launched that group.  Returns the launch order, or
    None when it deadlocks."""
    rng = np.random.default_rng(arrival_seed)
    cursor = {p.vrank: 0 for p in programs}
    # per control thread: queue of (vrank, group) in randomized arrival order
    queues: dict[int, list[int]] = {c: [] for c in range(n_controls)}
    members_ctrl = {
        g: {next(p.control for p in programs if p.vrank == v) for v in ms}
        for g, ms in groups.items()
    }
    blocked_on: dict[int, int | None] = {c: None for c in range(n_controls)}
    launched_by: dict[int, set[int]] = {g: set() for g in groups}
    order: list[int] = []
    total = sum(len(p.group_ids) for p in programs)
    done = 0

    for _ in range(max_steps):
        if done >= total:
            return order
        progressed = False
        # workers at the head of their program enqueue to their control
        for p in rng.permutation(len(programs)):
            prog = programs[p]
            c = cursor[prog.vrank]
            if c < len(prog.group_ids):
                g = prog.group_ids[c]
                if g not in queues[prog.control]:
                    queues[prog.control].append(g)
        for ctrl in range(n_controls):
            if blocked_on[ctrl] is None and queues[ctrl]:
                g = queues[ctrl].pop(0)   # FCFS: take the first arrival
                blocked_on[ctrl] = g
                launched_by[g].add(ctrl)
                progressed = True
        # a collective completes when every member control has launched it
        for g, ctrls in list(launched_by.items()):
            if ctrls and ctrls == members_ctrl[g]:
                order.append(g)
                for v in groups[g]:
                    cursor[v] += 1
                    done += 1
                for c2 in ctrls:
                    blocked_on[c2] = None
                launched_by[g] = set()
                members_ctrl[g] = set()  # single-shot
                progressed = True
        if not progressed:
            return None  # deadlock: every control blocked on a not-ready op
    return None
