"""MegaFBD virtual/physical rank mapping + heterogeneous placement (§4.2).

Virtual ranks follow Megatron's allocation rules — forward and backward
instances have the *same* virtual world size, so model partitioning logic is
untouched.  Physical ranks are the devices; several virtual ranks (threads)
may share a device.  The planner maps forward-instance ranks onto weaker
devices (forward is the lighter phase: ~1/3 of the FLOPs) and backward ranks
onto the fastest, then the simkit engine scores the placement against
co-located execution.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.simkit.engine import Engine, FaultModel, Task


@dataclass(frozen=True)
class VirtualPhysicalMap:
    n_virtual: int                       # per instance (fwd == bwd)
    fwd_device: tuple[int, ...]          # virtual rank -> physical device
    bwd_device: tuple[int, ...]

    def control_thread(self, device: int) -> int:
        return device  # one control thread per physical device

    def threads_on(self, device: int) -> list[tuple[str, int]]:
        out = []
        for v, d in enumerate(self.fwd_device):
            if d == device:
                out.append(("F", v))
        for v, d in enumerate(self.bwd_device):
            if d == device:
                out.append(("B", v))
        return out


@dataclass
class FBDPlacement:
    mapping: VirtualPhysicalMap
    device_speed: dict[int, float]
    est_makespan: float = 0.0


def _assign_balanced(
    n_virtual: int, devs: list[int], speed: dict[int, float]
) -> tuple[int, ...]:
    """Greedy LPT: each virtual rank goes to the device with the least
    projected load (1/speed per thread)."""
    load = {d: 0.0 for d in devs}
    out = []
    for _ in range(n_virtual):
        d = min(devs, key=lambda dd: (load[dd] + 1.0 / speed[dd], dd))
        load[d] += 1.0 / speed[d]
        out.append(d)
    return tuple(out)


def plan_placement(
    n_virtual: int,
    device_speed: dict[int, float],
    *,
    fwd_cost: float = 1.0,
    bwd_cost: float = 2.0,
) -> FBDPlacement:
    """Split devices into a forward set (weakest first) and a backward set so
    the phase makespans balance by *capacity* (sum of speeds), then spread
    virtual ranks within each set greedily."""
    devs = sorted(device_speed, key=lambda d: (device_speed[d], d))
    best: tuple[float, int] | None = None
    for k in range(1, len(devs)):
        cap_f = sum(device_speed[d] for d in devs[:k])
        cap_b = sum(device_speed[d] for d in devs[k:])
        t = max(fwd_cost / cap_f, bwd_cost / cap_b)
        if best is None or t < best[0]:
            best = (t, k)
    k = best[1] if best is not None else max(1, len(devs) // 3)
    fwd_devs, bwd_devs = devs[:k], devs[k:] or devs
    return FBDPlacement(
        VirtualPhysicalMap(
            n_virtual,
            _assign_balanced(n_virtual, fwd_devs, device_speed),
            _assign_balanced(n_virtual, bwd_devs, device_speed),
        ),
        dict(device_speed),
    )


def colocated_placement(n_virtual: int, device_speed: dict[int, float]) -> FBDPlacement:
    devs = sorted(device_speed)
    m = tuple(devs[v % len(devs)] for v in range(n_virtual))
    return FBDPlacement(VirtualPhysicalMap(n_virtual, m, m), dict(device_speed))


def evaluate_placement(
    pl: FBDPlacement,
    *,
    n_micro: int = 8,
    fwd_time: float = 1e-3,
    bwd_time: float = 2e-3,
    act_bytes: int = 16 << 20,
    link_bandwidth: float = 50e9,
) -> float:
    """Makespan of one iteration under the placement: per microbatch, each
    virtual rank runs F (on its fwd device), ships the saved activations to
    its bwd device (free if co-located), then runs B."""
    order: dict[int, list[Task]] = {d: [] for d in pl.device_speed}
    for v in range(pl.mapping.n_virtual):
        fd = pl.mapping.fwd_device[v]
        bd = pl.mapping.bwd_device[v]
        for m in range(n_micro):
            f_id = f"F_v{v}_m{m}"
            order[fd].append(Task(
                tid=f_id, rank=fd, duration=fwd_time, kind="compute",
                meta={"mb": m, "op": "fwd", "vrank": v},
            ))
            b_dep: tuple[str, ...] = (f_id,)
            if fd != bd:
                x_id = f"X_v{v}_m{m}"
                order[fd].append(Task(
                    tid=x_id, rank=fd, bytes=act_bytes, kind="send",
                    deps=(f_id,), peer=bd, blocking=False,
                    meta={"mb": m, "vrank": v},
                ))
                b_dep = (x_id,)
            order[bd].append(Task(
                tid=f"B_v{v}_m{m}", rank=bd, duration=bwd_time, kind="compute",
                deps=b_dep, meta={"mb": m, "op": "bwd", "vrank": v},
            ))
    faults = FaultModel(compute_slowdown=dict(pl.device_speed))
    eng = Engine(faults=faults, link_bandwidth=link_bandwidth, link_concurrency=4)
    res = eng.run(order)
    pl.est_makespan = res.makespan
    return res.makespan
