"""Decoupled forward/backward execution in JAX (MegaFBD §4.1-4.2).

PyTorch binds F and B to the same device by autograd construction; MegaFBD
splits them into separate instances with *different* parallel configurations.
JAX-native realization: ``jax.vjp`` + ``jax.closure_convert`` split one loss
into two pure, separately-jittable functions —

    fwd_fn(params, batch)            -> (loss, residuals)   [forward profile]
    bwd_fn(residuals, cotangent)     -> grads               [backward profile]

Each is compiled with its own mesh/sharding profile (e.g. forward on a weaker
half of the cluster or with a smaller TP degree, backward on the full mesh).
The residual transfer between the two placements is the explicit data
synchronization MegaFBD's coordinator manages; its byte volume is returned so
benchmarks can account it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import axis_rules


@dataclass
class DecoupledStep:
    fwd: Callable          # (params, batch) -> (loss, residuals)
    bwd: Callable          # (residuals, cotangent) -> grads
    residual_bytes: Callable  # (params, batch) -> int  (transfer volume)


def make_decoupled_step(
    loss_fn: Callable,                 # (params, batch) -> scalar loss
    *,
    fwd_mesh=None,
    fwd_rules=None,
    bwd_mesh=None,
    bwd_rules=None,
) -> DecoupledStep:
    def fwd(params, batch):
        with axis_rules(fwd_mesh, fwd_rules):
            loss, vjp = jax.vjp(lambda p: loss_fn(p, batch), params)
        vjp_pure, residuals = jax.closure_convert(vjp, jnp.ones_like(loss))
        return loss, residuals

    def bwd(params, batch, residuals, ct):
        # rebuild the pure transpose with the backward profile installed
        with axis_rules(bwd_mesh, bwd_rules):
            _, vjp = jax.vjp(lambda p: loss_fn(p, batch), params)
            vjp_pure, _ = jax.closure_convert(vjp, jnp.ones_like(ct))
        (grads,) = vjp_pure(ct, *residuals)
        return grads

    def residual_bytes(params, batch) -> int:
        _, res = jax.eval_shape(fwd, params, batch)
        return int(sum(r.size * r.dtype.itemsize for r in res))

    return DecoupledStep(fwd=fwd, bwd=bwd, residual_bytes=residual_bytes)


def decoupled_grad(step: DecoupledStep, params: Any, batch: Any):
    """Convenience: run fwd then bwd (possibly on different meshes) and
    return (loss, grads).  Matches jax.grad up to numerics."""
    loss, residuals = step.fwd(params, batch)
    grads = step.bwd(params, batch, residuals, jnp.ones_like(loss))
    return loss, grads
