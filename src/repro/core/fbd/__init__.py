from repro.core.fbd.coordinator import (
    BitVectorCoordinator,
    CollectiveRequest,
    run_with_coordinator,
    run_fcfs,
)
from repro.core.fbd.ranks import (
    FBDPlacement,
    VirtualPhysicalMap,
    evaluate_placement,
    plan_placement,
)
from repro.core.fbd.decouple import make_decoupled_step

__all__ = [
    "BitVectorCoordinator",
    "CollectiveRequest",
    "run_with_coordinator",
    "run_fcfs",
    "VirtualPhysicalMap",
    "FBDPlacement",
    "plan_placement",
    "evaluate_placement",
    "make_decoupled_step",
]
