"""Persistent compilation cache for AOT-compiled executables.

Restart/warmup as a measured product surface: ``MegaServe.precompile()`` and
the train loop ahead-of-time compile their bucketed step variants
(``jit(...).lower().compile()``), and this module persists the resulting
executables so the *next* process start skips XLA entirely — cold-start-to-
first-token drops from "compile the world" to "mmap + deserialize".

Modeled on jax's experimental compilation cache, with the same two defenses:

* a **versioned on-disk layout** — entries live under
  ``root/v<VERSION>/<backend>-jax<version>/<keyhash>.bin``, so a layout bump,
  a jax upgrade, or a backend switch simply *misses* (stale executables are
  never deserialized into an incompatible runtime);
* **keys over everything that shapes the executable** — the model config,
  the mesh descriptor, the bucket identity (step kind + static widths), and
  the donation signature all hash into the entry name, because two programs
  differing in any of them compile to different XLA modules.

Entries are whole pickled ``jax.experimental.serialize_executable`` triples
``(payload, in_tree, out_tree)`` behind a small magic header, written
atomically (tmp + rename) so concurrent processes can share one cache
directory.  Every read path fails *open*: a missing, truncated, corrupt, or
version-skewed entry returns ``None`` (counted in ``stats.errors`` and
unlinked when possible) and the caller falls back to a normal compile — the
cache can only ever make startup faster, never wrong or fatal.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

_MAGIC = b"RPCC"  # repro compile cache


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    errors: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _jsonable(x: Any) -> Any:
    """Best-effort canonical form for key parts: dataclasses flatten to
    sorted dicts, tuples to lists, everything else through ``str`` if json
    refuses it."""
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {k: _jsonable(v) for k, v in sorted(
            dataclasses.asdict(x).items()
        )}
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in sorted(x.items())}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    return str(x)


def mesh_descriptor(mesh: Any | None) -> str:
    """Stable string for the compilation mesh: axis names x sizes + device
    kinds (a 2x4 cpu mesh and a 2x4 tpu mesh are different programs)."""
    import jax

    if mesh is None or getattr(mesh, "empty", False):
        return f"nomesh/{jax.default_backend()}x{jax.device_count()}"
    shape = dict(getattr(mesh, "shape", {}))
    kinds = sorted({d.platform for d in mesh.devices.flat})
    return f"{shape}/{'+'.join(kinds)}"


class CompileCache:
    """Directory-backed executable store (see module docstring).

    ``key(...)`` hashes arbitrary jsonable parts — callers pass the model
    config, mesh descriptor, bucket identity, and donation signature;
    ``load``/``put`` move serialized executables; ``compile(key, lowered)``
    is the one-liner the warmup paths use: hit -> deserialize, miss ->
    ``lowered.compile()`` + persist.
    """

    VERSION = 1

    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.stats = CacheStats()

    # ------------------------------------------------------------- layout
    def _dir(self) -> Path:
        import jax

        return (
            self.root
            / f"v{self.VERSION}"
            / f"{jax.default_backend()}-jax{jax.__version__}"
        )

    def _path(self, key: str) -> Path:
        return self._dir() / f"{key}.bin"

    # --------------------------------------------------------------- keys
    def key(self, **parts: Any) -> str:
        """Hash the parts that shape the executable into an entry name.

        Conventional parts: ``config`` (model config dataclass), ``mesh``
        (:func:`mesh_descriptor`), ``bucket`` (step kind + every static
        width baked into the trace), ``donate`` (donated argnums).  The
        layout version and jax version/backend ride the directory, but are
        hashed in too so a relocated entry can never alias."""
        import jax

        body = json.dumps(
            {
                "v": self.VERSION,
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                **{k: _jsonable(v) for k, v in sorted(parts.items())},
            },
            sort_keys=True,
            separators=(",", ":"),
        )
        return hashlib.sha256(body.encode()).hexdigest()[:32]

    # ----------------------------------------------------------------- io
    def load(self, key: str) -> Callable | None:
        """Deserialize the cached executable for ``key``; ``None`` on miss
        *or any failure* (corrupt/truncated/alien entries are dropped)."""
        from jax.experimental import serialize_executable as se

        path = self._path(key)
        try:
            blob = path.read_bytes()
        except OSError:
            self.stats.misses += 1
            return None
        try:
            if blob[: len(_MAGIC)] != _MAGIC:
                raise ValueError("bad magic")
            payload, in_tree, out_tree = pickle.loads(blob[len(_MAGIC):])
            fn = se.deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            # fail open: a corrupt entry must cost one recompile, not a crash
            self.stats.errors += 1
            try:
                path.unlink()
            except OSError:
                pass
            return None
        self.stats.hits += 1
        return fn

    def put(self, key: str, compiled: Any) -> bool:
        """Serialize ``compiled`` (a ``jax`` Compiled/Loaded executable)
        under ``key``; atomic rename so concurrent writers race benignly."""
        from jax.experimental import serialize_executable as se

        try:
            payload, in_tree, out_tree = se.serialize(compiled)
            blob = _MAGIC + pickle.dumps((payload, in_tree, out_tree))
            d = self._dir()
            d.mkdir(parents=True, exist_ok=True)
            tmp = d / f".{key}.{os.getpid()}.tmp"
            tmp.write_bytes(blob)
            os.replace(tmp, self._path(key))
        except Exception:
            self.stats.errors += 1
            return False
        self.stats.puts += 1
        return True

    # ---------------------------------------------------------- composite
    def compile(self, key: str, lowered: Any) -> tuple[Callable, bool]:
        """Load-or-compile: returns ``(executable, was_hit)``.  On a miss
        the freshly compiled executable is persisted before returning."""
        fn = self.load(key)
        if fn is not None:
            return fn, True
        compiled = lowered.compile()
        self.put(key, compiled)
        return compiled, False


def aot_compile(
    jitted: Any,
    avatars: tuple,
    *,
    cache: CompileCache | None,
    key_parts: dict[str, Any],
) -> tuple[Callable, bool]:
    """AOT-compile ``jitted`` against ``avatars`` (ShapeDtypeStructs or real
    arrays), consulting ``cache`` when given.  Returns ``(exe, was_hit)``.
    On a hit the trace/lower/XLA-compile pipeline is skipped entirely; on a
    miss the executable is compiled and persisted for the next process.
    """
    if cache is None:
        lowered = jitted.lower(*avatars)
        return lowered.compile(), False
    key = cache.key(**key_parts)
    fn = cache.load(key)
    if fn is not None:
        return fn, True
    compiled = jitted.lower(*avatars).compile()
    cache.put(key, compiled)
    return compiled, False
