"""MegaDPP traversal orders over the (model_chunk x microbatch) task matrix.

The paper's two poles (§5.2, Fig. 3):

* DFC (depth-first): advance the *same* microbatch through chunks — backward
  starts earlier, activations release sooner, lower memory peak;
* BFC (breadth-first): advance *many* microbatches through the same chunk —
  chunk-level gradients complete earlier and send deadlines relax, at the
  price of a larger activation stash.

``sched_wave`` generalizes both: microbatches move in waves of ``w``
(w=1 -> DFC, w=n_micro -> BFC), which is the knob the best-effort planner
tunes under a memory cap.
"""

from __future__ import annotations

from repro.core.simkit.workload import Step, sched_bfc, sched_dfc, sched_1f1b


def sched_wave(n_micro: int, n_chunks: int, wave: int) -> list[Step]:
    """Wave-parametrized traversal: forward waves of `wave` microbatches per
    chunk, backward in reverse — interpolates DFC (wave=1) .. BFC (wave=n)."""
    wave = max(1, min(wave, n_micro))
    steps: list[Step] = []
    for w0 in range(0, n_micro, wave):
        ms = range(w0, min(w0 + wave, n_micro))
        for c in range(n_chunks):
            for m in ms:
                steps.append(("F", m, c))
        for c in reversed(range(n_chunks)):
            for m in ms:
                steps.append(("B", m, c))
    return steps


def sched_zb_split(n_micro: int, n_chunks: int, pp: int, stage: int) -> list[Step]:
    """ZB-inspired schedule (Qi et al., cited by the paper §2.3.2): backward
    is split into activation-grad ("B") and weight-grad ("W") halves; W work
    has no downstream consumer and fills what would otherwise be bubbles at
    the pipeline tail.  Encoded as extra ("W", m, c) steps the workload
    builder lowers to dependency-free compute."""
    base = sched_1f1b(n_micro, n_chunks, pp, stage)
    out: list[Step] = []
    pending_w: list[Step] = []
    for kind, m, c in base:
        if kind == "B":
            out.append(("B", m, c))
            pending_w.append(("W", m, c))
            # drain one deferred W only when at least `stage` W's are queued
            # (the tail stages defer more, mirroring ZB1P's wedge shape)
            if len(pending_w) > max(pp - stage - 1, 0):
                out.append(pending_w.pop(0))
        else:
            out.append((kind, m, c))
    out.extend(pending_w)
    return out


def legalize(steps: list[Step], *, n_chunks: int) -> list[Step]:
    """Reorder a desired per-stage visit order into a dependency-legal one
    *within the stage*: F(m, c) needs F(m, c-1) done on this stage only in the
    single-stage chunk chain sense; B(m, c) needs F(m, c) and B(m, c+1).
    Greedy stable pass: repeatedly emit the first runnable step."""
    done: set[Step] = set()
    pending = list(steps)
    out: list[Step] = []

    def runnable(s: Step) -> bool:
        kind, m, c = s
        if kind == "F":
            return True
        # backward: forward must have run; deeper chunk's backward first
        if ("F", m, c) not in done:
            return False
        if c < n_chunks - 1 and ("B", m, c + 1) in pending_set:
            return False
        return True

    pending_set = set(pending)
    while pending:
        for i, s in enumerate(pending):
            if runnable(s):
                out.append(s)
                done.add(s)
                pending_set.discard(s)
                pending.pop(i)
                break
        else:
            # no runnable step — emit remaining as-is (engine will flag)
            out.extend(pending)
            break
    return out


def schedule_table(
    steps_per_stage: dict[int, list[Step]], pp: int, n_chunks: int, n_micro: int
) -> list[list[Step | None]]:
    """Pad per-stage step lists into a rectangular [T][stage] table (None =
    bubble).  Used by the JAX executor to build static dispatch indices."""
    T = max(len(v) for v in steps_per_stage.values())
    table: list[list[Step | None]] = []
    for t in range(T):
        row = []
        for s in range(pp):
            lst = steps_per_stage[s]
            row.append(lst[t] if t < len(lst) else None)
        table.append(row)
    return table


__all__ = [
    "Step",
    "sched_dfc",
    "sched_bfc",
    "sched_1f1b",
    "sched_wave",
    "sched_zb_split",
    "legalize",
    "schedule_table",
]
