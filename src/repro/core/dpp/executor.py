"""JAX pipeline executor driven by MegaDPP schedule tables.

TPU-native realization of the paper's async-P2P runtime (DESIGN.md §2.2): the
planner picks the traversal order ahead-of-time; this executor lowers it into
a static sequence of per-stage compute + ring ``ppermute`` steps under
``shard_map``.  The backward pipeline falls out of autodiff (transpose of
ppermute is the reverse ppermute), with the forward traversal order — the
paper's contribution — fully schedule-controlled.

Interleaving layout: global block (c, s) = chunk c on stage s; value flow
(c, s) -> (c, s+1), wrapping (c, S-1) -> (c+1, 0), so every transfer is the
same +1 ring permute.

``params`` may be any pytree whose leaves are stage-major stacked
``[S, C, ...]`` arrays (a single array still works), and activations may have
any trailing shape — this is what lets the *real* transformer train step run
through the pipeline (``repro.models.pipeline`` builds the stacked block
pytrees and the per-cell ``block_fn``; ``repro.train.train_step`` drives it).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.dpp.schedule import Step
from repro.core.tracing.events import TraceEvent

# jax moved shard_map out of experimental (and renamed check_rep -> check_vma)
# around 0.5/0.6; support both so the executor runs on the pinned 0.4.x too.
try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _SHARD_MAP_KW = {"check_vma": False}
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


@dataclass
class TimeTable:
    """Static dispatch tables [T, S]: what each stage runs/receives per step."""
    run_m: jnp.ndarray
    run_c: jnp.ndarray
    run_act: jnp.ndarray
    recv_m: jnp.ndarray
    recv_c: jnp.ndarray     # destination chunk slot at the receiver
    recv_act: jnp.ndarray
    recv_fin: jnp.ndarray   # receipt is a final output (write to out buffer)
    steps: int


def build_time_table(
    order: list[Step], n_stages: int, n_chunks: int, n_micro: int
) -> TimeTable:
    """Greedy legal placement of the desired visit order: at each step every
    stage runs its highest-priority *ready* pending (m, c) — the static
    analogue of "always pick the highest-priority ready input"."""
    fwd = [(m, c) for kind, m, c in order if kind == "F"]
    pending = {s: list(fwd) for s in range(n_stages)}
    ready: dict[tuple[int, int, int], int] = {
        (m, 0, 0): 0 for m in range(n_micro)
    }
    placed: list[list[tuple[int, int] | None]] = []
    done = 0
    total = n_stages * len(fwd)
    t = 0
    max_steps = total + n_stages * n_chunks * n_micro + 16
    while done < total and t < max_steps:
        row: list[tuple[int, int] | None] = []
        for s in range(n_stages):
            pick = None
            for i, (m, c) in enumerate(pending[s]):
                r = ready.get((m, c, s))
                if r is not None and r <= t:
                    pick = (i, m, c)
                    break
            if pick is None:
                row.append(None)
                continue
            i, m, c = pick
            pending[s].pop(i)
            done += 1
            row.append((m, c))
            # successor becomes ready next step
            if s < n_stages - 1:
                ready[(m, c, s + 1)] = t + 1
            elif c < n_chunks - 1:
                ready[(m, c + 1, 0)] = t + 1
        placed.append(row)
        t += 1
    if done < total:
        raise RuntimeError("schedule could not be legalized (cyclic order)")

    T = len(placed) + 1  # one extra step to flush the last permute
    S = n_stages
    run_m = jnp.zeros((T, S), jnp.int32)
    run_c = jnp.zeros((T, S), jnp.int32)
    run_act = jnp.zeros((T, S), bool)
    recv_m = jnp.zeros((T, S), jnp.int32)
    recv_c = jnp.zeros((T, S), jnp.int32)
    recv_act = jnp.zeros((T, S), bool)
    recv_fin = jnp.zeros((T, S), bool)
    for t, row in enumerate(placed):
        for s, entry in enumerate(row):
            if entry is None:
                continue
            m, c = entry
            run_m = run_m.at[t, s].set(m)
            run_c = run_c.at[t, s].set(c)
            run_act = run_act.at[t, s].set(True)
            # the receiver sees this value at step t+1
            dst = (s + 1) % S
            if s < S - 1:
                dc, fin = c, False
            elif c < n_chunks - 1:
                dc, fin = c + 1, False
            else:
                dc, fin = 0, True
            recv_m = recv_m.at[t + 1, dst].set(m)
            recv_c = recv_c.at[t + 1, dst].set(dc)
            recv_act = recv_act.at[t + 1, dst].set(True)
            recv_fin = recv_fin.at[t + 1, dst].set(fin)
    return TimeTable(run_m, run_c, run_act, recv_m, recv_c, recv_act, recv_fin, T)


def bubble_fraction(table: TimeTable) -> float:
    """Fraction of (step, stage) slots in the forward table that are idle.

    The denominator includes the final flush step, so the number is directly
    comparable across schedules for the same (S, C, n_micro) problem.
    """
    run_act = np.asarray(table.run_act)
    T, S = run_act.shape
    busy = int(run_act.sum())
    return 1.0 - busy / float(T * S)


def pipeline_apply(
    params: Any,                       # pytree of [S, C, ...] stacked blocks
    x_micro: jax.Array,                # [n_micro, ...] microbatch inputs
    table: TimeTable,
    *,
    mesh: jax.sharding.Mesh,
    axis: str = "stage",
    block_fn: Callable[[Any, jax.Array], jax.Array],
    data_axis: str | None = None,
    param_specs: Any | None = None,
) -> jax.Array:
    """Runs the pipelined forward; returns [n_micro, ...] final activations
    (replicated).  Differentiable — backward pipelines automatically.

    ``params`` leaves are split over the ``axis`` mesh dimension (stage-major
    leading axis); every other mesh axis sees them replicated unless
    ``param_specs`` (a matching pytree of ``PartitionSpec``, each starting
    with ``axis``) additionally slices weight dims over e.g. the tensor
    axis — the per-leaf tp sharding of ``models.pipeline``.  ``block_fn``
    receives one cell's params (leaves indexed down to ``[...]``, the chunk
    axis consumed) and one microbatch activation of shape ``x_micro.shape[1:]``.

    ``data_axis`` composes data parallelism: the leading microbatch axis of
    ``x_micro`` shards across that mesh axis, each dp group pipelines its
    local slice (``table`` must then be built for the *local* microbatch
    count), and the output keeps the same sharding.  The backward pass
    all-reduces parameter cotangents over the data axis for free: everything
    runs manual under ``shard_map``, and the transpose of a replicated-input
    broadcast is a psum over the mesh axes its spec does not mention.
    """
    S = mesh.shape[axis]
    rest = x_micro.shape[1:]
    n_local = x_micro.shape[0]
    if data_axis is not None:
        dp = mesh.shape[data_axis]
        if n_local % dp != 0:
            raise ValueError(
                f"n_micro={n_local} not divisible by mesh axis "
                f"{data_axis!r} of size {dp}"
            )
        n_local //= dp
    n_micro = n_local
    C = jax.tree.leaves(params)[0].shape[1]

    def body(params_loc, x_loc):
        # params_loc leaves [1, C, ...] (this stage's chunks); x_loc holds
        # this dp group's microbatches (all of them when data_axis is None)
        params_loc = jax.tree.map(lambda a: a[0], params_loc)
        sid = jax.lax.axis_index(axis)

        inbox0 = jnp.zeros((n_micro, C, *rest), x_loc.dtype)
        out0 = jnp.zeros((n_micro, *rest), x_loc.dtype)
        recv0 = jnp.zeros(rest, x_loc.dtype)

        def step(carry, t):
            inbox, out, recv = carry
            # 1. deposit what arrived on the wire last step
            r_act = table.recv_act[t, sid]
            r_fin = table.recv_fin[t, sid]
            r_m = table.recv_m[t, sid]
            r_c = table.recv_c[t, sid]
            dep = jnp.where(r_act & ~r_fin, recv, inbox[r_m, r_c])
            inbox = inbox.at[r_m, r_c].set(dep)
            fin = jnp.where(r_act & r_fin, recv, out[r_m])
            out = out.at[r_m].set(fin)
            # 2. run this stage's scheduled task
            act = table.run_act[t, sid]
            m = table.run_m[t, sid]
            c = table.run_c[t, sid]
            first = (c == 0) & (sid == 0)
            x_in = jnp.where(first, x_loc[m], inbox[m, c])
            p_c = jax.tree.map(lambda a: a[c], params_loc)
            y = block_fn(p_c, x_in)
            y = jnp.where(act, y, jnp.zeros_like(y))
            # 3. ship downstream
            recv_next = jax.lax.ppermute(
                y, axis, perm=[(i, (i + 1) % S) for i in range(S)]
            )
            return (inbox, out, recv_next), None

        (inbox, out, _), _ = jax.lax.scan(
            step, (inbox0, out0, recv0), jnp.arange(table.steps)
        )
        # outputs accumulate on stage 0 only; replicate across stages
        out = jnp.where(sid == 0, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    x_spec = P() if data_axis is None else P(data_axis)
    if param_specs is None:
        param_specs = P(axis)  # broadcast: every leaf stage-sharded only
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=x_spec,
        **_SHARD_MAP_KW,
    )
    return fn(params, x_micro)


def reference_apply(params, x_micro, block_fn):
    """Sequential oracle: every block in (chunk, stage) order."""
    leaf = jax.tree.leaves(params)[0]
    S, C = leaf.shape[0], leaf.shape[1]

    def one(x):
        for c in range(C):
            for s in range(S):
                x = block_fn(jax.tree.map(lambda a: a[s, c], params), x)
        return x

    return jax.vmap(one)(x_micro)


def emit_pipeline_events(
    events: list[TraceEvent],
    table: TimeTable,
    *,
    ts: float,
    wall: float,
    bwd_cost: float = 2.0,
    step_idx: int = 0,
) -> None:
    """Synthesize per-(microbatch, stage, F/B) MegaScan events from the static
    dispatch table, scaled into a measured step's [ts, ts+wall] window.

    The forward traversal follows the table directly; the backward pipeline is
    autodiff's exact mirror (the transposed scan replays ticks in reverse), so
    its events are the reversed table stretched by ``bwd_cost``.  The chrome
    export then shows the schedule's *actual* bubble structure — one pid row
    per stage — without instrumenting the jitted scan body.
    """
    run_act = np.asarray(table.run_act)
    run_m = np.asarray(table.run_m)
    run_c = np.asarray(table.run_c)
    T, S = run_act.shape
    tick = max(wall, 1e-9) / (T * (1.0 + bwd_cost))
    fwd_span = T * tick
    for t in range(T):
        for s in range(S):
            if not run_act[t, s]:
                continue
            m, c = int(run_m[t, s]), int(run_c[t, s])
            args = {"mb": m, "chunk": c, "stage": s, "step": step_idx}
            events.append(TraceEvent(
                "pp_F", s, ts + t * tick, tick, "compute",
                {**args, "phase": "F"},
            ))
            events.append(TraceEvent(
                "pp_B", s,
                ts + fwd_span + (T - 1 - t) * bwd_cost * tick,
                bwd_cost * tick, "compute",
                {**args, "phase": "B"},
            ))
