"""Resource-aware schedule planning (MegaDPP §5.1-5.2).

The planner evaluates candidate traversal orders on the simkit engine with the
*current* resource picture — compute/link health comes straight from MegaScan
telemetry (a ``Diagnosis``), memory budget from the device spec — and picks
the best-effort schedule: the largest BFC wave whose predicted activation
peak fits, preferring makespan, i.e. "adopt BFC as long as it does not OOM".

Between iterations ``replan`` folds fresh telemetry in (straggler mitigation:
a slow stage or degraded link shifts the optimum; the planner reacts without
restarting the job).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.dpp.schedule import sched_wave
from repro.core.simkit.engine import DeadlockError, Engine, FaultModel
from repro.core.simkit.workload import ModelProfile, Topology, build_training_step


@dataclass
class PlanResult:
    schedule_name: str
    wave: int
    makespan: float
    peak_memory: int
    grad_ready: float          # earliest time the first chunk's grads are done
    per_candidate: dict = field(default_factory=dict)

    def steps(self, n_micro: int, n_chunks: int):
        return sched_wave(n_micro, n_chunks, self.wave)


@dataclass
class Planner:
    topo: Topology
    prof: ModelProfile
    n_micro: int
    memory_cap: int = 16 << 30
    async_p2p: bool = True
    link_bandwidth: float = 50e9
    faults: FaultModel = field(default_factory=FaultModel)

    def _evaluate(self, wave: int) -> tuple[float, int, float] | None:
        steps = sched_wave(self.n_micro, self.prof.n_chunks, wave)
        order = build_training_step(
            self.topo, self.prof, n_micro=self.n_micro,
            schedule={p: list(steps) for p in range(self.topo.pp)},
            async_p2p=self.async_p2p,
        )
        engine = Engine(
            faults=self.faults,
            link_bandwidth=self.link_bandwidth,
            link_concurrency=4 if self.async_p2p else 1,
        )
        try:
            res = engine.run(order)
        except DeadlockError:
            return None
        peak = max(res.peak_memory.values())
        # gradient-sync readiness: the earliest chunk to finish *all* its
        # backward work could start its gradient all-reduce then (BFC's
        # claimed benefit: per-chunk sync starts before the iteration ends)
        per_chunk: dict[int, float] = {}
        for r in res.records:
            if r.kind == "compute" and r.meta.get("phase") == "B":
                c = r.meta.get("chunk", 0)
                per_chunk[c] = max(per_chunk.get(c, 0.0), r.end)
        grad_ready = min(per_chunk.values()) if per_chunk else res.makespan
        return res.makespan, peak, grad_ready

    def plan(self) -> PlanResult:
        candidates: dict[int, tuple[float, int, float]] = {}
        waves = sorted({1, 2, self.n_micro // 2, self.n_micro} - {0})
        for w in waves:
            r = self._evaluate(w)
            if r is not None:
                candidates[w] = r
        # best-effort BFC: among schedules that fit the memory cap, take the
        # fastest; tie-break toward larger wave (earlier grad readiness)
        fitting = {w: v for w, v in candidates.items() if v[1] <= self.memory_cap}
        pool = fitting or candidates
        best_w = min(pool, key=lambda w: (pool[w][0], -w))
        mk, peak, gr = pool[best_w]
        name = {1: "dfc"}.get(best_w, "bfc" if best_w == self.n_micro else f"wave{best_w}")
        return PlanResult(
            schedule_name=name, wave=best_w, makespan=mk, peak_memory=peak,
            grad_ready=gr,
            per_candidate={
                w: {"makespan": v[0], "peak_mem": v[1], "grad_ready": v[2],
                    "fits": v[1] <= self.memory_cap}
                for w, v in candidates.items()
            },
        )

    def replan(self, diagnosis) -> PlanResult:
        """Fold MegaScan telemetry into the resource picture and re-plan."""
        self.faults = self.faults.merged(
            compute_slowdown={
                r: 0.5 for r in getattr(diagnosis, "slow_ranks", [])
            },
            link_slowdown={
                tuple(l): 0.5 for l in getattr(diagnosis, "degraded_links", [])
            },
        )
        return self.plan()
