from repro.core.dpp.schedule import (
    Step,
    legalize,
    sched_bfc,
    sched_dfc,
    sched_wave,
    sched_zb_split,
    schedule_table,
)
from repro.core.dpp.planner import PlanResult, Planner

__all__ = [
    "Step",
    "sched_dfc",
    "sched_bfc",
    "sched_wave",
    "sched_zb_split",
    "legalize",
    "schedule_table",
    "Planner",
    "PlanResult",
]
