"""Logical-axis sharding rules (MaxText-style) for the repro framework.

Every weight and activation in the model code is annotated with *logical* axis
names (``"batch"``, ``"embed"``, ``"heads"``, ...).  A per-run rule table maps
logical names onto physical mesh axes (``"pod"``, ``"data"``, ``"model"``).
Resolution is size-aware: a mesh axis that does not evenly divide the
corresponding array dimension is dropped (the dimension stays replicated), so a
single rule table serves architectures whose head counts / widths do not divide
the tensor-parallel degree (e.g. qwen2-0.5b's 14 heads on a 16-way axis).

The rule table is also the main performance-tuning knob used by the §Perf
hillclimb: see ``repro/configs`` for per-architecture overrides.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes (in nesting order) or None (replicated)
AxisRules = Mapping[str, tuple[str, ...] | None]

# Default rules: FSDP ("data") x TP ("model") with pure-DP "pod" axis.
#   - batch is sharded over pod+data (data parallelism)
#   - model-parallel width dims (heads / mlp / vocab) go to "model"
#   - "embed" on weights goes to "data": combined with the model axis on the
#     other dim this gives 2-D (ZeRO-3 / FSDP + TP) weight sharding
#   - sequence parallelism for activations between blocks uses "seq_act"
DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": None,              # sequence dim of activations inside attention
    "seq_act": None,          # sequence dim of residual-stream activations
    "embed": ("data",),       # weight embed dim -> FSDP
    "embed_act": None,        # activation embed dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "qkv": ("model",),        # fused qkv output dim
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": None,
    "kv_lora": None,
    "conv": None,
    "state": None,
    "layers": None,           # stacked-layer leading axis (scanned over)
    "stack": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: AxisRules | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, rules: AxisRules | None = None):
    """Context manager installing the (mesh, logical-axis rules) pair.

    With no mesh installed all sharding annotations are no-ops, so model code
    runs unchanged in single-device unit tests.
    """
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(rules) if rules is not None else dict(DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh_and_rules() -> tuple[Mesh | None, AxisRules | None]:
    return _CTX.mesh, _CTX.rules


def _resolve_axes(
    logical: str | None,
    mesh: Mesh,
    rules: AxisRules,
    dim_size: int | None,
    taken: set[str],
) -> tuple[str, ...] | None:
    """Resolve one logical axis to mesh axes, dropping non-dividing/taken axes."""
    if logical is None:
        return None
    mapped = rules.get(logical)
    if mapped is None:
        return None
    if isinstance(mapped, str):
        mapped = (mapped,)
    out: list[str] = []
    shard = 1
    for ax in mapped:
        if ax not in mesh.shape or ax in taken:
            continue
        size = mesh.shape[ax]
        if dim_size is not None and (dim_size % (shard * size)) != 0:
            continue
        out.append(ax)
        shard *= size
    return tuple(out) or None


def logical_to_spec(
    logical_axes: Sequence[str | None],
    shape: Sequence[int] | None = None,
    mesh: Mesh | None = None,
    rules: AxisRules | None = None,
) -> P:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    if mesh is None:
        mesh = _CTX.mesh
    if rules is None:
        rules = _CTX.rules or DEFAULT_RULES
    if mesh is None:
        return P()
    taken: set[str] = set()
    parts = []
    for i, name in enumerate(logical_axes):
        dim = None if shape is None else shape[i]
        axes = _resolve_axes(name, mesh, rules, dim, taken)
        if axes is not None:
            taken.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    # strip trailing Nones for a tidy spec
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard_act(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """Apply a logical sharding constraint to an activation (no-op w/o mesh)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard_act: {len(logical_axes)} logical axes for rank-{x.ndim} array"
        )
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(
    logical_axes: Sequence[str | None],
    shape: Sequence[int] | None = None,
    mesh: Mesh | None = None,
    rules: AxisRules | None = None,
) -> NamedSharding:
    if mesh is None:
        mesh = _CTX.mesh
    if mesh is None:
        raise ValueError("named_sharding requires a mesh")
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh, rules))


def param_shardings(
    logical_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: AxisRules | None = None,
) -> Any:
    """Build a NamedSharding pytree for params.

    ``logical_tree`` mirrors the param pytree with tuples of logical axis names
    as leaves; ``shape_tree`` holds ShapeDtypeStructs (from ``jax.eval_shape``).
    """
    return jax.tree.map(
        lambda axes, s: named_sharding(axes, s.shape, mesh, rules),
        logical_tree,
        shape_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(a, (str, type(None))) for a in t),
    )
