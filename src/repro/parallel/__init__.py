from repro.parallel.plan import (
    PP_SCHEDULES,
    ParallelPlan,
    forward_order,
    plan_summary,
    resolve_plan,
)
from repro.parallel.sharding import (
    AxisRules,
    DEFAULT_RULES,
    axis_rules,
    current_mesh_and_rules,
    logical_to_spec,
    named_sharding,
    param_shardings,
    shard_act,
)

__all__ = [
    "PP_SCHEDULES",
    "ParallelPlan",
    "forward_order",
    "plan_summary",
    "resolve_plan",
    "AxisRules",
    "DEFAULT_RULES",
    "axis_rules",
    "current_mesh_and_rules",
    "logical_to_spec",
    "named_sharding",
    "param_shardings",
    "shard_act",
]
