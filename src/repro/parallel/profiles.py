"""Per-(arch, shape-kind) logical-axis rule tables — the sharding profiles.

Profiles (DESIGN.md §4):

* ``fsdp_cp`` (train / prefill default): weights 2-D sharded
  (``embed_w`` -> data, TP dims -> model = ZeRO-3 x TP storage); activations
  batch-sharded over (pod, data) and *sequence*-sharded over model (context /
  sequence parallelism).  Attention gathers KV (``seq_kv`` -> replicated);
  linear-recurrence archs chunk-scan over the sharded sequence.  This profile
  has no head-divisibility constraints, which matters because most assigned
  archs have head counts that do not divide the 16-way model axis.

* ``tp_sp`` (classic Megatron TP + sequence parallelism): attention heads and
  MLP hidden sharded over model; residual stream sequence-sharded.  Valid only
  when both H and KV divide the model axis; exposed for the §Perf hillclimb.

* ``decode``: weights tensor-parallel over model (no FSDP dim — decode cannot
  afford per-token param gathers), KV-cache time dim sharded over model,
  everything else replicated (S=1 activations are tiny).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.parallel.sharding import AxisRules

_COMMON_WEIGHTS = {
    "heads_w": ("model",),
    "kv_heads_w": ("model",),
    "head_dim_w": ("model",),
    "qkv": ("model",),
    "mlp_w": ("model",),
    "vocab_w": ("model",),
    "expert_w": ("model",),
    "expert_mlp": None,
    "kv_lora_w": None,
    "conv": None,
    "layers": None,
    "stack": None,
}

_COMMON_ACTS = {
    "embed_act": None,
    "heads_act": None,
    "kv_heads_act": None,
    "head_dim_act": None,
    "mlp_act": None,
    "kv_lora_act": None,
    "state": None,
    "seq_ce": None,
    "vocab_act": ("model",),
    "moe_cap": None,
    "expert_pre": None,
    "expert_act": ("model",),
}

FSDP_CP: dict = {
    **_COMMON_WEIGHTS,
    **_COMMON_ACTS,
    "embed_w": ("data",),
    "batch": ("pod", "data"),
    "seq_act": ("model",),
    "seq": ("model",),
    "seq_kv": None,
    "kv_time": ("model",),
    # CE: batch stays on data axes so the vocab dim keeps the model axis —
    # the [D, V] unembed (and its grad) stay sharded; logsumexp psums are tiny.
    "ce_batch": ("pod", "data"),
    "moe_groups": ("pod", "data", "model"),
    "moe_groups_post": ("pod", "data"),
}

TP_SP: dict = {
    **_COMMON_WEIGHTS,
    **_COMMON_ACTS,
    "embed_w": ("data",),
    "batch": ("pod", "data"),
    "seq_act": ("model",),
    "seq": None,
    "seq_kv": None,
    "heads_act": ("model",),
    "kv_heads_act": ("model",),
    "mlp_act": ("model",),
    "kv_time": ("model",),
    "ce_batch": ("pod", "data"),
    "moe_groups": ("pod", "data", "model"),
    "moe_groups_post": ("pod", "data"),
}

DECODE: dict = {
    **_COMMON_WEIGHTS,
    **_COMMON_ACTS,
    "embed_w": None,
    "batch": ("pod", "data"),
    "seq_act": None,
    "seq": None,
    "seq_kv": None,
    "kv_time": ("model",),
    "ce_batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "moe_groups_post": ("pod", "data"),
}


def profile_name(cfg: ModelConfig, shape_kind: str) -> str:
    if shape_kind == "decode":
        return "decode"
    return "fsdp_cp"


def rules_for(
    cfg: ModelConfig, shape_kind: str, profile: str | None = None
) -> AxisRules:
    name = profile or profile_name(cfg, shape_kind)
    base = {"fsdp_cp": FSDP_CP, "tp_sp": TP_SP, "decode": DECODE}[name]
    rules = dict(base)
    ov = cfg.sharding_overrides
    if ov and all(isinstance(v, dict) for v in ov.values()):
        # per-shape-kind overrides: {"train": {...}, "prefill": {...}, ...}
        rules.update(ov.get(shape_kind, {}))
    else:
        rules.update(ov)
    return rules
