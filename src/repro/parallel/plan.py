"""ParallelPlan: the dp/tp/pp + schedule description threaded through the app.

One frozen dataclass describes how a training run parallelizes:

* ``dp`` / ``tp`` — the data / tensor degrees.  At ``pp == 1`` the
  logical-axis sharding rules resolve against them (``parallel.sharding``);
  at ``pp > 1`` they compose on the one ``(stage, data, model)`` mesh: the
  ``n_micro`` microbatches shard across dp groups (each group pipelines its
  ``n_micro_local`` slice) and tp slices heads/ffn inside every stage's
  ``shard_map`` body (``models.pipeline``);
* ``pp`` / ``n_micro`` / ``n_chunks`` / ``schedule`` / ``wave`` — the MegaDPP
  pipeline axis: how many stages, how the (microbatch, chunk) task matrix is
  traversed (``core.dpp.schedule``), and the wave width when the traversal is
  wave-parametrized.  ``wave=0`` with ``schedule="wave"`` delegates the choice
  to MegaDPP's resource-aware planner (best-effort BFC under the memory cap);
* ``fbd_backward`` — attach MegaFBD's decoupled backward: gradients come from
  an explicit forward-instance / backward-instance vjp split instead of one
  fused ``value_and_grad`` (``core.fbd.decouple`` is the standalone
  two-placement realization; the train step hosts the in-step attach).

``repro.app.Session`` builds a plan from the ``parallel`` config section and
hands it to ``train.loop.train`` -> ``train.train_step.make_train_step``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.dpp.schedule import (
    Step,
    sched_1f1b,
    sched_bfc,
    sched_dfc,
    sched_wave,
)

PP_SCHEDULES = ("1f1b", "dfc", "bfc", "wave")


@dataclass(frozen=True)
class ParallelPlan:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    n_micro: int = 0           # 0 = resolve_plan picks (2*pp when pp>1)
    n_chunks: int = 1
    schedule: str = "1f1b"     # one of PP_SCHEDULES
    wave: int = 0              # 0 + schedule="wave" = planner chooses
    fbd_backward: bool = False

    @property
    def world(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def n_micro_local(self) -> int:
        """Microbatches one dp group pipelines: the ``n_micro`` global
        microbatches shard evenly across the ``data`` axis, and each dp group
        runs its own copy of the schedule over its slice."""
        return self.n_micro // self.dp if self.n_micro else self.n_micro

    def topology(self):
        """The rank <-> (dp, stage, tp) coordinate mapping of the composed
        mesh (``core.simkit.workload.Topology``) — what the ft/obs paths use
        to decide which axis a detected link or straggler lives on."""
        from repro.core.simkit.workload import Topology

        return Topology(dp=self.dp, pp=self.pp, tp=self.tp)

    def validate(self) -> "ParallelPlan":
        if min(self.dp, self.tp, self.pp) < 1:
            raise ValueError(f"parallel degrees must be >= 1, got {self}")
        if self.schedule not in PP_SCHEDULES:
            raise ValueError(
                f"unknown pipeline schedule {self.schedule!r}; "
                f"one of {PP_SCHEDULES}"
            )
        if self.pp > 1 and self.n_micro < 0:
            raise ValueError(f"n_micro must be >= 0, got {self.n_micro}")
        if self.pp > 1 and self.n_micro and self.n_micro % self.dp != 0:
            raise ValueError(
                f"n_micro={self.n_micro} not divisible by dp={self.dp}: "
                "the microbatch axis shards evenly across dp groups"
            )
        return self


def resolve_plan(
    plan: ParallelPlan,
    *,
    memory_cap_gib: float = 8.0,
    prof=None,
) -> ParallelPlan:
    """Fill derived fields: default microbatch count, planner-chosen wave.

    The wave choice *is* MegaDPP's planner (``core.dpp.planner.Planner``):
    candidate waves are simulated on the simkit engine and the fastest one
    fitting the activation-memory cap wins — "adopt BFC as long as it does
    not OOM".
    """
    plan.validate()
    if plan.pp <= 1:
        return plan
    if plan.n_micro == 0:
        # 2 microbatches per stage *per dp group* keeps the per-group
        # pipeline depth (and so the bubble fraction) independent of dp
        plan = replace(plan, n_micro=2 * plan.pp * plan.dp)
    if plan.schedule == "wave" and plan.wave == 0:
        from repro.core.dpp.planner import Planner
        from repro.core.simkit.workload import ModelProfile

        planner = Planner(
            plan.topology(),
            prof or ModelProfile(n_chunks=plan.n_chunks),
            n_micro=plan.n_micro_local,
            memory_cap=int(memory_cap_gib * (1 << 30)),
        )
        plan = replace(plan, wave=planner.plan().wave)
    return plan


def forward_order(plan: ParallelPlan) -> list[Step]:
    """The desired (microbatch, chunk) visit order the executor's time table
    legalizes.  Only the F steps matter to the forward table; the backward
    traversal is autodiff's mirror.  Microbatch indices are *dp-local*: each
    dp group runs the same table over its ``n_micro_local`` slice of the
    globally-sharded microbatch axis."""
    nm, c = plan.n_micro_local, plan.n_chunks
    if plan.schedule == "dfc":
        return sched_dfc(nm, c)
    if plan.schedule == "bfc":
        return sched_bfc(nm, c)
    if plan.schedule == "wave":
        return sched_wave(nm, c, plan.wave or max(1, nm // 2))
    if plan.schedule == "1f1b":
        return sched_1f1b(nm, c, plan.pp, 0)
    raise ValueError(f"unknown pipeline schedule {plan.schedule!r}")


def link_axis(plan: ParallelPlan, link) -> str:
    """Which mesh axis a (rank, rank) link lives on: ``"data"`` / ``"stage"``
    / ``"model"`` for links whose endpoints differ in exactly one coordinate
    of the plan topology, ``"self"`` for a degenerate same-rank link,
    ``"mixed"`` for diagonal pairs, ``"unknown"`` for out-of-range ranks.

    This is how the ft mitigation picks its lever: data-axis links carry the
    gradient sync (compressible), stage-axis links carry pipeline P2P
    activations (replannable), model-axis links carry in-stage tensor
    collectives (neither — only exclusion helps).
    """
    topo = plan.topology()
    a, b = link
    if not (0 <= a < topo.world and 0 <= b < topo.world):
        return "unknown"
    ca, cb = topo.coords(a), topo.coords(b)
    diffs = [
        name for name, x, y in zip(("data", "stage", "model"), ca, cb)
        if x != y
    ]
    if not diffs:
        return "self"
    return diffs[0] if len(diffs) == 1 else "mixed"


def plan_summary(plan: ParallelPlan) -> dict:
    """JSON-able view for ``session.results`` / bench output."""
    return {
        "dp": plan.dp, "tp": plan.tp, "pp": plan.pp,
        "n_micro": plan.n_micro, "n_micro_local": plan.n_micro_local,
        "n_chunks": plan.n_chunks,
        "schedule": plan.schedule, "wave": plan.wave,
        "fbd_backward": plan.fbd_backward, "world": plan.world,
    }
