"""ParallelPlan: the dp/tp/pp + schedule description threaded through the app.

One frozen dataclass describes how a training run parallelizes:

* ``dp`` / ``tp`` — the data / tensor degrees the logical-axis sharding rules
  resolve against (``parallel.sharding``);
* ``pp`` / ``n_micro`` / ``n_chunks`` / ``schedule`` / ``wave`` — the MegaDPP
  pipeline axis: how many stages, how the (microbatch, chunk) task matrix is
  traversed (``core.dpp.schedule``), and the wave width when the traversal is
  wave-parametrized.  ``wave=0`` with ``schedule="wave"`` delegates the choice
  to MegaDPP's resource-aware planner (best-effort BFC under the memory cap);
* ``fbd_backward`` — attach MegaFBD's decoupled backward: gradients come from
  an explicit forward-instance / backward-instance vjp split instead of one
  fused ``value_and_grad`` (``core.fbd.decouple`` is the standalone
  two-placement realization; the train step hosts the in-step attach).

``repro.app.Session`` builds a plan from the ``parallel`` config section and
hands it to ``train.loop.train`` -> ``train.train_step.make_train_step``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.dpp.schedule import (
    Step,
    sched_1f1b,
    sched_bfc,
    sched_dfc,
    sched_wave,
)

PP_SCHEDULES = ("1f1b", "dfc", "bfc", "wave")


@dataclass(frozen=True)
class ParallelPlan:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    n_micro: int = 0           # 0 = resolve_plan picks (2*pp when pp>1)
    n_chunks: int = 1
    schedule: str = "1f1b"     # one of PP_SCHEDULES
    wave: int = 0              # 0 + schedule="wave" = planner chooses
    fbd_backward: bool = False

    @property
    def world(self) -> int:
        return self.dp * self.tp * self.pp

    def validate(self) -> "ParallelPlan":
        if min(self.dp, self.tp, self.pp) < 1:
            raise ValueError(f"parallel degrees must be >= 1, got {self}")
        if self.schedule not in PP_SCHEDULES:
            raise ValueError(
                f"unknown pipeline schedule {self.schedule!r}; "
                f"one of {PP_SCHEDULES}"
            )
        if self.pp > 1 and self.n_micro < 0:
            raise ValueError(f"n_micro must be >= 0, got {self.n_micro}")
        if self.pp > 1 and (self.dp > 1 or self.tp > 1):
            # honest failure beats silent replication: the pipelined loss
            # runs under axis_rules(None) with only the stage axis
            # partitioned, so dp/tp degrees would burn devices computing
            # identical replicas while reporting themselves as parallelism
            raise ValueError(
                f"dp={self.dp}/tp={self.tp} with pp={self.pp} is not "
                "supported yet: the pipelined step would replicate compute "
                "over the data/model axes (no speedup); use dp=tp=1 with "
                "pp>1, or pp=1 for the sharded DP/TP path"
            )
        return self


def resolve_plan(
    plan: ParallelPlan,
    *,
    memory_cap_gib: float = 8.0,
    prof=None,
) -> ParallelPlan:
    """Fill derived fields: default microbatch count, planner-chosen wave.

    The wave choice *is* MegaDPP's planner (``core.dpp.planner.Planner``):
    candidate waves are simulated on the simkit engine and the fastest one
    fitting the activation-memory cap wins — "adopt BFC as long as it does
    not OOM".
    """
    plan.validate()
    if plan.pp <= 1:
        return plan
    if plan.n_micro == 0:
        plan = replace(plan, n_micro=2 * plan.pp)
    if plan.schedule == "wave" and plan.wave == 0:
        from repro.core.dpp.planner import Planner
        from repro.core.simkit.workload import ModelProfile, Topology

        planner = Planner(
            Topology(dp=plan.dp, pp=plan.pp, tp=plan.tp),
            prof or ModelProfile(n_chunks=plan.n_chunks),
            n_micro=plan.n_micro,
            memory_cap=int(memory_cap_gib * (1 << 30)),
        )
        plan = replace(plan, wave=planner.plan().wave)
    return plan


def forward_order(plan: ParallelPlan) -> list[Step]:
    """The desired (microbatch, chunk) visit order the executor's time table
    legalizes.  Only the F steps matter to the forward table; the backward
    traversal is autodiff's mirror."""
    nm, c = plan.n_micro, plan.n_chunks
    if plan.schedule == "dfc":
        return sched_dfc(nm, c)
    if plan.schedule == "bfc":
        return sched_bfc(nm, c)
    if plan.schedule == "wave":
        return sched_wave(nm, c, plan.wave or max(1, nm // 2))
    if plan.schedule == "1f1b":
        return sched_1f1b(nm, c, plan.pp, 0)
    raise ValueError(f"unknown pipeline schedule {plan.schedule!r}")


def plan_summary(plan: ParallelPlan) -> dict:
    """JSON-able view for ``session.results`` / bench output."""
    return {
        "dp": plan.dp, "tp": plan.tp, "pp": plan.pp,
        "n_micro": plan.n_micro, "n_chunks": plan.n_chunks,
        "schedule": plan.schedule, "wave": plan.wave,
        "fbd_backward": plan.fbd_backward,
    }
