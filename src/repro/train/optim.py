"""Optimizer: AdamW with warmup-cosine and WSD (warmup-stable-decay, MiniCPM)
schedules, global-norm clipping, decay masking — raw JAX, fully sharded state.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Literal

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Literal["cosine", "wsd", "constant"] = "cosine"
    warmup_steps: int = 100
    total_steps: int = 10000
    wsd_decay_frac: float = 0.1  # final fraction of steps spent decaying


def schedule_lr(ocfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    if ocfg.schedule == "constant":
        frac = jnp.ones(())
    elif ocfg.schedule == "cosine":
        t = jnp.clip(
            (s - ocfg.warmup_steps) / max(ocfg.total_steps - ocfg.warmup_steps, 1),
            0.0, 1.0,
        )
        frac = ocfg.min_lr_frac + (1 - ocfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif ocfg.schedule == "wsd":
        # warmup -> stable plateau -> exponential-ish linear decay tail
        decay_steps = int(ocfg.total_steps * ocfg.wsd_decay_frac)
        decay_start = ocfg.total_steps - decay_steps
        t = jnp.clip((s - decay_start) / max(decay_steps, 1), 0.0, 1.0)
        frac = 1.0 - (1.0 - ocfg.min_lr_frac) * t
    else:
        raise ValueError(ocfg.schedule)
    return ocfg.lr * warm * frac


def _decay_mask(params: Any) -> Any:
    """Weight decay applies only to rank>=2 tensors (not norms/biases)."""
    return jax.tree.map(lambda p: float(p.ndim >= 2), params)


def init_opt_state(master: Any) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, master)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    ocfg: OptimizerConfig,
    grads: Any,  # fp32, same tree as master
    master: Any,  # fp32 master params
    opt: dict,
) -> tuple[Any, dict, dict]:
    """Returns (new_master, new_opt_state, stats)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = ocfg.betas
    lr = schedule_lr(ocfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mask = _decay_mask(master)

    def upd(g, p, m, v, wd):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * wd * p
        return p - lr * delta, m, v

    flat, treedef = jax.tree.flatten(master)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(opt["m"])
    vflat = jax.tree.leaves(opt["v"])
    wdflat = jax.tree.leaves(mask)
    new_p, new_m, new_v = [], [], []
    for g, p, m, v, wd in zip(gflat, flat, mflat, vflat, wdflat):
        pn, mn, vn = upd(g, p, m, v, wd)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    stats = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree.unflatten(treedef, new_p),
        {"m": jax.tree.unflatten(treedef, new_m),
         "v": jax.tree.unflatten(treedef, new_v),
         "step": step},
        stats,
    )
