"""Train-step factory: mixed precision (bf16 compute params + fp32 master &
moments), optional gradient accumulation, optional gradient compression,
fully sharded (ZeRO) state.

With a :class:`repro.parallel.plan.ParallelPlan` whose ``pp > 1`` the step
routes the transformer block stack through MegaDPP's schedule-controlled
pipeline executor (``core.dpp.executor``) instead of the fused forward:
microbatched grad-accum *is* the pipeline traversal, and the backward
pipeline falls out of autodiff through ``ppermute``.  ``plan.fbd_backward``
additionally attaches MegaFBD's decoupled backward (explicit vjp split —
forward instance produces residuals, a separately-invokable pure transpose
consumes them).  At ``pp == 1`` a plan degrades to plain gradient
accumulation over ``plan.n_micro`` microbatches — bit-for-bit the existing
step.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.parallel.sharding import current_mesh_and_rules, shard_act
from repro.train.optim import OptimizerConfig, adamw_update, init_opt_state


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(a, (str, type(None))) for a in t)


def shard_like_params(axes: Any, tree: Any) -> Any:
    """Constrain a grad pytree to the params' sharding.  Crucially this forces
    XLA to resolve partial-sums (reduce-scatter) while grads are still bf16 —
    before the fp32 cast for the optimizer — halving gradient-sync bytes."""
    return jax.tree.map(
        lambda a, g: shard_act(g, a), axes, tree, is_leaf=_is_axes
    )


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any   # compute-dtype (bf16) copy used by fwd/bwd
    master: Any   # fp32 master copy
    opt: dict     # {"m","v","step"} fp32 moments


def init_train_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    m = get_model(cfg)
    master = m.init(cfg, key)  # fp32 per cfg.param_dtype
    params = jax.tree.map(lambda x: x.astype(cfg.compute_dtype), master)
    return TrainState(params=params, master=master, opt=init_opt_state(master))


def train_state_axes(cfg: ModelConfig) -> TrainState:
    axes = get_model(cfg).param_axes(cfg)
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    copy = lambda: jax.tree.map(lambda t: t, axes, is_leaf=is_axes)
    return TrainState(
        params=copy(),
        master=copy(),
        opt={"m": copy(), "v": copy(), "step": ()},
    )


@dataclass(frozen=True)
class PipelineStepInfo:
    """Static pipeline context attached to a pp>1 step callable (``.pipeline``)
    so the train loop can emit MegaScan bubble-structure events per step."""

    plan: Any            # ParallelPlan
    table: Any           # core.dpp.executor.TimeTable
    layout: Any          # models.pipeline.PipelineLayout


def make_train_step(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    *,
    grad_accum: int = 1,
    grad_transform: Callable[[Any], Any] | None = None,
    collector: Collector = NULL_COLLECTOR,
    plan=None,
    mesh=None,
    compressor=None,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics); pure and jittable.

    ``plan`` (a ``ParallelPlan``) selects the pipeline-parallel path when its
    ``pp > 1`` — ``mesh`` then must carry a ``"stage"`` axis of size ``pp``
    (default: the mesh installed via ``parallel.sharding.axis_rules``).  A
    ``pp == 1`` plan is plain gradient accumulation over ``plan.n_micro``.

    ``compressor`` (a ``repro.ft.GradCompressor``) switches on int8
    gradient sync with error feedback — the ft controller's soft mitigation
    for a degraded DP link.  The step signature then threads the feedback
    buffers: ``step(state, err, batch) -> (state, err, metrics)``;
    ``TrainState`` (and so the checkpoint format) is unchanged.
    """
    if plan is not None and plan.pp > 1:
        if compressor is not None and plan.dp <= 1:
            raise ValueError(
                "gradient compression targets the DP gradient sync; a "
                f"pp={plan.pp} plan with dp=1 has no data axis to compress "
                "over — set parallel.dp > 1 to compose them"
            )
        return _make_pipeline_train_step(
            cfg, ocfg, plan, mesh=mesh, grad_accum=grad_accum,
            grad_transform=grad_transform, collector=collector,
            compressor=compressor,
        )
    if plan is not None:
        grad_accum = max(grad_accum, plan.n_micro)
    model = get_model(cfg)

    def loss_of(params, batch):
        return model.loss_fn(cfg, params, batch, collector)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if grad_accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        B = batch["targets"].shape[0]
        mb = B // grad_accum
        split = jax.tree.map(
            lambda x: x.reshape(grad_accum, mb, *x.shape[1:])
            if hasattr(x, "shape") and x.shape[:1] == (B,)
            else x,
            batch,
        )
        # mrope ids are [3, B, S]: handle their leading-axis layout
        if "mrope_position_ids" in batch:
            split["mrope_position_ids"] = jnp.moveaxis(
                batch["mrope_position_ids"].reshape(3, grad_accum, mb, -1), 1, 0
            )

        def body(carry, micro):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, micro)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (zero, jnp.zeros(())), split
        )
        grads = jax.tree.map(lambda g: g / grad_accum, grads)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    param_axes = model.param_axes(cfg)

    def apply_update(state, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        grads = shard_like_params(param_axes, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        return metrics, grads

    def finish(state, metrics, grads):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        master, opt, stats = adamw_update(ocfg, grads, state.master, state.opt)
        params = jax.tree.map(lambda x: x.astype(cfg.compute_dtype), master)
        new_state = TrainState(params=params, master=master, opt=opt)
        return new_state, {**metrics, **stats}

    if compressor is not None:
        def step_c(state: TrainState, err: Any, batch: dict):
            metrics, grads = apply_update(state, batch)
            # quantize-dequantize before the (sharding-resolved) sync; the
            # residual rides in the error-feedback buffers to the next step
            grads, err = compressor.apply(grads, err)
            new_state, out = finish(state, metrics, grads)
            return new_state, err, out

        return step_c

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        metrics, grads = apply_update(state, batch)
        return finish(state, metrics, grads)

    return step


def _make_pipeline_train_step(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    plan,
    *,
    mesh=None,
    grad_accum: int = 1,
    grad_transform: Callable[[Any], Any] | None = None,
    collector: Collector = NULL_COLLECTOR,
    compressor=None,
) -> Callable:
    """The pp>1 train step: block stack through the MegaDPP pipeline executor.

    Params stay in their canonical stacked layout — the differentiable
    restack to ``[stage, chunk, ...]`` happens inside the loss — so the
    optimizer update, checkpoint format, and sharding constraints are
    unchanged from the fused path.

    Composition: ``plan.dp`` shards the microbatch axis over the mesh's
    ``data`` axis (each dp group pipelines ``plan.n_micro_local``
    microbatches; parameter cotangents all-reduce over ``data`` through the
    ``shard_map`` transpose), ``plan.tp`` slices heads/ffn over ``model``
    inside every stage's body, and ``grad_accum > 1`` runs that many *full
    pipeline passes* back-to-back, averaging their gradients — macrobatch
    accumulation on top of (not instead of) the microbatched traversal.
    """
    from repro.core.dpp.executor import build_time_table
    from repro.models import pipeline as pl
    from repro.parallel.plan import forward_order
    from repro.parallel.sharding import axis_rules

    if mesh is None:
        mesh = current_mesh_and_rules()[0]
    want = {"stage": plan.pp, "data": plan.dp, "model": plan.tp}
    have = dict(mesh.shape) if mesh is not None else {}
    if mesh is None or any(have.get(ax, 1) != n for ax, n in want.items()):
        raise ValueError(
            f"pipeline train step (pp={plan.pp}, dp={plan.dp}, "
            f"tp={plan.tp}) needs a mesh shaped {want}; got "
            f"{have or None} — build one with "
            "repro.launch.mesh.make_pipeline_mesh(pp, dp, tp)"
        )
    layout = pl.pipeline_layout(cfg, plan.pp, plan.n_chunks, tp=plan.tp)
    table = build_time_table(
        forward_order(plan), plan.pp, plan.n_chunks, plan.n_micro_local
    )
    block_fn = pl.make_block_fn(cfg, layout)
    model = get_model(cfg)
    param_axes = model.param_axes(cfg)
    if collector is not NULL_COLLECTOR:
        import logging

        logging.getLogger("repro.train").warning(
            "MegaScope probes do not observe pipelined blocks (pp=%d): "
            "captures cannot ride the pipeline's activation wire", plan.pp
        )

    def loss_of(params, batch):
        return pl.pipeline_loss(
            cfg, params, batch,
            layout=layout, table=table, mesh=mesh,
            n_micro=plan.n_micro, block_fn=block_fn, dp=plan.dp,
        )

    if plan.fbd_backward:
        def grads_once(params, batch):
            # MegaFBD attach: the forward instance records residuals; the
            # transpose is hoisted into a pure, separately-invokable function
            # (closure_convert), its residual arguments being exactly the
            # F->B transfer MegaFBD's coordinator manages.
            loss, vjp, metrics = jax.vjp(
                lambda p: loss_of(p, batch), params, has_aux=True
            )
            vjp_pure, residuals = jax.closure_convert(
                vjp, jnp.ones_like(loss)
            )
            (grads,) = vjp_pure(jnp.ones_like(loss), *residuals)
            return loss, metrics, grads
    else:
        grad_fn = jax.value_and_grad(loss_of, has_aux=True)

        def grads_once(params, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

    if grad_accum <= 1:
        compute_grads = grads_once
    else:
        def compute_grads(params, batch):
            # macrobatch accumulation over full pipeline passes: each scan
            # iteration is one complete microbatched traversal
            B = batch["targets"].shape[0]
            mb = B // grad_accum
            split = jax.tree.map(
                lambda x: x.reshape(grad_accum, mb, *x.shape[1:])
                if hasattr(x, "shape") and x.shape[:1] == (B,)
                else x,
                batch,
            )

            def body(carry, macro):
                acc, loss_acc = carry
                loss, metrics, grads = grads_once(params, macro)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zero, jnp.zeros(())), split
            )
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda x: x[-1], metrics)
            return loss_sum / grad_accum, metrics, grads

    def apply_update(state, batch):
        # the whole grad computation traces with sharding rules inert: the
        # chunked-attention custom_vjp backward is traced lazily during the
        # grad pull-back — *after* pipeline_loss's own axis_rules(None)
        # context has exited — and a logical sharding constraint resolving
        # against ('data','model') inside the manual shard_map transpose is
        # exactly the seq_len>kv_chunk manual_axes crash
        with axis_rules(None):
            loss, metrics, grads = compute_grads(state.params, batch)
        grads = shard_like_params(param_axes, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        return metrics, grads

    def finish(state, metrics, grads):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        master, opt, stats = adamw_update(ocfg, grads, state.master, state.opt)
        params = jax.tree.map(lambda x: x.astype(cfg.compute_dtype), master)
        new_state = TrainState(params=params, master=master, opt=opt)
        return new_state, {**metrics, **stats}

    info = PipelineStepInfo(plan=plan, table=table, layout=layout)

    if compressor is not None:
        def step_c(state: TrainState, err: Any, batch: dict):
            metrics, grads = apply_update(state, batch)
            grads, err = compressor.apply(grads, err)
            new_state, out = finish(state, metrics, grads)
            return new_state, err, out

        step_c.pipeline = info
        return step_c

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        metrics, grads = apply_update(state, batch)
        return finish(state, metrics, grads)

    step.pipeline = info
    return step
