"""Train-step factory: mixed precision (bf16 compute params + fp32 master &
moments), optional gradient accumulation, optional gradient compression,
fully sharded (ZeRO) state.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.parallel.sharding import shard_act
from repro.train.optim import OptimizerConfig, adamw_update, init_opt_state


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(a, (str, type(None))) for a in t)


def shard_like_params(axes: Any, tree: Any) -> Any:
    """Constrain a grad pytree to the params' sharding.  Crucially this forces
    XLA to resolve partial-sums (reduce-scatter) while grads are still bf16 —
    before the fp32 cast for the optimizer — halving gradient-sync bytes."""
    return jax.tree.map(
        lambda a, g: shard_act(g, a), axes, tree, is_leaf=_is_axes
    )


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any   # compute-dtype (bf16) copy used by fwd/bwd
    master: Any   # fp32 master copy
    opt: dict     # {"m","v","step"} fp32 moments


def init_train_state(cfg: ModelConfig, key: jax.Array) -> TrainState:
    m = get_model(cfg)
    master = m.init(cfg, key)  # fp32 per cfg.param_dtype
    params = jax.tree.map(lambda x: x.astype(cfg.compute_dtype), master)
    return TrainState(params=params, master=master, opt=init_opt_state(master))


def train_state_axes(cfg: ModelConfig) -> TrainState:
    axes = get_model(cfg).param_axes(cfg)
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    copy = lambda: jax.tree.map(lambda t: t, axes, is_leaf=is_axes)
    return TrainState(
        params=copy(),
        master=copy(),
        opt={"m": copy(), "v": copy(), "step": ()},
    )


def make_train_step(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    *,
    grad_accum: int = 1,
    grad_transform: Callable[[Any], Any] | None = None,
    collector: Collector = NULL_COLLECTOR,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics); pure and jittable."""
    model = get_model(cfg)

    def loss_of(params, batch):
        return model.loss_fn(cfg, params, batch, collector)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if grad_accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        B = batch["targets"].shape[0]
        mb = B // grad_accum
        split = jax.tree.map(
            lambda x: x.reshape(grad_accum, mb, *x.shape[1:])
            if hasattr(x, "shape") and x.shape[:1] == (B,)
            else x,
            batch,
        )
        # mrope ids are [3, B, S]: handle their leading-axis layout
        if "mrope_position_ids" in batch:
            split["mrope_position_ids"] = jnp.moveaxis(
                batch["mrope_position_ids"].reshape(3, grad_accum, mb, -1), 1, 0
            )

        def body(carry, micro):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, micro)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (zero, jnp.zeros(())), split
        )
        grads = jax.tree.map(lambda g: g / grad_accum, grads)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    param_axes = model.param_axes(cfg)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        loss, metrics, grads = compute_grads(state.params, batch)
        grads = shard_like_params(param_axes, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        master, opt, stats = adamw_update(ocfg, grads, state.master, state.opt)
        params = jax.tree.map(lambda x: x.astype(cfg.compute_dtype), master)
        new_state = TrainState(params=params, master=master, opt=opt)
        return new_state, {**metrics, **stats}

    return step
