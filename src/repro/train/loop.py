"""End-to-end training driver: data pipeline + jitted train step + async
checkpointing + MegaScan tracing + optional MegaScope probes + failover.

The `python -m repro train` workload drives this loop through
``repro.app.Session`` (module plugins attach via :class:`StepHooks`); the
fault-tolerance tests call ``train`` directly.  The same loop drives the
multi-pod configuration (the jit step is mesh-agnostic — shardings come
from the installed axis rules).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.core.tracing.tracer import Tracer
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.hooks import NULL_COLLECTOR
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    n_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    seed: int = 0
    grad_accum: int = 1


@dataclass
class StepHooks:
    """Plugin attach points threaded in by ``repro.app.Session``.

    ``wrap_step`` decorates the jitted step callable once, before the loop;
    ``on_step(events, metrics)`` observes each completed step — the MegaScan
    ``TraceEvent``s it appended and its (possibly device-resident) metrics.
    """

    wrap_step: Callable[[Callable], Callable] | None = None
    on_step: Callable[[list, dict], None] | None = None


def _step_flops(jit_step, state, batch) -> float:
    """Model flops of one jitted step via XLA's cost analysis (the MFU
    numerator).  ``Lowered.cost_analysis`` needs no compile; fall back to
    the compiled executable's analysis, and to 0.0 (series disabled) on
    backends exposing neither."""
    try:
        lowered = jit_step.lower(state, batch)
        try:
            cost = lowered.cost_analysis()
        except Exception:
            cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return float(cost.get("flops", 0.0) or 0.0)
    except Exception:
        return 0.0


_MEM_STATS_SUPPORTED: bool | None = None  # probed once; CPU returns None


def _device_mem_bytes() -> float | None:
    """Live device memory (None on backends without allocator stats)."""
    global _MEM_STATS_SUPPORTED
    if _MEM_STATS_SUPPORTED is False:
        return None
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and "bytes_in_use" in stats:
            _MEM_STATS_SUPPORTED = True
            return float(stats["bytes_in_use"])
    except Exception:
        pass
    _MEM_STATS_SUPPORTED = False
    return None


def _publish_step_metrics(registry, metrics, *, step_s, tokens, flops):
    """One step's standard series into the MetricsRegistry (host-side)."""
    registry.counter("train.steps").inc()
    registry.counter("train.tokens").inc(tokens)
    registry.histogram("train.step_time_s").observe(step_s)
    registry.gauge("train.tokens_per_s").set(tokens / max(step_s, 1e-9))
    if flops:
        registry.histogram("train.model_flops_per_s").observe(
            flops / max(step_s, 1e-9)
        )
    for k in ("loss", "grad_norm", "lr"):
        v = metrics.get(k)
        if v is not None and getattr(v, "ndim", 0) == 0:
            registry.gauge(f"train.{k}").set(float(v))
    mem = _device_mem_bytes()
    if mem is not None:
        registry.gauge("train.device_mem_bytes").set(mem)


def train(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    data_cfg: DataConfig,
    loop: LoopConfig,
    *,
    collector=NULL_COLLECTOR,
    tracer: Tracer | None = None,
    state=None,
    hooks: StepHooks | None = None,
    plan=None,
    registry=None,
    obs=None,
) -> tuple[Any, list[dict]]:
    # tracing defaults ON, matching MegaServe — the repo-wide documented
    # default (observability is always-on; pass a disabled Tracer to opt out)
    # ``registry`` (a repro.obs.MetricsRegistry) receives the standard train
    # series each step; ``obs`` (a repro.obs.RankEventSpec) synthesizes
    # per-rank events — and induces a live straggler when its slow_rank >= 0
    tracer = tracer or Tracer(rank=0, enabled=True)
    ds = SyntheticTokens(data_cfg)
    if state is None:
        with tracer.scope("init", op="init"):
            state = init_train_state(cfg, jax.random.PRNGKey(loop.seed))

    raw_step = make_train_step(
        cfg, ocfg, grad_accum=loop.grad_accum, collector=collector, plan=plan
    )
    # pp>1 steps carry their static dispatch table; MegaScan folds it into
    # per-(microbatch, stage, F/B) events after each measured step
    pp_info = getattr(raw_step, "pipeline", None)
    # when compute dtype == param dtype the bf16 cast is a no-op and
    # state.params aliases state.master — donating the state would hand XLA
    # the same buffer twice (Execute() rejects it; under SPMD the surviving
    # devices then hang at the next collective).  Donation is a pure memory
    # optimization, so drop it for same-dtype (fp32 smoke) configs.
    donate = (
        (0,) if np.dtype(cfg.compute_dtype) != np.dtype(cfg.param_dtype)
        else ()
    )
    jit_step = jax.jit(raw_step, donate_argnums=donate)
    step_fn = jit_step
    if hooks is not None and hooks.wrap_step is not None:
        step_fn = hooks.wrap_step(step_fn)

    start = 0
    ckpt = None
    if loop.ckpt_dir:
        ckpt = Checkpointer(loop.ckpt_dir)
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            state, _ = restore(loop.ckpt_dir, state)
            start = last
            log.info("restored checkpoint at step %d", start)

    # MFU numerator, once: the flops XLA attributes to one step (lowering
    # uses the same in-memory jit, so the first real call still compiles
    # exactly once).  Only probed when someone will read the series.
    flops = (
        _step_flops(jit_step, state, ds.batch_at(start))
        if registry is not None else 0.0
    )
    tokens_per_step = data_cfg.global_batch * data_cfg.seq_len

    history: list[dict] = []
    t0 = time.perf_counter()
    for step in range(start, loop.n_steps):
        batch = ds.batch_at(step)
        n_ev = len(tracer.events)
        t_step = time.perf_counter()
        with tracer.scope("train_step", op="train_step", mb=step):
            state, metrics = step_fn(state, batch)
            extra = 0.0
            if obs is not None and obs.slow_rank >= 0:
                # induce the straggler INSIDE the scope: block until the
                # real compute lands, then sleep the downclock excess —
                # the step window genuinely stretches, like a slow rank's
                jax.block_until_ready(metrics)
                extra = obs.extra_seconds(time.perf_counter() - t_step)
                if extra > 0:
                    time.sleep(extra)
        step_s = time.perf_counter() - t_step
        anchor = tracer.events[-1] if tracer.enabled else None
        if pp_info is not None and anchor is not None:
            from repro.core.dpp.executor import emit_pipeline_events

            # the train_step scope just closed; fold its wall into
            # per-(microbatch, stage, F/B) pipeline events
            emit_pipeline_events(
                tracer.events, pp_info.table,
                ts=anchor.ts, wall=anchor.dur, step_idx=step,
            )
        if obs is not None and anchor is not None:
            from repro.obs.inject import emit_rank_events

            emit_rank_events(
                tracer.events, obs,
                ts=anchor.ts, wall=anchor.dur, extra=extra, step=step,
            )
        if registry is not None:
            _publish_step_metrics(
                registry, metrics,
                step_s=step_s, tokens=tokens_per_step, flops=flops,
            )
        if hooks is not None and hooks.on_step is not None:
            hooks.on_step(tracer.events[n_ev:], metrics)
        if (step + 1) % loop.log_every == 0 or step == loop.n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if hasattr(v, "ndim") and v.ndim == 0}
            m["step"] = step + 1
            m["wall_s"] = round(time.perf_counter() - t0, 2)
            history.append(m)
            log.info("step %d: loss=%.4f lr=%.2e", step + 1,
                     m.get("loss", float("nan")), m.get("lr", 0.0))
        if ckpt and (step + 1) % loop.ckpt_every == 0:
            ckpt.save_async(state, step + 1, metadata={"arch": cfg.name})
    if ckpt:
        ckpt.wait()
    return state, history
