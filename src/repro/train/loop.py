"""End-to-end training driver: data pipeline + jitted train step + async
checkpointing + MegaScan tracing + optional MegaScope probes + supervised
fault tolerance.

The `python -m repro train` workload drives this loop through
``repro.app.Session`` (module plugins attach via :class:`StepHooks`); the
fault-tolerance tests call ``train`` directly.  The same loop drives the
multi-pod configuration (the jit step is mesh-agnostic — shardings come
from the installed axis rules).

With a :class:`repro.ft.FtController` attached (the ``ft`` module plugin),
the loop is *supervised*: any step failure — a chaos-injected crash, a
mitigation-requested exclusion restart, a guard rollback — restores the
latest checkpoint and resumes, bounded by ``ft.max_restarts`` with
exponential backoff.  Step-indexed batch determinism
(``SyntheticTokens.batch_at``) makes the replayed trajectory identical to a
fault-free run.  The controller's pending mitigation actions execute at
step boundaries:

* **compress_on** — rebuild the jit step with ``GradCompressor`` int8
  gradient sync + error feedback (degraded DP link mitigation);
* **replan_schedule** — re-resolve the MegaDPP wave schedule around a slow
  pipeline stage and rebuild the pipelined step;
* **exclude_restart** — mark the rank excluded (its induced slowdown
  stops, so the detector observes the recovery) and roll back through the
  elastic-restore path.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.core.tracing.tracer import Tracer
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.hooks import NULL_COLLECTOR
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    n_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    seed: int = 0
    grad_accum: int = 1


@dataclass
class StepHooks:
    """Plugin attach points threaded in by ``repro.app.Session``.

    ``wrap_step`` decorates the jitted step callable once, before the loop;
    ``on_step(events, metrics)`` observes each completed step — the MegaScan
    ``TraceEvent``s it appended and its (possibly device-resident) metrics.
    """

    wrap_step: Callable[[Callable], Callable] | None = None
    on_step: Callable[[list, dict], None] | None = None


class _MitigationRestart(RuntimeError):
    """The controller decided EXCLUDE_RESTART: roll back and resume."""


class _GuardRollback(RuntimeError):
    """An in-band guard tripped with guard_action=rollback."""


def _aot_train_step(jit_fn, avatars, *, cache, key_parts, registry):
    """AOT-compile the train step (``jit(...).lower().compile()``) through
    the persistent compile cache: a restarted process deserializes the prior
    run's executable instead of paying XLA at its first step.  Returns the
    compiled executable (a drop-in callable for the jitted step)."""
    from repro.core.compile_cache import aot_compile

    t0 = time.perf_counter()
    exe, hit = aot_compile(jit_fn, avatars, cache=cache, key_parts=key_parts)
    ms = (time.perf_counter() - t0) * 1e3
    log.info("train step AOT %s in %.0f ms",
             "cache hit" if hit else "compiled", ms)
    if registry is not None:
        registry.gauge("train.precompile_ms").set(ms)
    return exe


def _step_flops(jit_step, state, batch) -> float:
    """Model flops of one jitted step via XLA's cost analysis (the MFU
    numerator).  ``Lowered.cost_analysis`` needs no compile; fall back to
    the compiled executable's analysis, and to 0.0 (series disabled) on
    backends exposing neither."""
    try:
        lowered = jit_step.lower(state, batch)
        try:
            cost = lowered.cost_analysis()
        except Exception:
            cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return float(cost.get("flops", 0.0) or 0.0)
    except Exception:
        return 0.0


def _shardings(state):
    """Per-leaf shardings of the live state (the elastic-restore target)."""
    return jax.tree.map(lambda x: getattr(x, "sharding", None), state)


def _route_links(plan, links) -> tuple[list, list]:
    """Split detected degraded links by the mesh axis they live on.

    Pure-DP runs (no plan) treat every link as a data link — the old
    behavior.  Composed plans map both endpoints through the plan topology
    (rank -> (dp, stage, tp) coordinates): links crossing the data axis are
    gradient-sync links (compressible), links crossing the stage axis are
    pipeline P2P links (replannable); anything else — tp links, diagonal
    pairs, out-of-range ranks — mitigates as neither.
    """
    links = [tuple(l) for l in (links or [])]
    if plan is None:
        return links, []
    from repro.parallel.plan import link_axis

    data = [l for l in links if link_axis(plan, l) == "data"]
    stage = [l for l in links if link_axis(plan, l) == "stage"]
    return data, stage


_MEM_STATS_SUPPORTED: bool | None = None  # probed once; CPU returns None


def _device_mem_bytes() -> float | None:
    """Live device memory (None on backends without allocator stats)."""
    global _MEM_STATS_SUPPORTED
    if _MEM_STATS_SUPPORTED is False:
        return None
    try:
        stats = jax.local_devices()[0].memory_stats()
        if stats and "bytes_in_use" in stats:
            _MEM_STATS_SUPPORTED = True
            return float(stats["bytes_in_use"])
    except Exception:
        pass
    _MEM_STATS_SUPPORTED = False
    return None


def _publish_step_metrics(registry, metrics, *, step_s, tokens, flops):
    """One step's standard series into the MetricsRegistry (host-side)."""
    registry.counter("train.steps").inc()
    registry.counter("train.tokens").inc(tokens)
    registry.histogram("train.step_time_s").observe(step_s)
    registry.gauge("train.tokens_per_s").set(tokens / max(step_s, 1e-9))
    if flops:
        registry.histogram("train.model_flops_per_s").observe(
            flops / max(step_s, 1e-9)
        )
    for k in ("loss", "grad_norm", "lr"):
        v = metrics.get(k)
        if v is not None and getattr(v, "ndim", 0) == 0:
            registry.gauge(f"train.{k}").set(float(v))
    mem = _device_mem_bytes()
    if mem is not None:
        registry.gauge(f"train.device_mem_bytes").set(mem)


def train(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    data_cfg: DataConfig,
    loop: LoopConfig,
    *,
    collector=NULL_COLLECTOR,
    tracer: Tracer | None = None,
    state=None,
    hooks: StepHooks | None = None,
    plan=None,
    registry=None,
    obs=None,
    controller=None,
    compile_cache=None,
) -> tuple[Any, list[dict]]:
    # tracing defaults ON, matching MegaServe — the repo-wide documented
    # default (observability is always-on; pass a disabled Tracer to opt out)
    # ``registry`` (a repro.obs.MetricsRegistry) receives the standard train
    # series each step; ``obs`` (a repro.obs.RankEventSpec) synthesizes
    # per-rank events — and induces a live straggler when its slow_rank >= 0;
    # ``controller`` (a repro.ft.FtController) supervises the whole loop
    tracer = tracer or Tracer(rank=0, enabled=True)
    ds = SyntheticTokens(data_cfg)
    if state is None:
        with tracer.scope("init", op="init"):
            state = init_train_state(cfg, jax.random.PRNGKey(loop.seed))
    if controller is not None:
        controller.registry = registry

    # when compute dtype == param dtype the bf16 cast is a no-op and
    # state.params aliases state.master — donating the state would hand XLA
    # the same buffer twice (Execute() rejects it; under SPMD the surviving
    # devices then hang at the next collective).  Donation is a pure memory
    # optimization, so drop it for same-dtype (fp32 smoke) configs — and for
    # skip-guard runs, whose semantics need the pre-step buffers alive.
    may_donate = (
        np.dtype(cfg.compute_dtype) != np.dtype(cfg.param_dtype)
        and not (controller is not None
                 and controller.options.guard_action == "skip")
    )

    def build(plan_, compressor=None):
        """(Re)build the wrapped jit step — also the mitigation rebuild path
        (compression on, schedule replanned); runs under the ambient mesh
        Session installed around this loop."""
        raw = make_train_step(
            cfg, ocfg, grad_accum=loop.grad_accum, collector=collector,
            plan=plan_, compressor=compressor,
        )
        # pp>1 steps carry their static dispatch table; MegaScan folds it
        # into per-(microbatch, stage, F/B) events after each measured step
        pp = getattr(raw, "pipeline", None)
        donate = (
            ((0, 1) if compressor is not None else (0,)) if may_donate else ()
        )
        jit_fn = jax.jit(raw, donate_argnums=donate)
        inner = jit_fn
        if compile_cache is not None:
            # AOT warmup through the persistent cache — restricted to runs
            # whose sharding is trivial (no mesh, or a single-device mesh):
            # avatars carry no shardings, so a multi-device step compiled
            # from them would expect replicated inputs and reject the live
            # sharded state
            from repro.core.compile_cache import mesh_descriptor
            from repro.parallel.sharding import current_mesh_and_rules

            mesh = current_mesh_and_rules()[0]
            if (mesh is None or getattr(mesh, "empty", False)
                    or getattr(mesh, "size", 0) == 1):
                av = lambda t: jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
                )
                avatars = [av(state)]
                if compressor is not None:
                    avatars.append(av(jax.eval_shape(
                        compressor.init, state.master
                    )))
                avatars.append(av(ds.batch_at(0)))
                inner = _aot_train_step(
                    jit_fn, tuple(avatars),
                    cache=compile_cache, registry=registry,
                    key_parts={
                        "model": cfg, "opt": ocfg, "data": data_cfg,
                        "grad_accum": loop.grad_accum, "plan": plan_,
                        "compress": compressor is not None,
                        "donate": list(donate),
                        "mesh": mesh_descriptor(mesh),
                        "state": [
                            f"{l.shape}/{l.dtype}"
                            for l in jax.tree.leaves(av(state))
                        ],
                    },
                )
        fn = inner
        if hooks is not None and hooks.wrap_step is not None:
            fn = hooks.wrap_step(inner)
        return fn, jit_fn, pp

    step_fn, jit_step, pp_info = build(plan)
    comp = None            # GradCompressor once the mitigation activates
    comp_err = None        # its error-feedback buffers
    comp_wire = (0, 0)     # (compressed, bf16-baseline) bytes per step

    start = 0
    ckpt = None
    if loop.ckpt_dir:
        ckpt = Checkpointer(loop.ckpt_dir)
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            state, _ = restore(loop.ckpt_dir, state, shardings=_shardings(state))
            start = last
            log.info("restored checkpoint at step %d", start)
        elif controller is not None:
            # supervised runs always have a rollback target, even before
            # the first periodic save lands
            ckpt.save_async(state, 0, metadata={"arch": cfg.name})

    # MFU numerator, once: the flops XLA attributes to one step (lowering
    # uses the same in-memory jit, so the first real call still compiles
    # exactly once).  Only probed when someone will read the series.
    flops = (
        _step_flops(jit_step, state, ds.batch_at(start))
        if registry is not None else 0.0
    )
    tokens_per_step = data_cfg.global_batch * data_cfg.seq_len

    guards_on = controller is not None and (
        controller.options.guard_nan or controller.options.guard_spike > 0
    )
    skip_guard = (
        guards_on and controller.options.guard_action == "skip"
    )
    max_restarts = controller.options.max_restarts if controller is not None else 0
    backoff_s = controller.options.backoff_s if controller is not None else 0.0

    history: list[dict] = []
    t0 = time.perf_counter()
    step = start
    attempts = 0
    while step < loop.n_steps:
        try:
            if controller is not None:
                for act in controller.poll():
                    if act.kind == "exclude":
                        controller.excluded.update(act.slow_ranks)
                        controller.record(step, "mitigate:exclude", {
                            "ranks": sorted(act.slow_ranks),
                            "detect_step": act.detect_step,
                            "restart": ckpt is not None,
                        })
                        if ckpt is not None:
                            raise _MitigationRestart(
                                f"excluding ranks {sorted(act.slow_ranks)}"
                            )
                        log.warning("ft: excluding %s without restart "
                                    "(no ckpt_dir)", sorted(act.slow_ranks))
                    elif (data_stage := _route_links(plan, act.degraded_links))[0] \
                            and comp is None and (
                                plan is None or plan.pp <= 1 or plan.dp > 1
                    ):
                        # a data-axis link has a gradient sync to compress —
                        # either the pure DP/TP path, or a composed plan with
                        # dp>1 (the pipelined backward's data-axis all-reduce)
                        from repro.ft.compress import GradCompressor

                        data_links = data_stage[0]
                        comp = GradCompressor()
                        comp_err = comp.init(state.master)
                        comp_wire = comp.wire_bytes(state.master)
                        step_fn, jit_step, pp_info = build(plan, compressor=comp)
                        controller.replans += 1
                        controller.compression_on = True
                        controller.record(step, "mitigate:compress_on", {
                            "links": [list(l) for l in data_links],
                            "detect_step": act.detect_step,
                            "wire_bytes_per_sync": comp_wire[0],
                            "baseline_bytes_per_sync": comp_wire[1],
                        })
                        log.warning(
                            "ft: int8 gradient sync ON (%.2fx wire bytes) "
                            "for degraded links %s",
                            comp_wire[0] / max(comp_wire[1], 1),
                            [list(l) for l in data_links],
                        )
                    elif (act.slow_ranks or data_stage[1]) \
                            and plan is not None and plan.pp > 1:
                        # slow ranks or degraded stage-axis P2P links: route
                        # around them with a MegaDPP wave re-plan
                        from dataclasses import replace as _dc_replace
                        from types import SimpleNamespace

                        from repro.core.dpp.planner import Planner
                        from repro.core.simkit.workload import ModelProfile

                        planner = Planner(
                            plan.topology(),
                            ModelProfile(n_chunks=plan.n_chunks),
                            n_micro=plan.n_micro_local,
                        )
                        res = planner.replan(SimpleNamespace(
                            slow_ranks=list(act.slow_ranks),
                            degraded_links=data_stage[1],
                        ))
                        plan = _dc_replace(plan, schedule="wave", wave=res.wave)
                        step_fn, jit_step, pp_info = build(plan)
                        controller.replans += 1
                        controller.record(step, "mitigate:replan_schedule", {
                            "slow_ranks": sorted(act.slow_ranks),
                            "detect_step": act.detect_step,
                            "wave": res.wave,
                            "makespan_ms": round(res.makespan * 1e3, 3),
                        })
                        log.warning("ft: replanned pipeline schedule -> "
                                    "wave=%d around slow ranks %s",
                                    res.wave, sorted(act.slow_ranks))
                    else:
                        controller.record(step, "mitigate:replan_noop", {
                            "slow_ranks": sorted(act.slow_ranks),
                            "detect_step": act.detect_step,
                        })
                if controller.crash_due(step):
                    from repro.ft.chaos import InjectedCrash

                    raise InjectedCrash(f"chaos: injected crash at step {step}")

            batch = ds.batch_at(step)
            eff_obs = (
                controller.effective_obs(obs, step)
                if controller is not None else obs
            )
            if controller is not None:
                batch = controller.poison_batch(batch, step)
            # skip-guard runs keep the pre-step buffers alive (they never
            # donate) so a tripped guard can discard the poisoned update
            prev_state, prev_err = (state, comp_err) if skip_guard else (None, None)
            n_ev = len(tracer.events)
            t_step = time.perf_counter()
            with tracer.scope("train_step", op="train_step", mb=step):
                if comp is None:
                    state, metrics = step_fn(state, batch)
                else:
                    state, comp_err, metrics = step_fn(state, comp_err, batch)
                extra = 0.0
                if eff_obs is not None and eff_obs.slow_rank >= 0:
                    # induce the straggler INSIDE the scope: block until the
                    # real compute lands, then sleep the downclock excess —
                    # the step window genuinely stretches, like a slow rank's
                    jax.block_until_ready(metrics)
                    extra = eff_obs.extra_seconds(time.perf_counter() - t_step)
                    if extra > 0:
                        time.sleep(extra)
            step_s = time.perf_counter() - t_step
            if guards_on:
                verdict = controller.check_guards(
                    step,
                    float(metrics.get("loss", 0.0)),
                    float(metrics.get("grad_norm", 0.0)),
                )
                if verdict == "rollback":
                    raise _GuardRollback(f"guard tripped at step {step}")
                if verdict == "skip":
                    # discard the poisoned update (pre-step buffers are
                    # alive: skip-guard runs never donate) and move on —
                    # cheaper than a rollback, at the cost of diverging
                    # from the fault-free trajectory by one skipped batch
                    state, comp_err = prev_state, prev_err
                    del tracer.events[n_ev:]
                    step += 1
                    continue
            anchor = tracer.events[-1] if tracer.enabled else None
            if pp_info is not None and anchor is not None:
                from repro.core.dpp.executor import emit_pipeline_events

                # the train_step scope just closed; fold its wall into
                # per-(microbatch, stage, F/B) pipeline events
                emit_pipeline_events(
                    tracer.events, pp_info.table,
                    ts=anchor.ts, wall=anchor.dur, step_idx=step,
                )
            if eff_obs is not None and anchor is not None:
                from repro.obs.inject import emit_rank_events

                emit_rank_events(
                    tracer.events, eff_obs,
                    ts=anchor.ts, wall=anchor.dur, extra=extra, step=step,
                )
            if registry is not None:
                _publish_step_metrics(
                    registry, metrics,
                    step_s=step_s, tokens=tokens_per_step, flops=flops,
                )
                if comp is not None:
                    registry.counter("ft.wire_bytes_compressed").inc(comp_wire[0])
                    registry.counter("ft.wire_bytes_baseline").inc(comp_wire[1])
            if hooks is not None and hooks.on_step is not None:
                hooks.on_step(tracer.events[n_ev:], metrics)
            if (step + 1) % loop.log_every == 0 or step == loop.n_steps - 1:
                m = {k: float(v) for k, v in metrics.items()
                     if hasattr(v, "ndim") and v.ndim == 0}
                m["step"] = step + 1
                m["wall_s"] = round(time.perf_counter() - t0, 2)
                history.append(m)
                log.info("step %d: loss=%.4f lr=%.2e", step + 1,
                         m.get("loss", float("nan")), m.get("lr", 0.0))
            step += 1
            if ckpt and step % loop.ckpt_every == 0:
                ckpt.save_async(state, step, metadata={"arch": cfg.name})
        except Exception as e:  # noqa: BLE001 — the supervised recovery path
            attempts += 1
            if controller is None or ckpt is None or attempts > max_restarts:
                raise
            log.warning("step %d failed (%s: %s); recovery %d/%d",
                        step, type(e).__name__, e, attempts, max_restarts)
            # drain (not wait): a background save error here must not mask
            # the failure being recovered from — log and restore anyway
            bg = ckpt.drain()
            if bg is not None:
                log.warning("background checkpoint save failed (%s); "
                            "restoring from the previous one", bg)
            last = latest_step(loop.ckpt_dir)
            if last is None:
                raise
            if backoff_s > 0:
                time.sleep(min(backoff_s * 2 ** (attempts - 1), 30.0))
            # restore into the live state's exact shardings — a bare
            # device_put would land replicated, and the changed reduction
            # orders drift the replayed trajectory off the fault-free one
            state, _ = restore(loop.ckpt_dir, state, shardings=_shardings(state))
            if comp is not None:
                # error-feedback buffers are step-local state, not part of
                # the checkpoint contract: restart them at zero
                comp_err = comp.init(state.master)
            # drop history rows past the restored step — the replayed steps
            # re-append them; keeping both double-counts
            history[:] = [h for h in history if h["step"] <= last]
            if isinstance(e, _GuardRollback):
                controller.record_rollback(step, last)
            else:
                reason = ("exclude" if isinstance(e, _MitigationRestart)
                          else type(e).__name__)
                controller.record_restart(step, last, reason)
            log.info("restored checkpoint at step %d; resuming", last)
            step = last
    if ckpt:
        ckpt.wait()
    return state, history
