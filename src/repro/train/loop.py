"""End-to-end training driver: data pipeline + jitted train step + async
checkpointing + MegaScan tracing + optional MegaScope probes + failover.

The `python -m repro train` workload drives this loop through
``repro.app.Session`` (module plugins attach via :class:`StepHooks`); the
fault-tolerance tests call ``train`` directly.  The same loop drives the
multi-pod configuration (the jit step is mesh-agnostic — shardings come
from the installed axis rules).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.core.tracing.tracer import Tracer
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.hooks import NULL_COLLECTOR
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    n_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    seed: int = 0
    grad_accum: int = 1


@dataclass
class StepHooks:
    """Plugin attach points threaded in by ``repro.app.Session``.

    ``wrap_step`` decorates the jitted step callable once, before the loop;
    ``on_step(events, metrics)`` observes each completed step — the MegaScan
    ``TraceEvent``s it appended and its (possibly device-resident) metrics.
    """

    wrap_step: Callable[[Callable], Callable] | None = None
    on_step: Callable[[list, dict], None] | None = None


def train(
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    data_cfg: DataConfig,
    loop: LoopConfig,
    *,
    collector=NULL_COLLECTOR,
    tracer: Tracer | None = None,
    state=None,
    hooks: StepHooks | None = None,
    plan=None,
) -> tuple[Any, list[dict]]:
    # tracing defaults ON, matching MegaServe — the repo-wide documented
    # default (observability is always-on; pass a disabled Tracer to opt out)
    tracer = tracer or Tracer(rank=0, enabled=True)
    ds = SyntheticTokens(data_cfg)
    if state is None:
        with tracer.scope("init", op="init"):
            state = init_train_state(cfg, jax.random.PRNGKey(loop.seed))

    raw_step = make_train_step(
        cfg, ocfg, grad_accum=loop.grad_accum, collector=collector, plan=plan
    )
    # pp>1 steps carry their static dispatch table; MegaScan folds it into
    # per-(microbatch, stage, F/B) events after each measured step
    pp_info = getattr(raw_step, "pipeline", None)
    # when compute dtype == param dtype the bf16 cast is a no-op and
    # state.params aliases state.master — donating the state would hand XLA
    # the same buffer twice (Execute() rejects it; under SPMD the surviving
    # devices then hang at the next collective).  Donation is a pure memory
    # optimization, so drop it for same-dtype (fp32 smoke) configs.
    donate = (
        (0,) if np.dtype(cfg.compute_dtype) != np.dtype(cfg.param_dtype)
        else ()
    )
    step_fn = jax.jit(raw_step, donate_argnums=donate)
    if hooks is not None and hooks.wrap_step is not None:
        step_fn = hooks.wrap_step(step_fn)

    start = 0
    ckpt = None
    if loop.ckpt_dir:
        ckpt = Checkpointer(loop.ckpt_dir)
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            state, _ = restore(loop.ckpt_dir, state)
            start = last
            log.info("restored checkpoint at step %d", start)

    history: list[dict] = []
    t0 = time.perf_counter()
    for step in range(start, loop.n_steps):
        batch = ds.batch_at(step)
        n_ev = len(tracer.events)
        with tracer.scope("train_step", op="train_step", mb=step):
            state, metrics = step_fn(state, batch)
        if pp_info is not None and tracer.enabled:
            from repro.core.dpp.executor import emit_pipeline_events

            anchor = tracer.events[-1]  # the train_step scope just closed
            emit_pipeline_events(
                tracer.events, pp_info.table,
                ts=anchor.ts, wall=anchor.dur, step_idx=step,
            )
        if hooks is not None and hooks.on_step is not None:
            hooks.on_step(tracer.events[n_ev:], metrics)
        if (step + 1) % loop.log_every == 0 or step == loop.n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if hasattr(v, "ndim") and v.ndim == 0}
            m["step"] = step + 1
            m["wall_s"] = round(time.perf_counter() - t0, 2)
            history.append(m)
            log.info("step %d: loss=%.4f lr=%.2e", step + 1,
                     m.get("loss", float("nan")), m.get("lr", 0.0))
        if ckpt and (step + 1) % loop.ckpt_every == 0:
            ckpt.save_async(state, step + 1, metadata={"arch": cfg.name})
    if ckpt:
        ckpt.wait()
    return state, history
