"""Family dispatch: a uniform Model API over lm.py and encdec.py.

    m = get_model(cfg)
    params = m.init(cfg, key)
    loss, metrics = m.loss_fn(cfg, params, batch)
    cache = m.init_cache(cfg, batch_size, cache_len)
    cache, logits = m.prefill(cfg, params, batch, cache)
    cache, logits = m.decode_step(cfg, params, cache, tokens, pos)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


@dataclass(frozen=True)
class Model:
    init: Callable
    param_axes: Callable
    loss_fn: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    forward: Callable | None = None


def _encdec_init_cache(cfg, batch, cache_len, src_len=None):
    return encdec.init_cache(cfg, batch, cache_len, src_len or cache_len)


ENCDEC = Model(
    init=encdec.init,
    param_axes=encdec.param_axes,
    loss_fn=encdec.loss_fn,
    init_cache=_encdec_init_cache,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
)

LM = Model(
    init=lm.init,
    param_axes=lm.param_axes,
    loss_fn=lm.loss_fn,
    init_cache=lm.init_cache,
    prefill=lm.prefill,
    decode_step=lm.decode_step,
    forward=lm.forward,
)


def get_model(cfg: ModelConfig) -> Model:
    return ENCDEC if cfg.family == "encdec" else LM


def make_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array) -> dict:
    """Synthetic training batch matching the arch's input kind."""
    k1, k2, k3 = jax.random.split(key, 3)
    out: dict[str, Any] = {
        "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.input_kind == "tokens":
        out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
    else:
        out["embeds"] = jax.random.normal(k1, (batch, seq, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            out["tokens"] = jax.random.randint(k3, (batch, seq), 0, cfg.vocab_size)
        if cfg.input_kind == "embeds_mrope":
            pos = jnp.broadcast_to(jnp.arange(seq), (batch, seq))
            out["mrope_position_ids"] = jnp.stack([pos, pos, pos]).astype(jnp.int32)
    return out


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (for MODEL_FLOPS = 6 * N_active * D)."""
    total = _dense_param_count(cfg)
    if cfg.family == "moe":
        mo = cfg.moe
        expert_p = 3 * cfg.d_model * mo.expert_d_ff
        n_moe_layers = cfg.num_layers - mo.first_k_dense
        total -= n_moe_layers * mo.num_experts * expert_p
        total += n_moe_layers * mo.top_k * expert_p
    return total


def _dense_param_count(cfg: ModelConfig) -> int:
    """Parameter count computed analytically from shapes (excl. embeddings
    for FLOPs purposes the embedding gather is not a matmul; the unembed is)."""
    cfg_counts = jax.eval_shape(
        lambda k: get_model(cfg).init(cfg, k), jax.random.PRNGKey(0)
    )
    n = sum(int(x.size) for x in jax.tree.leaves(cfg_counts))
    # exclude the input embedding gather (not matmul FLOPs).  For tied
    # embeddings the single table also serves as the unembed matmul, so it
    # stays counted.
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model
    return n
