"""Introspection hooks threaded through model code (MegaScope attach point).

Model forward functions accept an optional ``Collector``; the default one is
inert (captures nothing, perturbs nothing) so the model code stays clean and
zero-overhead when MegaScope is disabled — the paper's "optional, activated
via runtime flags" property.
"""

from __future__ import annotations

from typing import Any

import jax


class Collector:
    """No-op probe collector; ``repro.core.scope`` subclasses it."""

    def tag(self, name: str, value: jax.Array, **meta: Any) -> jax.Array:
        """Observe ``value`` under ``name``; may return a perturbed copy."""
        return value

    def drain(self) -> dict[str, Any]:
        """Return and clear captured (compressed) values.  Called at the end
        of each scanned layer body so captures flow out through scan ys."""
        return {}

    def aux(self) -> dict[str, Any]:
        return {}


NULL_COLLECTOR = Collector()


class LayerScoped(Collector):
    """Wraps a collector, prefixing tags with a layer index (used in scans)."""

    def __init__(self, inner: Collector, layer: jax.Array | int):
        self.inner = inner
        self.layer = layer

    def tag(self, name: str, value: jax.Array, **meta: Any) -> jax.Array:
        return self.inner.tag(name, value, layer=self.layer, **meta)

    def drain(self) -> dict[str, Any]:
        return self.inner.drain()

    def aux(self) -> dict[str, Any]:
        return self.inner.aux()
