"""Decoder-only LM assembly for all families (dense / moe / rwkv6 / griffin).

Layers are stacked into *segments* (runs of identical repeating structure) and
executed with ``lax.scan`` + per-layer remat, keeping HLO size O(1) in depth:

  dense/moe : [ (first_k_dense dense blocks) ] + [ (moe|dense block) x N ]
  rwkv6     : [ rwkv block x N ]
  griffin   : [ (rec, rec, attn) x N ] + [ remainder blocks ]
"""

from __future__ import annotations

import math
from dataclasses import replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import griffin as gf
from repro.models import rwkv as rk
from repro.models import layers as L
from repro.models.hooks import Collector, LayerScoped, NULL_COLLECTOR
from repro.parallel.sharding import shard_act

# ---------------------------------------------------------------------------
# segment layout
# ---------------------------------------------------------------------------


def maybe_scan(body, carry, xs, n: int, unroll: bool):
    """lax.scan, or an unrolled python loop (cost-probe configs: while-loop
    bodies are counted once by HLO cost analysis, so probes unroll)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    ys_all = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys_all.append(y)
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_all)
    return carry, ys


def segment_layout(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """Returns [(block_kinds_per_group, n_groups), ...] covering all layers."""
    if cfg.family == "dense":
        return [(("dense",), cfg.num_layers)]
    if cfg.family == "moe":
        segs = []
        fk = cfg.moe.first_k_dense
        if fk:
            segs.append((("dense",), fk))
        segs.append((("moe",), cfg.num_layers - fk))
        return segs
    if cfg.family == "rwkv6":
        return [(("rwkv",), cfg.num_layers)]
    if cfg.family == "griffin":
        pat = cfg.griffin.pattern
        n_full, rem = divmod(cfg.num_layers, len(pat))
        segs = []
        if n_full:
            segs.append((pat, n_full))
        if rem:
            segs.append((pat[:rem], 1))
        return segs
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# block init / apply dispatch
# ---------------------------------------------------------------------------


def _block_init(b: L.ParamBuilder, cfg: ModelConfig, kind: str) -> None:
    if kind == "rwkv":
        rk.rwkv_block_init(b, cfg)
        return
    if kind in ("rec", "attn"):
        gf.griffin_block_init(b, cfg, kind)
        return
    L.norm_init(b, "ln1", cfg.d_model, cfg.norm_kind)
    L.norm_init(b, "ln2", cfg.d_model, cfg.norm_kind)
    if cfg.use_mla:
        L.mla_init(b.sub("attn"), cfg)
    else:
        L.gqa_init(b.sub("attn"), cfg)
    if kind == "moe":
        L.moe_init(b.sub("mlp"), cfg)
    else:
        L.mlp_init(b.sub("mlp"), cfg)


def _resid(cfg: ModelConfig, x: jax.Array, delta: jax.Array) -> jax.Array:
    if cfg.scale_depth:
        return x + delta * (cfg.scale_depth / math.sqrt(cfg.num_layers))
    return x + delta


def _block_apply(
    p: dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: dict | None,
    cache_pos: jax.Array | None,
    mrope_position_ids: jax.Array | None,
    paged: Any | None,
    collector: Collector,
) -> tuple[jax.Array, dict | None, dict]:
    # anchor the block input: the constraint's transpose pins the residual
    # *gradient* sharding in backward (GSPMD can otherwise fully replicate it
    # on multi-axis meshes — "involuntary full rematerialization")
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    if kind == "rwkv":
        x, st = rk.rwkv_block_apply(p, cfg, x, state=cache, collector=collector)
        return x, st, {}
    if kind in ("rec", "attn"):
        x, st = gf.griffin_block_apply(
            p, cfg, kind, x,
            positions=positions, state=cache, cache_pos=cache_pos,
            paged=paged, collector=collector,
        )
        return x, st, {}
    aux: dict = {}
    h = L.norm_apply(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = L.mla_apply(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_pos=cache_pos, paged=paged, collector=collector,
        )
    else:
        a, new_cache = L.gqa_apply(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_pos=cache_pos, mrope_position_ids=mrope_position_ids,
            paged=paged, collector=collector,
        )
    x = _resid(cfg, x, collector.tag("att_resid", a))
    h = L.norm_apply(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    if kind == "moe":
        f, aux = L.moe_apply(
            p["mlp"], cfg, h, n_seq_groups=cfg.moe.seq_groups, collector=collector
        )
    else:
        f = L.mlp_apply(p["mlp"], cfg, h, collector)
    x = _resid(cfg, x, collector.tag("ffn_resid", f))
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacked-segment parameter construction
# ---------------------------------------------------------------------------


def _group_init(b: L.ParamBuilder, cfg: ModelConfig, kinds: tuple[str, ...]) -> None:
    for j, kind in enumerate(kinds):
        _block_init(b.sub(f"b{j}"), cfg, kind)


def _prepend_layers_axis(axes_tree: Any) -> Any:
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    return jax.tree.map(
        lambda t: ("layers", *t), axes_tree, is_leaf=is_axes
    )


def init(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    b = L.ParamBuilder(key, dtype)
    L.embed_init(b, cfg)
    L.norm_init(b, "final_norm", cfg.d_model, cfg.norm_kind)
    for i, (kinds, n) in enumerate(segment_layout(cfg)):
        seg_key = b.split()

        def one(k, kinds=kinds):
            gb = L.ParamBuilder(k, dtype)
            _group_init(gb, cfg, kinds)
            return gb.params

        b.params[f"seg{i}"] = jax.vmap(one)(jax.random.split(seg_key, n))
    return b.params


def param_axes(cfg: ModelConfig) -> dict:
    captured: dict = {}

    def run(key):
        b = L.ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        L.embed_init(b, cfg)
        L.norm_init(b, "final_norm", cfg.d_model, cfg.norm_kind)
        captured.update(b.axes)
        return b.params

    jax.eval_shape(run, jax.random.PRNGKey(0))
    for i, (kinds, n) in enumerate(segment_layout(cfg)):
        seg_cap: dict = {}

        def run_g(key, kinds=kinds, seg_cap=seg_cap):
            gb = L.ParamBuilder(key, jnp.dtype(cfg.param_dtype))
            _group_init(gb, cfg, kinds)
            seg_cap.update(gb.axes)
            return gb.params

        jax.eval_shape(run_g, jax.random.PRNGKey(0))
        captured[f"seg{i}"] = _prepend_layers_axis(seg_cap)
    return captured


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _split_cache(tree: Any, flags: Any) -> tuple[Any, Any]:
    """Partition a nested-dict cache by a mirrored bool tree into
    (flagged, unflagged) trees of identical structure with ``None`` at the
    dropped leaf positions (``None`` leaves are empty pytrees, so scan/vmap
    simply skip them)."""
    if isinstance(tree, dict):
        a, b = {}, {}
        for k, v in tree.items():
            a[k], b[k] = _split_cache(v, flags[k])
        return a, b
    return (tree, None) if flags else (None, tree)


def _merge_cache(a: Any, b: Any) -> Any:
    """Inverse of ``_split_cache``: overlay two structurally-identical trees
    with complementary ``None`` leaves."""
    if isinstance(a, dict):
        return {k: _merge_cache(a[k], b[k]) for k in a}
    return a if b is None else b


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict, dtype) -> jax.Array:
    if cfg.input_kind == "tokens":
        return L.embed_apply(params, cfg, batch["tokens"], dtype)
    x = batch["embeds"].astype(dtype)
    if cfg.scale_emb != 1.0:
        x = x * cfg.scale_emb
    return shard_act(x, ("batch", "seq_act", "embed_act"))


def forward(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    cache: dict | None = None,
    cache_pos: jax.Array | None = None,
    paged: Any | None = None,
    paged_flags: Any | None = None,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None, dict]:
    """Returns (hidden [B,S,D], new_cache, aux).

    When ``paged`` (a ``kernels.paged_attention.ops.PagedInfo``) is set, the
    attention leaves of ``cache`` are layer-stacked physical pool arrays
    shared across the batch, ``cache_pos`` is a per-row ``[B]`` vector of
    slot positions, and attention streams K/V blocks via the paged kernel
    instead of a dense cache (see ``serve.engine.make_paged_decode_step``).
    ``paged_flags`` (a bool tree mirroring ``cache``, e.g.
    ``PagedKVCache.paged``) marks which leaves are pools: those ride the
    layer scan's *carry* and are updated in place by layer-indexed scatters —
    scanning them through xs/ys would re-stack the entire pool every decode
    step, turning an O(kv_len) step back into an O(pool) one.  Slot-state
    leaves (rwkv/griffin recurrent state) stay in xs/ys as usual.
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_inputs(cfg, params, batch, dtype)
    B, S, _ = x.shape
    if cache_pos is None:
        positions = jnp.arange(S)
    elif jnp.ndim(cache_pos) == 1:  # per-slot positions (paged decode)
        positions = cache_pos[:, None] + jnp.arange(S)[None, :]
    else:
        positions = cache_pos + jnp.arange(S)
    mrope_ids = batch.get("mrope_position_ids")
    x = collector.tag("embeddings", x)

    aux_losses = jnp.zeros((), jnp.float32)
    aux_metrics: dict[str, jax.Array] = {}
    captures_by_seg: dict[str, dict] = {}
    new_cache: dict = {}
    layer_offset = 0
    for i, (kinds, n) in enumerate(segment_layout(cfg)):
        seg_p = params[f"seg{i}"]
        seg_cache = cache.get(f"seg{i}") if cache is not None else None
        if paged is not None and seg_cache is not None:
            seg_flags = paged_flags[f"seg{i}"]
            seg_pool, seg_state = _split_cache(seg_cache, seg_flags)
        else:
            seg_flags, seg_pool, seg_state = None, None, seg_cache

        def body(carry, xs, kinds=kinds, offset=layer_offset, flags=seg_flags):
            xc, aux_c, pool_c = carry
            layer_p, layer_cache, g = xs
            new_layer_cache = {} if layer_cache is not None else None
            captured = {}
            for j, kind in enumerate(kinds):
                col = LayerScoped(collector, offset + g * len(kinds) + j)
                blk_cache = None if layer_cache is None else layer_cache[f"b{j}"]
                blk_paged = None
                if pool_c is not None:
                    # overlay this block's pool leaves (full stacks from the
                    # carry, addressed at layer g) onto its slot-state slice
                    blk_cache = _merge_cache(pool_c[f"b{j}"], blk_cache)
                    blk_paged = replace(paged, layer=g)
                xc, c_new, aux = _block_apply(
                    layer_p[f"b{j}"], cfg, kind, xc,
                    positions=positions,
                    cache=blk_cache,
                    cache_pos=cache_pos,
                    mrope_position_ids=mrope_ids,
                    paged=blk_paged,
                    collector=col,
                )
                if pool_c is not None and c_new is not None:
                    p_new, c_new = _split_cache(c_new, flags[f"b{j}"])
                    pool_c = {**pool_c, f"b{j}": p_new}
                if new_layer_cache is not None:
                    new_layer_cache[f"b{j}"] = c_new
                if aux:
                    aux_c = aux_c + aux.get("moe_aux_loss", 0.0)
                    captured["moe_drop_frac"] = aux.get("moe_drop_frac", 0.0)
                probes = col.drain()
                if probes:
                    pre = f"b{j}/" if len(kinds) > 1 else ""
                    captured.update({pre + k: v for k, v in probes.items()})
            ys = (new_layer_cache, captured)
            return (xc, aux_c, pool_c), ys

        if cfg.remat != "none":
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)

        xs = (seg_p, seg_state, jnp.arange(n))
        (x, aux_losses, seg_pool), (seg_new_cache, cap) = maybe_scan(
            body, (x, aux_losses, seg_pool), xs, n, cfg.scan_unroll
        )
        if seg_cache is not None:
            new_cache[f"seg{i}"] = (
                _merge_cache(seg_pool, seg_new_cache)
                if seg_pool is not None else seg_new_cache
            )
        if cap:
            if "moe_drop_frac" in cap:
                aux_metrics[f"seg{i}_moe_drop_frac"] = cap["moe_drop_frac"].mean()
            rest = {k: v for k, v in cap.items() if k != "moe_drop_frac"}
            if rest:
                captures_by_seg[f"seg{i}"] = rest
        layer_offset += n * len(kinds)

    x = L.norm_apply(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    x = collector.tag("final_hidden", x)
    aux = {"aux_loss": aux_losses, **aux_metrics}
    top = collector.drain()
    if top or captures_by_seg:
        aux["captures"] = dict(captures_by_seg)
        if top:
            aux["captures"]["top"] = top
    return x, (new_cache if cache is not None else None), aux


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict]:
    hidden, _, aux = forward(cfg, params, batch, collector=collector)
    total, count = L.chunked_xent(
        params, cfg, hidden, batch["targets"], batch.get("loss_mask")
    )
    ce = total / jnp.maximum(count, 1.0)
    loss = ce + aux["aux_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    cache: dict = {}
    for i, (kinds, n) in enumerate(segment_layout(cfg)):
        def one_group(kinds=kinds):
            out = {}
            for j, kind in enumerate(kinds):
                if kind == "rwkv":
                    out[f"b{j}"] = rk.rwkv_init_state(cfg, batch)
                elif kind in ("rec", "attn"):
                    out[f"b{j}"] = gf.griffin_init_state(cfg, kind, batch, cache_len)
                elif cfg.use_mla:
                    m = cfg.mla
                    out[f"b{j}"] = {
                        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), jnp.bfloat16),
                        "kpe": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), jnp.bfloat16),
                    }
                else:
                    out[f"b{j}"] = {
                        "k": jnp.zeros(
                            (batch, cache_len, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16
                        ),
                        "v": jnp.zeros(
                            (batch, cache_len, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16
                        ),
                    }
            return out

        g = one_group()
        cache[f"seg{i}"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (n, *leaf.shape)).copy()
            if hasattr(leaf, "shape")
            else leaf,
            g,
        )
    return cache


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    cache: dict,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[dict, jax.Array]:
    """Run the prompt through the model, filling the cache.  Returns
    (cache, last-position logits [B, V])."""
    hidden, new_cache, _ = forward(
        cfg, params, batch, cache=cache, cache_pos=jnp.int32(0), collector=collector
    )
    logits = L.logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    return new_cache, logits


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # [B] or [B,1] token ids (or [B,1,D] embeds)
    pos: jax.Array,  # scalar int32: current position (number of cached tokens)
    collector: Collector = NULL_COLLECTOR,
) -> tuple[dict, jax.Array]:
    if cfg.input_kind == "tokens":
        tok = tokens.reshape(-1, 1)
        batch = {"tokens": tok}
    else:
        batch = {"embeds": tokens.reshape(tokens.shape[0], 1, -1)}
        if cfg.input_kind == "embeds_mrope":
            B = batch["embeds"].shape[0]
            batch["mrope_position_ids"] = jnp.broadcast_to(
                pos, (3, B, 1)
            ).astype(jnp.int32)
    hidden, new_cache, _ = forward(
        cfg, params, batch, cache=cache, cache_pos=pos, collector=collector
    )
    logits = L.logits_fn(params, cfg, hidden)[:, 0]
    return new_cache, logits
