"""Chunked linear-recurrence utilities (RWKV6 WKV, RG-LRU).

Both recurrences are evaluated with a *two-level* decomposition that keeps the
sequence dimension shardable (context parallelism for linear-attention
models — DESIGN.md §2.2):

  1. intra-chunk: parallel within each chunk (matmul form for the matrix-state
     WKV — MXU friendly; associative scan for the diagonal RG-LRU — exact);
  2. inter-chunk: an associative scan over per-chunk summaries.  The summary
     state is tiny, so when the chunk dim is sharded over the "model" axis the
     cross-device exchange is a few MB — the TPU-native replacement for a
     sequential per-token CUDA kernel.

Numerics: the WKV chunk math uses exponentials of cumulative log-decay
differences.  With chunk size C and per-step log-decay clamped to >= -WKV_CLAMP
the exponent magnitude is bounded by C * WKV_CLAMP < 88 (fp32 exp range).
Channels decaying harder than exp(-WKV_CLAMP) per step are indistinguishable
from zero after two steps; ref.py implements the exact sequential recurrence
and tests bound the approximation error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WKV_CHUNK = 32
WKV_CLAMP = 2.0  # max |log decay| per step used by the chunked path


def wkv6_sequential(
    r: jax.Array,  # [B, S, H, K]
    k: jax.Array,  # [B, S, H, K]
    v: jax.Array,  # [B, S, H, V]
    w: jax.Array,  # [B, S, H, K] decay in (0, 1)
    u: jax.Array,  # [H, K] bonus
    state: jax.Array | None = None,  # [B, H, K, V]
) -> tuple[jax.Array, jax.Array]:
    """Exact per-token recurrence (oracle / decode path).

    y_t = r_t^T (S_t + (u * k_t) v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B, H, K/V]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,K,V]
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, yt

    xs = tuple(
        jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w)
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state  # [B, S, H, V], [B, H, K, V]


def wkv6_chunked(
    r: jax.Array,  # [B, S, H, K]
    k: jax.Array,
    v: jax.Array,  # [B, S, H, V]
    w: jax.Array,  # [B, S, H, K]
    u: jax.Array,  # [H, K]
    state: jax.Array | None = None,  # [B, H, K, V]
    chunk: int = WKV_CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV: matmul-form intra-chunk + associative inter-chunk."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    if S % chunk != 0:
        return wkv6_sequential(r, k, v, w, u, state)
    nc = S // chunk
    C = chunk

    f32 = jnp.float32
    rc = r.reshape(B, nc, C, H, K).astype(f32)
    kc = k.reshape(B, nc, C, H, K).astype(f32)
    vc = v.reshape(B, nc, C, H, V).astype(f32)
    lw = jnp.clip(jnp.log(w.reshape(B, nc, C, H, K).astype(f32)), -WKV_CLAMP, -1e-6)
    cum = jnp.cumsum(lw, axis=2)  # inclusive cumulative log decay  [B,nc,C,H,K]
    cum_prev = cum - lw  # exclusive

    qp = rc * jnp.exp(cum_prev)  # decayed queries
    kp = kc * jnp.exp(-cum)      # inverse-decayed keys

    # intra-chunk pair contributions (strictly lower triangular) + diagonal u
    scores = jnp.einsum("bnihk,bnjhk->bnhij", qp, kp)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bnihk,hk,bnihk->bnhi", rc, u.astype(f32), kc)
    y_intra = jnp.einsum("bnhij,bnjhv->bnihv", scores, vc)
    y_intra = y_intra + diag[..., None].transpose(0, 1, 3, 2, 4) * vc

    # chunk summaries: total decay + decayed key-value outer products
    a_chunk = jnp.exp(cum[:, :, -1])  # [B,nc,H,K]
    k_dec = kc * jnp.exp(cum[:, :, -1:, :, :] - cum)  # decay from pos to chunk end
    m_chunk = jnp.einsum("bnjhk,bnjhv->bnhkv", k_dec, vc)  # [B,nc,H,K,V]

    # inter-chunk associative scan: (a, M) o (a', M') = (a*a', a'[:,None]*M + M')
    def combine(x, y):
        ax, mx = x
        ay, my = y
        return ax * ay, ay[..., None] * mx + my

    a_in, m_in = jax.lax.associative_scan(combine, (a_chunk, m_chunk), axis=1)
    # exclusive: state entering chunk n (shift right, seed with initial state)
    s0 = state.astype(f32) if state is not None else jnp.zeros((B, H, K, V), f32)
    a_ex = jnp.concatenate(
        [jnp.ones((B, 1, H, K), f32), a_in[:, :-1]], axis=1
    )
    m_ex = jnp.concatenate([jnp.zeros((B, 1, H, K, V), f32), m_in[:, :-1]], axis=1)
    s_in = a_ex[..., None] * s0[:, None] + m_ex  # [B,nc,H,K,V]

    y_carry = jnp.einsum("bnihk,bnhkv->bnihv", qp, s_in)
    y = (y_intra + y_carry).reshape(B, S, H, V)
    final_state = a_in[:, -1, ..., None] * s0 + m_in[:, -1]
    return y, final_state


def lru_scan(
    a: jax.Array,  # [B, S, W] per-step decay in (0,1)
    b: jax.Array,  # [B, S, W] per-step input
    h0: jax.Array | None = None,  # [B, W]
) -> tuple[jax.Array, jax.Array]:
    """Exact diagonal linear recurrence h_t = a_t h_{t-1} + b_t via two-level
    associative scans (chunk dim shardable).  Returns (h [B,S,W], h_last)."""
    B, S, W = a.shape
    f32 = jnp.float32
    a = a.astype(f32)
    b = b.astype(f32)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    chunk = 128 if S % 128 == 0 else (S if S < 128 else 1)
    if chunk > 1 and S % chunk == 0:
        nc = S // chunk
        ac = a.reshape(B, nc, chunk, W)
        bc = b.reshape(B, nc, chunk, W)
        a_c, h_c = jax.lax.associative_scan(combine, (ac, bc), axis=2)
        a_sum, h_sum = a_c[:, :, -1], h_c[:, :, -1]  # [B,nc,W]
        a_in, h_in = jax.lax.associative_scan(combine, (a_sum, h_sum), axis=1)
        a_ex = jnp.concatenate([jnp.ones((B, 1, W), f32), a_in[:, :-1]], axis=1)
        h_ex = jnp.concatenate([jnp.zeros((B, 1, W), f32), h_in[:, :-1]], axis=1)
        if h0 is not None:
            h_ex = h_ex + a_ex * h0[:, None].astype(f32)
        h = h_c + a_c * h_ex[:, :, None]
        h = h.reshape(B, S, W)
    else:
        a_in, h_in = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = h_in
        if h0 is not None:
            h = h + a_in * h0[:, None].astype(f32)
    return h, h[:, -1]


def shift_tokens(
    x: jax.Array, prev: jax.Array | None = None, n_chunks: int = 16
) -> jax.Array:
    """x_{t-1} stream: [B,S,D] -> [B,S,D]; position 0 sees ``prev`` (or zeros).

    Sharding-aware: a plain concat/slice over a sequence dim sharded for
    context parallelism makes GSPMD gather the full sequence per layer.
    Instead the shift is done within shard-aligned chunks plus a halo exchange
    of the single boundary column ([B, nc, D] — a few MB)."""
    from repro.parallel.sharding import shard_act

    B, S, D = x.shape
    first = (
        prev[:, None].astype(x.dtype)
        if prev is not None
        else jnp.zeros((B, 1, D), x.dtype)
    )
    if S % n_chunks != 0 or n_chunks <= 1 or S == 1:
        return jnp.concatenate([first, x[:, :-1]], axis=1)
    C = S // n_chunks
    x4 = shard_act(x.reshape(B, n_chunks, C, D), ("batch", "seq_act", None, "embed_act"))
    bound = x4[:, :, -1, :]                      # [B, nc, D] last token per chunk
    bound_prev = jnp.concatenate([first, bound[:, :-1, :]], axis=1)  # halo
    shifted = jnp.concatenate([bound_prev[:, :, None, :], x4[:, :, :-1, :]], axis=2)
    shifted = shard_act(shifted, ("batch", "seq_act", None, "embed_act"))
    return shifted.reshape(B, S, D)


def causal_conv1d(
    x: jax.Array,  # [B, S, W]
    weight: jax.Array,  # [width, W] depthwise taps (tap 0 = current token)
    bias: jax.Array | None = None,  # [W]
    prev: jax.Array | None = None,  # [B, width-1, W] carry-in context
    n_chunks: int = 16,
) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via repeated 1-token halo shifts (sharding-aware
    like shift_tokens); returns (y, new_prev)."""
    B, S, W = x.shape
    width = weight.shape[0]
    ctx = (
        prev.astype(x.dtype)
        if prev is not None
        else jnp.zeros((B, width - 1, W), x.dtype)
    )
    y = weight[0].astype(x.dtype) * x
    shifted = x
    for i in range(1, width):
        prev_col = ctx[:, width - 1 - i, :]  # x_{-i} for the first position
        shifted = shift_tokens(shifted, prev_col, n_chunks)
        y = y + weight[i].astype(x.dtype) * shifted
    if bias is not None:
        y = y + bias.astype(x.dtype)
    if S >= width - 1 and width > 1:
        new_prev = x[:, S - (width - 1):, :]
    else:
        new_prev = jnp.concatenate([ctx, x], axis=1)[:, -(width - 1):, :]
    return y, new_prev
