"""Griffin / RecurrentGemma blocks: RG-LRU recurrent mix + local attention.

Follows arXiv:2402.19427: the temporal-mixing layer alternates in a
(rec, rec, attn) pattern.  A recurrent block is
``(gelu gate) * rglru(conv1d(linear(x)))`` with the RG-LRU
``h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)``,
``a_t = exp(-c * softplus(Lambda) * r_t)``.  Attention blocks use MQA over a
sliding window.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.models.layers import (
    ParamBuilder,
    gqa_apply,
    gqa_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
)
from repro.models.scan_utils import causal_conv1d, lru_scan
from repro.parallel.sharding import shard_act


def rglru_init(b: ParamBuilder, cfg: ModelConfig):
    W = cfg.lru_width
    b.param("w_a", (W, W), ("embed_w", "qkv"), fan_in=W)
    b.param("b_a", (W,), ("qkv",), init="zeros")
    b.param("w_i", (W, W), ("embed_w", "qkv"), fan_in=W)
    b.param("b_i", (W,), ("qkv",), init="zeros")
    # Lambda init so that softplus gives decay in a useful range (Griffin A.2)
    b.param("lam", (W,), ("qkv",), init="uniform", scale=1.0)


def rglru_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, W]
    h0: jax.Array | None = None,  # [B, W]
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, jax.Array]:
    c = cfg.griffin.c
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x, p["w_a"].astype(x.dtype)) + p["b_a"].astype(x.dtype)
    ).astype(jnp.float32)
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x, p["w_i"].astype(x.dtype)) + p["b_i"].astype(x.dtype)
    )
    log_a = -c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r  # [B,S,W] <= 0
    a = jnp.exp(log_a)
    a = collector.tag("rglru_decay", a)
    # input normalization sqrt(1 - a^2), computed stably
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b_in = beta * (i * x).astype(jnp.float32)
    if cfg.kernels_impl != "xla" and h0 is None and x.shape[1] > 1:
        from repro.kernels.rglru.ops import rglru_scan

        h, h_last = rglru_scan(a, b_in, impl=cfg.kernels_impl)
    else:
        h, h_last = lru_scan(a, b_in, h0)
    return h.astype(x.dtype), h_last


def recurrent_block_init(b: ParamBuilder, cfg: ModelConfig):
    D, W = cfg.d_model, cfg.lru_width
    cw = cfg.griffin.conv_width
    b.param("w_gate", (D, W), ("embed_w", "qkv"), fan_in=D)
    b.param("w_x", (D, W), ("embed_w", "qkv"), fan_in=D)
    b.param("conv_w", (cw, W), ("conv", "qkv"), init="normal", fan_in=cw)
    b.param("conv_b", (W,), ("qkv",), init="zeros")
    rglru_init(b.sub("rglru"), cfg)
    b.param("w_out", (W, D), ("qkv", "embed_w"), fan_in=W,
            scale=1.0 / math.sqrt(2 * cfg.num_layers))


def recurrent_block_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    state: dict | None = None,  # {"conv": [B, cw-1, W], "h": [B, W]}
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype)))
    y = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    y = shard_act(y, ("batch", "seq_act", "mlp_act"))
    conv_prev = state["conv"] if state is not None else None
    y, conv_new = causal_conv1d(y, p["conv_w"], p["conv_b"], conv_prev)
    h0 = state["h"] if state is not None else None
    y, h_last = rglru_apply(p["rglru"], cfg, y, h0, collector)
    y = collector.tag("rglru_out", y)
    out = jnp.einsum("bsw,wd->bsd", gate * y, p["w_out"].astype(x.dtype))
    new_state = None
    if state is not None:
        new_state = {"conv": conv_new, "h": h_last}
    return out, new_state


def griffin_block_init(b: ParamBuilder, cfg: ModelConfig, kind: str):
    norm_init(b, "ln1", cfg.d_model, cfg.norm_kind)
    norm_init(b, "ln2", cfg.d_model, cfg.norm_kind)
    if kind == "rec":
        recurrent_block_init(b.sub("mix"), cfg)
    else:
        gqa_init(b.sub("mix"), cfg, window=cfg.griffin.window)
    mlp_init(b.sub("mlp"), cfg)


def griffin_block_apply(
    p: dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    *,
    positions: jax.Array,
    state: dict | None = None,
    cache_pos: jax.Array | None = None,
    paged=None,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    h = norm_apply(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    if kind == "rec":
        a, new_state = recurrent_block_apply(
            p["mix"], cfg, h, state=state, collector=collector
        )
    else:
        a, new_state = gqa_apply(
            p["mix"], cfg, h,
            positions=positions,
            window=cfg.griffin.window,
            cache=state,
            cache_pos=cache_pos,
            paged=paged,
            collector=collector,
        )
    x = x + collector.tag("att_resid", a)
    h = norm_apply(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    f = mlp_apply(p["mlp"], cfg, h, collector)
    x = x + collector.tag("ffn_resid", f)
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    return x, new_state


def griffin_init_state(cfg: ModelConfig, kind: str, batch: int, cache_len: int) -> dict:
    if kind == "rec":
        return {
            "conv": jnp.zeros((batch, cfg.griffin.conv_width - 1, cfg.lru_width), jnp.float32),
            "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        }
    # Full-length linear cache; the window mask limits attention reach.  (A
    # ring buffer would cap memory at `window`; linear layout keeps the
    # GSPMD-sharded time dim simple and the T-sharding already divides it.)
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16),
    }
