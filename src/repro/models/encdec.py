"""Encoder-decoder LM (seamless-m4t family).

Encoder consumes precomputed modality embeddings (the audio frontend stub per
the assignment); decoder is a causal LM with cross-attention to encoder memory.
Serving caches: growing self-attention KV + static cross-attention KV computed
once from the encoder output at prefill.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.hooks import Collector, LayerScoped, NULL_COLLECTOR
from repro.parallel.sharding import shard_act


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------


def cross_attn_init(b: L.ParamBuilder, cfg: ModelConfig):
    D, H, K, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b.param("wq", (D, H, dh), ("embed_w", "heads_w", "head_dim_w"), fan_in=D)
    b.param("wk", (D, K, dh), ("embed_w", "kv_heads_w", "head_dim_w"), fan_in=D)
    b.param("wv", (D, K, dh), ("embed_w", "kv_heads_w", "head_dim_w"), fan_in=D)
    b.param("wo", (H, dh, D), ("heads_w", "head_dim_w", "embed_w"),
            fan_in=H * dh, scale=1.0 / math.sqrt(2 * cfg.num_layers))


def cross_kv(p: dict, cfg: ModelConfig, memory: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"].astype(memory.dtype))
    k = shard_act(k, ("batch", "kv_time", "kv_heads_act", "head_dim_act"))
    v = shard_act(v, ("batch", "kv_time", "kv_heads_act", "head_dim_act"))
    return k, v


def cross_attn_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D] decoder stream
    kv: tuple[jax.Array, jax.Array],  # precomputed memory K/V [B, T, K, dh]
    collector: Collector = NULL_COLLECTOR,
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = shard_act(q, ("batch", "seq", "heads_act", "head_dim_act"))
    k, v = kv
    S = x.shape[1]
    o = L.attention(
        q, k.astype(x.dtype), v.astype(x.dtype),
        scale=1.0 / math.sqrt(cfg.head_dim),
        positions_q=jnp.zeros((S,), jnp.int32),
        causal=False,
        impl=cfg.attn_impl,
        kv_chunk=cfg.attn_kv_chunk,
        collector=collector,
    )
    o = collector.tag("cross_attn_out", o)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def enc_block_init(b: L.ParamBuilder, cfg: ModelConfig):
    L.norm_init(b, "ln1", cfg.d_model, cfg.norm_kind)
    L.norm_init(b, "ln2", cfg.d_model, cfg.norm_kind)
    L.gqa_init(b.sub("attn"), cfg)
    L.mlp_init(b.sub("mlp"), cfg)


def enc_block_apply(p, cfg, x, *, positions, collector):
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    h = L.norm_apply(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    a, _ = L.gqa_apply(p["attn"], cfg, h, positions=positions, causal=False,
                       collector=collector)
    x = x + collector.tag("att_resid", a)
    h = L.norm_apply(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    x = x + collector.tag("ffn_resid", L.mlp_apply(p["mlp"], cfg, h, collector))
    return shard_act(x, ("batch", "seq_act", "embed_act"))


def dec_block_init(b: L.ParamBuilder, cfg: ModelConfig):
    L.norm_init(b, "ln1", cfg.d_model, cfg.norm_kind)
    L.norm_init(b, "ln_cross", cfg.d_model, cfg.norm_kind)
    L.norm_init(b, "ln2", cfg.d_model, cfg.norm_kind)
    L.gqa_init(b.sub("attn"), cfg)
    cross_attn_init(b.sub("cross"), cfg)
    L.mlp_init(b.sub("mlp"), cfg)


def dec_block_apply(
    p, cfg, x, *, positions, mem_kv, cache=None, cache_pos=None, collector
):
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    h = L.norm_apply(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    a, new_self = L.gqa_apply(
        p["attn"], cfg, h, positions=positions, cache=self_cache,
        cache_pos=cache_pos, collector=collector,
    )
    x = x + collector.tag("att_resid", a)
    h = L.norm_apply(p["ln_cross"], x, cfg.norm_kind, cfg.norm_eps)
    x = x + cross_attn_apply(p["cross"], cfg, h, mem_kv, collector)
    h = L.norm_apply(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    x = x + collector.tag("ffn_resid", L.mlp_apply(p["mlp"], cfg, h, collector))
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    new_cache = None
    if cache is not None:
        new_cache = {**new_self, "ck": cache["ck"], "cv": cache["cv"]}
    return x, new_cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    b = L.ParamBuilder(key, dtype)
    L.embed_init(b, cfg)
    L.norm_init(b, "enc_final_norm", cfg.d_model, cfg.norm_kind)
    L.norm_init(b, "final_norm", cfg.d_model, cfg.norm_kind)

    def one_enc(k):
        gb = L.ParamBuilder(k, dtype)
        enc_block_init(gb, cfg)
        return gb.params

    def one_dec(k):
        gb = L.ParamBuilder(k, dtype)
        dec_block_init(gb, cfg)
        return gb.params

    b.params["encoder"] = jax.vmap(one_enc)(
        jax.random.split(b.split(), cfg.num_encoder_layers)
    )
    b.params["decoder"] = jax.vmap(one_dec)(
        jax.random.split(b.split(), cfg.num_layers)
    )
    return b.params


def param_axes(cfg: ModelConfig) -> dict:
    captured: dict = {}

    def run_top(key):
        b = L.ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        L.embed_init(b, cfg)
        L.norm_init(b, "enc_final_norm", cfg.d_model, cfg.norm_kind)
        L.norm_init(b, "final_norm", cfg.d_model, cfg.norm_kind)
        captured.update(b.axes)
        return b.params

    jax.eval_shape(run_top, jax.random.PRNGKey(0))
    from repro.models.lm import _prepend_layers_axis

    for name, init_fn in (("encoder", enc_block_init), ("decoder", dec_block_init)):
        cap: dict = {}

        def run(key, init_fn=init_fn, cap=cap):
            gb = L.ParamBuilder(key, jnp.dtype(cfg.param_dtype))
            init_fn(gb, cfg)
            cap.update(gb.axes)
            return gb.params

        jax.eval_shape(run, jax.random.PRNGKey(0))
        captured[name] = _prepend_layers_axis(cap)
    return captured


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def encode(cfg, params, embeds, collector=NULL_COLLECTOR):
    x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, xs):
        lp, g = xs
        col = LayerScoped(collector, g)
        return enc_block_apply(lp, cfg, carry, positions=positions, collector=col), None

    body = _maybe_remat(cfg, body)
    from repro.models.lm import maybe_scan

    x, _ = maybe_scan(
        body, x, (params["encoder"], jnp.arange(cfg.num_encoder_layers)),
        cfg.num_encoder_layers, cfg.scan_unroll,
    )
    return L.norm_apply(params["enc_final_norm"], x, cfg.norm_kind, cfg.norm_eps)


def _decode_stack(cfg, params, x, memory, *, cache=None, cache_pos=None,
                  collector=NULL_COLLECTOR):
    B, S, _ = x.shape
    positions = jnp.arange(S) if cache_pos is None else cache_pos + jnp.arange(S)

    def body(carry, xs):
        lp, layer_cache, g = xs
        col = LayerScoped(collector, g)
        if layer_cache is not None:
            mem_kv = (layer_cache["ck"], layer_cache["cv"])
        else:
            mem_kv = cross_kv(lp["cross"], cfg, memory)
        xc, new_cache = dec_block_apply(
            lp, cfg, carry, positions=positions, mem_kv=mem_kv,
            cache=layer_cache, cache_pos=cache_pos, collector=col,
        )
        return xc, new_cache

    body = _maybe_remat(cfg, body)
    from repro.models.lm import maybe_scan

    x, new_cache = maybe_scan(
        body, x, (params["decoder"], cache, jnp.arange(cfg.num_layers)),
        cfg.num_layers, cfg.scan_unroll,
    )
    x = L.norm_apply(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    return x, (new_cache if cache is not None else None)


def loss_fn(cfg, params, batch, collector=NULL_COLLECTOR):
    memory = encode(cfg, params, batch["embeds"], collector)
    dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed_apply(params, cfg, batch["tokens"], dtype)
    hidden, _ = _decode_stack(cfg, params, x, memory, collector=collector)
    total, count = L.chunked_xent(
        params, cfg, hidden, batch["targets"], batch.get("loss_mask")
    )
    ce = total / jnp.maximum(count, 1.0)
    return ce, {"loss": ce, "ce": ce, "aux_loss": jnp.zeros((), jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, src_len: int) -> dict:
    L_dec = cfg.num_layers
    K, dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L_dec, batch, cache_len, K, dh), jnp.bfloat16),
        "v": jnp.zeros((L_dec, batch, cache_len, K, dh), jnp.bfloat16),
        "ck": jnp.zeros((L_dec, batch, src_len, K, dh), jnp.bfloat16),
        "cv": jnp.zeros((L_dec, batch, src_len, K, dh), jnp.bfloat16),
    }


def prefill(cfg, params, batch, cache, collector=NULL_COLLECTOR):
    """Encode source embeddings, fill cross-KV, prefill decoder self-KV over
    the target prompt.  Returns (cache, last logits [B, V])."""
    memory = encode(cfg, params, batch["embeds"], collector)

    # fill static cross-attention caches per layer
    def fill(lp):
        k, v = cross_kv(lp["cross"], cfg, memory)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    ck, cv = jax.vmap(fill)(params["decoder"])
    cache = {**cache, "ck": ck, "cv": cv}

    dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed_apply(params, cfg, batch["tokens"], dtype)
    hidden, new_cache = _decode_stack(
        cfg, params, x, memory, cache=cache, cache_pos=jnp.int32(0),
        collector=collector,
    )
    logits = L.logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    return new_cache, logits


def decode_step(cfg, params, cache, tokens, pos, collector=NULL_COLLECTOR):
    dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed_apply(params, cfg, tokens.reshape(-1, 1), dtype)
    hidden, new_cache = _decode_stack(
        cfg, params, x, None, cache=cache, cache_pos=pos, collector=collector,
    )
    logits = L.logits_fn(params, cfg, hidden)[:, 0]
    return new_cache, logits
