"""Core transformer building blocks (pure JAX, logical-axis annotated).

Sharding conventions (see repro/parallel/profiles.py for the rule tables):

Weight logical axes (suffix ``_w``): ``embed_w`` (FSDP dim), ``heads_w`` /
``kv_heads_w`` / ``head_dim_w`` / ``mlp_w`` / ``vocab_w`` / ``expert_w`` /
``kv_lora_w`` — tensor-parallel dims with size-aware fallback (e.g. 40 heads on
a 16-way axis falls through to sharding ``head_dim_w``).

Activation logical axes: ``batch``, ``seq_act`` (residual stream; sharded over
"model" in the context-parallel profile), ``seq`` (query positions inside
attention), ``seq_kv`` (gathered key/value positions), ``heads_act``,
``mlp_act``, ``kv_time`` (decode cache time dim), ``vocab_act`` (logit chunks),
``ce_batch`` (cross-entropy batch).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.parallel.sharding import shard_act

BIG_NEG = -1e30


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Builds a params pytree and its mirrored logical-axes pytree in lockstep."""

    def __init__(self, key: jax.Array, dtype: Any = jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def split(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple[str | None, ...],
        init: str = "normal",
        fan_in: int | None = None,
        scale: float = 1.0,
        fill: float = 0.0,
    ) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "normal":
            fi = fan_in if fan_in is not None else shape[0]
            std = scale / math.sqrt(max(fi, 1))
            val = jax.random.normal(self.split(), shape, self.dtype) * jnp.asarray(
                std, self.dtype
            )
        elif init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "const":
            val = jnp.full(shape, fill, self.dtype)
        elif init == "uniform":
            val = jax.random.uniform(
                self.split(), shape, self.dtype, minval=-scale, maxval=scale
            )
        else:
            raise ValueError(init)
        self.params[name] = val
        self.axes[name] = axes

    def sub(self, name: str) -> "ParamBuilder":
        child = ParamBuilder(self.split(), self.dtype)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def done(self) -> tuple[dict, dict]:
        return self.params, self.axes


def cast(p, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, p)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(b: ParamBuilder, name: str, dim: int, kind: str, axis_name: str = "embed_w"):
    s = b.sub(name)
    s.param("scale", (dim,), (axis_name,), init="ones")
    if kind == "layernorm":
        s.param("bias", (dim,), (axis_name,), init="zeros")


def norm_apply(p: dict, x: jax.Array, kind: str, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head-dim RMSNorm (qwen3 qk_norm): x [..., dh], scale [dh]."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (1-D and multimodal 3-D)
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, H, D] or [B, S, D]; positions [S] shared or [B, S] per-row
    absolute positions (the paged decode step carries one position per slot)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [(B,) S, d/2]
    if x.ndim == 4:  # head dim present: [B, S, H, D]
        ang = ang[..., None, :]  # [(B,) S, 1, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, position_ids: jax.Array, sections: tuple[int, ...], theta: float
) -> jax.Array:
    """M-RoPE: x [B, S, H, D]; position_ids [3, B, S]; sections sum to D/2."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    # Build per-frequency position selection: frequencies are split into
    # (t, h, w) sections; each section rotates with its own position stream.
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # [d/2]
    pos = position_ids.astype(jnp.float32)  # [3, B, S]
    # [B, S, d/2]: pick position component per frequency
    pos_sel = jnp.take(pos, sec_id, axis=0)  # [d/2, B, S] -> want [B,S,d/2]
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)
    ang = pos_sel * freqs  # [B, S, d/2]
    ang = ang[:, :, None, :]  # heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (online-softmax chunked / local-block / decode / naive)
# ---------------------------------------------------------------------------


def _mask(
    pq: jax.Array,  # [S] or [B,S] query absolute positions
    pk: jax.Array,  # [C] key absolute positions
    causal: bool,
    window: int | None,
    kv_len: jax.Array | None,  # scalar or [B]
) -> jax.Array:
    """Returns boolean mask broadcastable to [B?, S, C]: True = attend."""
    q = pq[..., :, None]
    k = pk[None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if kl.ndim == 1:  # per-batch
            m = m & (k < kl[:, None, None])
        else:
            m = m & (k < kl)
    return m


def attention(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, K, D]
    v: jax.Array,  # [B, T, K, D]
    *,
    scale: float,
    positions_q: jax.Array,  # [S] absolute positions of queries
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | None = None,
    impl: str = "chunked",
    kv_chunk: int = 1024,
    paged: Any | None = None,  # kernels.paged_attention.ops.PagedInfo
    collector: Collector = NULL_COLLECTOR,
) -> jax.Array:
    if paged is not None:
        # paged-KV decode: k/v are the *physical block pool* ``[num_blocks,
        # bs, K, D]`` and the kernel walks ``paged.tables`` instead of a
        # gathered dense view — S == 1, per-slot ``kv_len`` masks dead
        # positions.  (No ``attn_probs`` tag on this path: probabilities
        # never materialize outside the kernel.)
        from repro.kernels.paged_attention.ops import paged_attention

        return paged_attention(
            q, k, v, tables=paged.tables, kv_len=kv_len, scale=scale,
            window=window, impl=paged.impl, layer=paged.layer,
        )

    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    qg = q.reshape(B, S, K, G, D)

    if S == 1 or impl == "naive" or T <= kv_chunk:
        s = jnp.einsum(
            "bskgd,btkd->bskgt", qg, k, preferred_element_type=jnp.float32
        ) * scale
        m = _mask(positions_q, jnp.arange(T), causal, window, kv_len)
        m = m.reshape((B if m.ndim == 3 else 1), S, 1, 1, T)
        s = jnp.where(m, s, BIG_NEG)
        p = jax.nn.softmax(s, axis=-1)
        p = collector.tag("attn_probs", p)
        o = jnp.einsum(
            "bskgt,btkd->bskgd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return o.reshape(B, S, H, Dv).astype(q.dtype)

    if impl == "local_block" and window is not None and S == T and S % window == 0:
        return _local_block_attention(
            qg, k, v, scale=scale, window=window, collector=collector
        ).reshape(B, S, H, Dv).astype(q.dtype)

    if impl in ("pallas", "pallas_interpret") and kv_len is None:
        from repro.kernels.flash_attention.ops import flash_attention as fa

        return fa(q, k, v, scale=scale, causal=causal, window=window, impl=impl)

    # flash path: chunked online-softmax with a custom VJP that recomputes
    # scores in the backward pass (nothing quadratic is saved for bwd)
    pad = (-T) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_len_arr = jnp.asarray(T if kv_len is None else kv_len, jnp.int32)
    f = _make_flash(float(scale), bool(causal), window, int(kv_chunk))
    o = f(qg, k, v, jnp.asarray(positions_q), kv_len_arr)
    return o.reshape(B, S, H, Dv).astype(q.dtype)


def _chunk_mask(positions_q, i, kv_chunk, causal, window, kv_len, B, S):
    pk = i * kv_chunk + jnp.arange(kv_chunk)
    msk = _mask(positions_q, pk, causal, window, kv_len)
    return msk.reshape((B if msk.ndim == 3 else 1), S, 1, 1, kv_chunk)


def _flash_forward(qg, k, v, pq, kv_len, scale, causal, window, kv_chunk):
    B, S, K, G, D = qg.shape
    Dv = v.shape[-1]
    nc = k.shape[1] // kv_chunk
    kc = jnp.moveaxis(k.reshape(B, nc, kv_chunk, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, kv_chunk, K, Dv), 1, 0)

    def body(carry, inp):
        m_r, l_r, o_r = carry
        i, kb, vb = inp
        s = jnp.einsum(
            "bskgd,bckd->bskgc", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        msk = _chunk_mask(pq, i, kv_chunk, causal, window, kv_len, B, S)
        s = jnp.where(msk, s, BIG_NEG)
        m_new = jnp.maximum(m_r, s.max(-1))
        corr = jnp.exp(m_r - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_r * corr + p.sum(-1)
        o_new = o_r * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, o_new), None

    _q_axes = ("batch", "seq", "kv_heads_act", "heads_act", "head_dim_act")
    m0 = shard_act(jnp.full((B, S, K, G), BIG_NEG, jnp.float32), _q_axes[:-1])
    l0 = shard_act(jnp.zeros((B, S, K, G), jnp.float32), _q_axes[:-1])
    o0 = shard_act(jnp.zeros((B, S, K, G, Dv), jnp.float32), _q_axes)
    (m_f, l_f, o_f), _ = jax.lax.scan(body, (m0, l0, o0), (jnp.arange(nc), kc, vc))
    o = o_f / jnp.where(l_f[..., None] == 0, 1.0, l_f[..., None])
    lse = jnp.where(l_f == 0, 0.0, m_f + jnp.log(jnp.maximum(l_f, 1e-30)))
    return o, lse


import functools


@functools.lru_cache(maxsize=None)
def _make_flash(scale: float, causal: bool, window: int | None, kv_chunk: int):
    @jax.custom_vjp
    def flash(qg, k, v, pq, kv_len):
        o, _ = _flash_forward(qg, k, v, pq, kv_len, scale, causal, window, kv_chunk)
        return o

    def fwd(qg, k, v, pq, kv_len):
        o, lse = _flash_forward(qg, k, v, pq, kv_len, scale, causal, window, kv_chunk)
        return o, (qg, k, v, pq, kv_len, o, lse)

    def bwd(res, do):
        qg, k, v, pq, kv_len, o, lse = res
        B, S, K, G, D = qg.shape
        Dv = v.shape[-1]
        nc = k.shape[1] // kv_chunk
        kc = jnp.moveaxis(k.reshape(B, nc, kv_chunk, K, D), 1, 0)
        vc = jnp.moveaxis(v.reshape(B, nc, kv_chunk, K, Dv), 1, 0)
        # pin the cotangent to the forward activation sharding — without an
        # anchor GSPMD can lose it on multi-axis meshes and fall back to
        # "involuntary full rematerialization" (full replication)
        _q_axes = ("batch", "seq", "kv_heads_act", "heads_act", "head_dim_act")
        do = shard_act(do.astype(jnp.float32), _q_axes)
        delta = shard_act((do * o).sum(-1), _q_axes[:-1])  # [B,S,K,G]

        do_b = do.astype(k.dtype)

        def body(dq, inp):
            # matmul operands stay bf16 (f32 accumulation via preferred) —
            # keeping them f32 makes XLA hoist converts before the KV gathers,
            # doubling gather bytes
            i, kb, vb = inp
            s = jnp.einsum(
                "bskgd,bckd->bskgc", qg, kb, preferred_element_type=jnp.float32
            ) * scale
            msk = _chunk_mask(pq, i, kv_chunk, causal, window, kv_len, B, S)
            p = jnp.where(msk, jnp.exp(s - lse[..., None]), 0.0)
            p_b = p.astype(k.dtype)
            dv_c = jnp.einsum("bskgc,bskgv->bckv", p_b, do_b,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bskgv,bckv->bskgc", do_b, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta[..., None]) * scale
            ds_b = ds.astype(k.dtype)
            dq = dq + jnp.einsum("bskgc,bckd->bskgd", ds_b, kb,
                                 preferred_element_type=jnp.float32)
            dq = shard_act(dq, _q_axes)
            dk_c = jnp.einsum("bskgc,bskgd->bckd", ds_b, qg,
                              preferred_element_type=jnp.float32)
            return dq, (dk_c.astype(k.dtype), dv_c.astype(v.dtype))

        dq0 = shard_act(jnp.zeros((B, S, K, G, D), jnp.float32), _q_axes)
        dq, (dk_s, dv_s) = jax.lax.scan(body, dq0, (jnp.arange(nc), kc, vc))
        dk = jnp.moveaxis(dk_s, 0, 1).reshape(B, nc * kv_chunk, K, D)
        dv = jnp.moveaxis(dv_s, 0, 1).reshape(B, nc * kv_chunk, K, Dv)
        return (
            dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None,
        )

    flash.defvjp(fwd, bwd)
    return flash


def _local_block_attention(
    qg: jax.Array,  # [B, S, K, G, D]
    k: jax.Array,  # [B, S, K, D]
    v: jax.Array,
    *,
    scale: float,
    window: int,
    collector: Collector = NULL_COLLECTOR,
) -> jax.Array:
    """Banded local attention: each W-block of queries attends to its own and
    the previous key block — linear cost in S (vs masked-quadratic chunked)."""
    B, S, K, G, D = qg.shape
    Dv = v.shape[-1]
    W = window
    nb = S // W
    qb = qg.reshape(B, nb, W, K, G, D)
    kb = k.reshape(B, nb, W, K, D)
    vb = v.reshape(B, nb, W, K, Dv)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kprev, kb], axis=2)  # [B, nb, 2W, K, D]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    s = jnp.einsum(
        "bnwkgd,bnckd->bnwkgc", qb, k2, preferred_element_type=jnp.float32
    ) * scale
    # positions within the 2W strip: query i (at W+i), key j; attend iff
    # j <= W+i and j > i (window) and (block>0 or j >= W)
    i = jnp.arange(W)[:, None]
    j = jnp.arange(2 * W)[None, :]
    base = (j <= W + i) & (j > i)
    first = base & (j >= W)
    blk = jnp.arange(nb)[:, None, None]
    msk = jnp.where(blk > 0, base[None], first[None])  # [nb, W, 2W]
    s = jnp.where(msk[None, :, :, None, None, :], s, BIG_NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bnwkgc,bnckd->bnwkgd", p.astype(v2.dtype), v2,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, S, K, G, Dv)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache)
# ---------------------------------------------------------------------------


def gqa_init(b: ParamBuilder, cfg: ModelConfig, window: int | None = None):
    D, H, K, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b.param("wq", (D, H, dh), ("embed_w", "heads_w", "head_dim_w"), fan_in=D)
    b.param("wk", (D, K, dh), ("embed_w", "kv_heads_w", "head_dim_w"), fan_in=D)
    b.param("wv", (D, K, dh), ("embed_w", "kv_heads_w", "head_dim_w"), fan_in=D)
    b.param("wo", (H, dh, D), ("heads_w", "head_dim_w", "embed_w"),
            fan_in=H * dh, scale=1.0 / math.sqrt(2 * cfg.num_layers))
    if cfg.qkv_bias:
        b.param("bq", (H, dh), ("heads_w", "head_dim_w"), init="zeros")
        b.param("bk", (K, dh), ("kv_heads_w", "head_dim_w"), init="zeros")
        b.param("bv", (K, dh), ("kv_heads_w", "head_dim_w"), init="zeros")
    if cfg.qk_norm:
        b.param("q_norm", (dh,), ("head_dim_w",), init="ones")
        b.param("k_norm", (dh,), ("head_dim_w",), init="ones")


def gqa_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    positions: jax.Array,  # [S] or [B,S] absolute positions
    window: int | None = None,
    causal: bool = True,
    cache: dict | None = None,  # {"k","v"} [B, T, K, dh] ring/linear cache
    cache_pos: jax.Array | None = None,  # scalar write position, or [B] paged
    mrope_position_ids: jax.Array | None = None,  # [3, B, S]
    paged: Any | None = None,  # PagedInfo: cache leaves are pool blocks
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        kk = kk + p["bk"].astype(x.dtype)
        vv = vv + p["bv"].astype(x.dtype)
    mrope = cfg.mrope_sections and mrope_position_ids is not None
    if (
        cache is not None and paged is not None and paged.prefill
        and S > 1 and causal and not mrope and collector is NULL_COLLECTOR
    ):
        # fused flash-prefill: norm + rope + pool scatter + banded attention
        # in one op straight against the block pool — full prefill, chunked
        # prefill, and the spec-verify step all land here (decode S == 1
        # keeps the decode kernel below).  The raw q rides into the kernel,
        # whose prologue fuses the qk_norm/rope entry; the K side reuses the
        # jnp helpers so pool contents match this function's generic branch
        # bit-for-bit.  Gated off whenever a collector is live: the fused op
        # never materializes the roped q/k this function would tag.
        from repro.kernels.paged_attention.ops import paged_prefill

        o, new_cache = paged_prefill(
            q, kk, vv, cache["k"], cache["v"],
            tables=paged.tables, positions=positions,
            block_size=paged.block_size,
            scale=1.0 / math.sqrt(dh),
            window=window, impl=paged.impl, layer=paged.layer,
            q_norm=p["q_norm"] if cfg.qk_norm else None,
            k_norm=p["k_norm"] if cfg.qk_norm else None,
            eps=cfg.norm_eps, rope_theta=cfg.rope_theta,
            q_start=paged.q_start,
        )
        out = jnp.einsum(
            "bshk,hkd->bsd", o.astype(x.dtype), p["wo"].astype(x.dtype)
        )
        return out, new_cache
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        kk = rms_head_norm(p["k_norm"], kk, cfg.norm_eps)
    if mrope:
        q = apply_mrope(q, mrope_position_ids, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
    q = collector.tag("q", q)
    vv = collector.tag("v", vv)
    q = shard_act(q, ("batch", "seq", "heads_act", "head_dim_act"))

    kv_len = None
    new_cache = None
    if cache is not None and paged is not None:
        # paged decode: cache leaves are the physical pool ``[(n_layers,)
        # num_blocks, bs, K, dh]`` shared by all slots; the new tokens' K/V
        # go *straight into the blocks owning each slot's write positions*
        # (no dense gather, no block write-back).  S > 1 is the speculative-
        # decoding verify step: S = draft_len + 1 tokens land at consecutive
        # positions of the same slot.  Inactive slots sit at pos 0 of the
        # null block — their writes collide there harmlessly and are masked
        # by kv_len; write positions beyond the table's reach (padded verify
        # rows near a slot's max_len) are redirected to the null block too,
        # so clamped gathers can never corrupt a live block.  Values quantize
        # through bfloat16 (the lm attention-cache dtype) even when the pool
        # container is wider: XLA CPU cannot alias bfloat16 scatters, so such
        # pools store bf16 values in f32 so the in-place update actually
        # stays in place.
        kk = apply_rope(kk, positions, cfg.rope_theta)
        kk = collector.tag("k", kk)
        pos = positions                             # [B, S] write positions
        bs = paged.block_size
        in_reach = pos < paged.tables.shape[1] * bs
        blk = jnp.where(in_reach, pos // bs, 0)
        phys = jnp.take_along_axis(paged.tables, blk, axis=1)  # [B, S]
        phys = jnp.where(in_reach, phys, 0)
        off = pos % bs
        k_new = kk.astype(jnp.bfloat16).astype(cache["k"].dtype)
        v_new = vv.astype(jnp.bfloat16).astype(cache["v"].dtype)
        if paged.layer is None:
            ck = cache["k"].at[phys, off].set(k_new)
            cv = cache["v"].at[phys, off].set(v_new)
        else:  # layer-stacked pools riding lm.forward's scan carry
            ck = cache["k"].at[paged.layer, phys, off].set(k_new)
            cv = cache["v"].at[paged.layer, phys, off].set(v_new)
        new_cache = {"k": ck, "v": cv}
        kf, vf = ck, cv
        kv_len = pos[:, -1] + 1                     # incl. all S new tokens
    elif cache is not None:
        # decode / cached path: rope the new K, write kv at cache_pos
        if mrope:
            kk = apply_mrope(kk, mrope_position_ids, cfg.mrope_sections, cfg.rope_theta)
        else:
            kk = apply_rope(kk, positions, cfg.rope_theta)
        kk = collector.tag("k", kk)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kk.astype(cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vv.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        ck = shard_act(ck, ("batch", "kv_time", "kv_heads_act", "head_dim_act"))
        cv = shard_act(cv, ("batch", "kv_time", "kv_heads_act", "head_dim_act"))
        kf, vf = ck, cv
        kv_len = cache_pos + S
    else:
        # context-parallel path: gather K over the sequence axis while still
        # bf16 and *pre-rope* (rope's f32 internals would otherwise be hoisted
        # before the gather, doubling gather bytes), then rope locally.
        kf = shard_act(kk, ("batch", "seq_kv", "kv_heads_act", "head_dim_act"))
        vf = shard_act(vv, ("batch", "seq_kv", "kv_heads_act", "head_dim_act"))
        if mrope:
            kf = apply_mrope(kf, mrope_position_ids, cfg.mrope_sections, cfg.rope_theta)
        else:
            kf = apply_rope(kf, positions, cfg.rope_theta)
        kf = collector.tag("k", kf)

    # (windowed attention goes through the flash path: far chunks are fully
    # masked — wasted score FLOPs are <3% of model FLOPs even at 32k, and the
    # flash custom-VJP keeps memory flat, unlike the banded local_block path)
    impl = cfg.attn_impl
    if paged is not None:
        # pool leaves stay in cache dtype: casting here would materialize a
        # full pool-sized copy per layer — the kernel/ref upcasts only the
        # blocks it actually reads
        kf_a, vf_a = kf, vf
    else:
        kf_a, vf_a = kf.astype(x.dtype), vf.astype(x.dtype)
    o = attention(
        q.astype(x.dtype), kf_a, vf_a,
        scale=1.0 / math.sqrt(dh),
        positions_q=positions,
        causal=causal,
        window=window,
        kv_len=kv_len,
        impl=impl,
        kv_chunk=cfg.attn_kv_chunk,
        paged=paged,
        collector=collector,
    )
    o = collector.tag("attn_out", o)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(b: ParamBuilder, cfg: ModelConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    b.param("wq", (D, H, dq), ("embed_w", "heads_w", "head_dim_w"), fan_in=D)
    b.param("wdkv", (D, m.kv_lora_rank), ("embed_w", "kv_lora_w"), fan_in=D)
    b.param("wkr", (D, m.qk_rope_head_dim), ("embed_w", "head_dim_w"), fan_in=D)
    b.param("kv_norm", (m.kv_lora_rank,), ("kv_lora_w",), init="ones")
    b.param("wuk", (m.kv_lora_rank, H, m.qk_nope_head_dim),
            ("kv_lora_w", "heads_w", "head_dim_w"), fan_in=m.kv_lora_rank)
    b.param("wuv", (m.kv_lora_rank, H, m.v_head_dim),
            ("kv_lora_w", "heads_w", "head_dim_w"), fan_in=m.kv_lora_rank)
    b.param("wo", (H, m.v_head_dim, D), ("heads_w", "head_dim_w", "embed_w"),
            fan_in=H * m.v_head_dim, scale=1.0 / math.sqrt(2 * cfg.num_layers))


def _mla_qkr(p, cfg, x, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    qn = q[..., : m.qk_nope_head_dim]
    qr = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return qn, qr


def mla_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: dict | None = None,  # {"ckv": [B,T,r], "kpe": [B,T,dr]}
    cache_pos: jax.Array | None = None,
    paged: Any | None = None,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    if paged is not None:
        # the latent-space cache has no kv-head axis for the paged kernel to
        # walk; MLA serves through the gathered-dense oracle path instead
        raise NotImplementedError("paged decode does not support MLA")
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    qn, qr = _mla_qkr(p, cfg, x, positions)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(x.dtype))
    ckv = norm_apply({"scale": p["kv_norm"]}, ckv, "rmsnorm", cfg.norm_eps)
    kpe = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["wkr"].astype(x.dtype)), positions,
        cfg.rope_theta,
    )

    if cache is not None and S == 1:
        # absorbed decode: attend in the latent space (compressed KV cache)
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        kpe_c = jax.lax.dynamic_update_slice_in_dim(
            cache["kpe"], kpe.astype(cache["kpe"].dtype), cache_pos, axis=1)
        new_cache = {"ckv": ckv_c, "kpe": kpe_c}
        ckv_s = shard_act(ckv_c, ("batch", "kv_time", "kv_lora_act"))
        kpe_s = shard_act(kpe_c, ("batch", "kv_time", "head_dim_act"))
        T = ckv_s.shape[1]
        q_lat = jnp.einsum("bshk,rhk->bshr", qn, p["wuk"].astype(x.dtype))
        s = (
            jnp.einsum("bshr,btr->bsht", q_lat, ckv_s.astype(x.dtype),
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bshk,btk->bsht", qr, kpe_s.astype(x.dtype),
                         preferred_element_type=jnp.float32)
        ) * scale
        kv_len = cache_pos + 1
        msk = jnp.arange(T)[None, None, None, :] < kv_len
        s = jnp.where(msk, s, BIG_NEG)
        prob = jax.nn.softmax(s, axis=-1)
        prob = collector.tag("attn_probs", prob)
        ctx = jnp.einsum("bsht,btr->bshr", prob.astype(x.dtype), ckv_s.astype(x.dtype),
                         preferred_element_type=jnp.float32).astype(x.dtype)
        o = jnp.einsum("bshr,rhv->bshv", ctx, p["wuv"].astype(x.dtype))
        out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(x.dtype))
        return out, new_cache

    # full (training / prefill) path
    kn = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(x.dtype))
    vv = jnp.einsum("bsr,rhv->bshv", ckv, p["wuv"].astype(x.dtype))
    k_full = jnp.concatenate(
        [kn, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1,
    )
    q_full = jnp.concatenate([qn, qr], axis=-1)
    q_full = shard_act(q_full, ("batch", "seq", "heads_act", "head_dim_act"))
    k_full = shard_act(k_full, ("batch", "seq_kv", "heads_act", "head_dim_act"))
    vv = shard_act(vv, ("batch", "seq_kv", "heads_act", "head_dim_act"))
    o = attention(
        q_full, k_full, vv,
        scale=scale,
        positions_q=positions,
        causal=True,
        impl=cfg.attn_impl,
        kv_chunk=cfg.attn_kv_chunk,
        collector=collector,
    )
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(x.dtype))
    new_cache = None
    if cache is not None:  # prefill fills the compressed cache
        T = cache["ckv"].shape[1]
        pad = [(0, 0), (0, T - S), (0, 0)]
        new_cache = {
            "ckv": jnp.pad(ckv.astype(cache["ckv"].dtype), pad),
            "kpe": jnp.pad(kpe.astype(cache["kpe"].dtype), pad),
        }
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(b: ParamBuilder, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    scale_out = 1.0 / math.sqrt(2 * cfg.num_layers)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        b.param("w_gate", (D, F), ("embed_w", "mlp_w"), fan_in=D)
    b.param("w_up", (D, F), ("embed_w", "mlp_w"), fan_in=D)
    b.param("w_down", (F, D), ("mlp_w", "embed_w"), fan_in=F, scale=scale_out)


def mlp_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              collector: Collector = NULL_COLLECTOR) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_kind == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.gelu(g) * h
    elif cfg.mlp_kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.mlp_kind)
    h = shard_act(h, ("batch", "seq_act", "mlp_act"))
    h = collector.tag("mlp_hidden", h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Mixture of Experts (sort/scatter dispatch — no one-hot einsum FLOPs)
# ---------------------------------------------------------------------------


def moe_init(b: ParamBuilder, cfg: ModelConfig):
    D = cfg.d_model
    mo = cfg.moe
    E, F = mo.num_experts, mo.expert_d_ff
    b.param("router", (D, E), ("embed_w", None), fan_in=D)
    b.param("w_gate", (E, D, F), ("expert_w", "embed_w", "expert_mlp"), fan_in=D)
    b.param("w_up", (E, D, F), ("expert_w", "embed_w", "expert_mlp"), fan_in=D)
    b.param("w_down", (E, F, D), ("expert_w", "expert_mlp", "embed_w"),
            fan_in=F, scale=1.0 / math.sqrt(2 * cfg.num_layers))
    if mo.num_shared_experts:
        s = b.sub("shared")
        shared_cfg = cfg.replace(mlp_kind="swiglu")
        mlp_init(s, shared_cfg, d_ff=mo.num_shared_experts * F)


def moe_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    n_seq_groups: int = 1,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Top-k routed experts with capacity, sort-based dispatch, EP all-to-all.

    Tokens are viewed as [G, C, D] groups (G = batch x seq-chunks, matching the
    activation sharding so dispatch is local); expert compute is sharded over
    ``expert_w``; the G->E resharding between constraints is the all-to-all.
    """
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.top_k
    nsg = n_seq_groups if S % max(n_seq_groups, 1) == 0 else 1
    Cg = S // nsg
    G = B * nsg
    N = Cg * K
    # Regroup tokens so each group is device-local *before* any data-dependent
    # gather/sort — GSPMD cannot keep gathers over a sharded seq dim sharded.
    # The reshape is staged through an explicitly-anchored 4-D intermediate:
    # propagating the merged [G] sharding straight through the reshape lets
    # Shardy assign B a greedy (data+model) sharding that conflicts with the
    # residual layout and degenerates into full rematerialization.
    x4 = shard_act(
        x.reshape(B, nsg, Cg, D), ("batch", "seq_act", None, "embed_act")
    )
    xt = shard_act(x4.reshape(G, Cg, D), ("moe_groups", None, "embed_act"))

    logits = jnp.einsum("gcd,de->gce", xt, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # [G, Cg, K]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    gate = collector.tag("router_gate", gate)

    # aux losses (Switch-style load balance + z-loss)
    me = probs.mean(axis=(0, 1))  # [E]
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (G * N)
    aux_lb = (me * ce).sum() * E * mo.router_aux_coef
    aux_z = jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean() * mo.router_z_coef

    cap = max(int(math.ceil(Cg * K / E * mo.capacity_factor)), 1)

    # ---- sort-based dispatch (no one-hot einsum FLOPs, no [G,N,D] tensors):
    # build a slot->token index table, then one output-sized gather.
    flat_e = eidx.reshape(G, N)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # [G, N] sorted entries
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    first = jax.vmap(lambda s: jnp.searchsorted(s, jnp.arange(E + 1)))(sorted_e)
    # slot (e, c) holds sorted entry j = first[e] + c while j < first[e+1]
    j = first[:, :E, None] + jnp.arange(cap)[None, None, :]  # [G, E, cap]
    valid = j < first[:, 1:, None]
    tok_sorted = order // K  # token of each sorted entry
    tok_for_slot = jnp.where(
        valid,
        jnp.take_along_axis(tok_sorted, jnp.minimum(j, N - 1).reshape(G, E * cap), axis=-1
                            ).reshape(G, E, cap),
        Cg,  # sentinel -> zero pad row
    )
    xt_pad = jnp.pad(xt, ((0, 0), (0, 1), (0, 0)))
    expert_in = jnp.take_along_axis(
        xt_pad, tok_for_slot.reshape(G, E * cap)[..., None], axis=1
    ).reshape(G, E, cap, D)
    expert_in = shard_act(expert_in, ("moe_groups", "expert_pre", "moe_cap", "embed_act"))
    # all-to-all: groups spread back over the data axes, experts onto EP axis
    expert_in = shard_act(expert_in, ("moe_groups_post", "expert_act", "moe_cap", "embed_act"))

    h_up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(x.dtype))
    h_g = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_up
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    expert_out = shard_act(expert_out, ("moe_groups_post", "expert_act", "moe_cap", "embed_act"))
    # reverse all-to-all
    expert_out = shard_act(expert_out, ("moe_groups", "expert_pre", "moe_cap", "embed_act"))
    flat_out = jnp.pad(
        expert_out.reshape(G, E * cap, D), ((0, 0), (0, 1), (0, 0))
    )  # zero row at E*cap for dropped entries

    # ---- combine: per top-k choice, gather the slot output and weight it
    inv = jnp.argsort(order, axis=-1, stable=True)  # entry -> sorted position
    slot_sorted = jnp.arange(N)[None, :] - jnp.take_along_axis(first[:, :E], sorted_e, -1)
    dest_sorted = jnp.where(
        slot_sorted < cap, sorted_e * cap + slot_sorted, E * cap
    )
    slot_entry = jnp.take_along_axis(dest_sorted, inv, axis=-1)  # [G, N]
    y = jnp.zeros((G, Cg, D), x.dtype)
    for k in range(K):
        se = slot_entry[:, k::K]  # [G, Cg] entries (t, k) are laid out t*K+k
        out_k = jnp.take_along_axis(flat_out, se[..., None], axis=1)
        y = y + out_k * gate[:, :, k][..., None].astype(x.dtype)

    if mo.num_shared_experts:
        # shared experts: a plain SwiGLU applied in the group-local layout
        sp = p["shared"]
        hs = jnp.einsum("gcd,df->gcf", xt, sp["w_up"].astype(x.dtype))
        gs = jnp.einsum("gcd,df->gcf", xt, sp["w_gate"].astype(x.dtype))
        y = y + jnp.einsum(
            "gcf,fd->gcd", jax.nn.silu(gs) * hs, sp["w_down"].astype(x.dtype)
        )

    y = shard_act(y, ("moe_groups", None, "embed_act"))
    y4 = shard_act(
        y.reshape(B, nsg, Cg, D), ("batch", "seq_act", None, "embed_act")
    )
    aux = {
        "moe_aux_loss": aux_lb + aux_z,
        "moe_drop_frac": (slot_entry == E * cap).mean(),
    }
    return y4.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Embedding + vocab-sharded chunked cross-entropy
# ---------------------------------------------------------------------------


def embed_init(b: ParamBuilder, cfg: ModelConfig):
    # vocab rows padded to a shardable multiple (Megatron-style); padded
    # logits are masked out in logits_fn / chunked_xent
    b.param("embedding", (cfg.padded_vocab, cfg.d_model), ("vocab_w", "embed_w"),
            fan_in=cfg.d_model, scale=1.0)
    if not cfg.tie_embeddings:
        b.param("unembed", (cfg.d_model, cfg.padded_vocab), ("embed_w", "vocab_w"),
                fan_in=cfg.d_model)


def embed_apply(p: dict, cfg: ModelConfig, tokens: jax.Array, dtype) -> jax.Array:
    emb = p["embedding"].astype(dtype)
    x = jnp.take(emb, tokens, axis=0)
    if cfg.scale_emb != 1.0:
        x = x * cfg.scale_emb
    return shard_act(x, ("batch", "seq_act", "embed_act"))


def _unembed_matrix(p: dict, cfg: ModelConfig, dtype) -> jax.Array:
    if cfg.tie_embeddings:
        return p["embedding"].astype(dtype).T
    return p["unembed"].astype(dtype)


def _mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    col = jnp.arange(cfg.padded_vocab)
    return jnp.where(col < cfg.vocab_size, logits, BIG_NEG)


def logits_fn(p: dict, cfg: ModelConfig, y: jax.Array) -> jax.Array:
    """Full logits (serving path): y [B, S, D] -> [B, S, padded_V] with padded
    columns masked to -inf."""
    w = _unembed_matrix(p, cfg, y.dtype)
    if cfg.dim_model_base:
        y = y / (cfg.d_model / cfg.dim_model_base)
    logits = jnp.einsum("bsd,dv->bsv", y, w)
    logits = _mask_padded_vocab(cfg, logits)
    return shard_act(logits, ("ce_batch", "seq_ce", "vocab_act"))


def chunked_xent(
    p: dict,
    cfg: ModelConfig,
    y: jax.Array,  # [B, S, D] final hidden states
    targets: jax.Array,  # [B, S] int32
    loss_mask: jax.Array | None = None,  # [B, S]
) -> tuple[jax.Array, jax.Array]:
    """Sequence-chunked, vocab-sharded cross entropy; never materializes
    [B, S, V].  Custom VJP: logits are recomputed per chunk in backward with
    the analytic softmax gradient, and dy/dw leave in bf16 — grad reductions
    across the mesh run at half the bytes of the autodiff (f32) path.
    Returns (sum_loss, sum_count)."""
    B, S, D = y.shape
    w = _unembed_matrix(p, cfg, y.dtype)
    if cfg.dim_model_base:
        y = y / (cfg.d_model / cfg.dim_model_base)
    # regroup: batch over all data axes, sequence gathered, for clean chunking
    y = shard_act(y, ("ce_batch", "seq_ce", "embed_act"))
    c = min(cfg.logits_chunk, S)
    nchunks = max(S // c, 1)
    c = S // nchunks
    mask = (
        loss_mask.astype(jnp.float32)
        if loss_mask is not None
        else jnp.ones((B, S), jnp.float32)
    )
    fn = _make_ce(nchunks, c, cfg.vocab_size, cfg.padded_vocab)
    return fn(y, w, targets, mask)


@functools.lru_cache(maxsize=None)
def _make_ce(nchunks: int, c: int, vocab_real: int, padded: int):
    col_valid = None  # built lazily inside traces

    def _logits(yc, w):
        logits = jnp.einsum("bsd,dv->bsv", yc, w, preferred_element_type=jnp.float32)
        if padded != vocab_real:
            logits = jnp.where(jnp.arange(padded) < vocab_real, logits, BIG_NEG)
        return shard_act(logits, ("ce_batch", "seq_ce", "vocab_act"))

    def _forward(y, w, t, m):
        total = jnp.zeros((), jnp.float32)
        for i in range(nchunks):
            sl = slice(i * c, (i + 1) * c)
            logits = _logits(y[:, sl], w)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, t[:, sl, None], axis=-1)[..., 0]
            total = total + ((lse - tgt) * m[:, sl]).sum()
        return total, m.sum()

    @jax.custom_vjp
    def ce(y, w, t, m):
        return _forward(y, w, t, m)

    def fwd(y, w, t, m):
        return _forward(y, w, t, m), (y, w, t, m)

    def bwd(res, ct):
        y, w, t, m = res
        g = ct[0].astype(jnp.float32)  # cotangent of sum_loss
        dy_chunks = []
        dw = None
        for i in range(nchunks):
            sl = slice(i * c, (i + 1) * c)
            yc = y[:, sl]
            logits = _logits(yc, w)
            prob = jax.nn.softmax(logits, axis=-1)
            eq = jnp.arange(padded)[None, None, :] == t[:, sl, None]
            dlog = (prob - eq.astype(jnp.float32)) * (m[:, sl] * g)[..., None]
            dlog = dlog.astype(w.dtype)  # bf16 grad reductions
            dy_chunks.append(
                jnp.einsum("bcv,dv->bcd", dlog, w, preferred_element_type=jnp.float32)
                .astype(y.dtype)
            )
            dw_c = jnp.einsum("bcd,bcv->dv", yc, dlog,
                              preferred_element_type=jnp.float32)
            dw = dw_c if dw is None else dw + dw_c
        dy = jnp.concatenate(dy_chunks, axis=1)
        dy = shard_act(dy, ("ce_batch", "seq_ce", "embed_act"))
        return dy, dw.astype(w.dtype), None, None

    ce.defvjp(fwd, bwd)
    return ce
