from repro.models.model import Model, get_model, make_batch, count_params

__all__ = ["Model", "get_model", "make_batch", "count_params"]
