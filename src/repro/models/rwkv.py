"""RWKV-6 ("Finch") blocks: data-dependent token shift + decay WKV attention.

Follows arXiv:2404.05892: time-mix block (ddlerp token shift via a small
tanh-LoRA, data-dependent per-channel decay w_t = exp(-exp(w0 + lora(x))),
per-head matrix-valued WKV state with bonus u) and channel-mix block
(squared-ReLU with simple token-shift lerp).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.hooks import Collector, NULL_COLLECTOR
from repro.models.layers import ParamBuilder, norm_apply, norm_init
from repro.models.scan_utils import shift_tokens, wkv6_chunked, wkv6_sequential
from repro.parallel.sharding import shard_act

MIX_NAMES = ("w", "k", "v", "r", "g")


def time_mix_init(b: ParamBuilder, cfg: ModelConfig):
    D = cfg.d_model
    r = cfg.rwkv.ddlerp_rank
    dr = cfg.rwkv.decay_rank
    H = cfg.num_heads
    hs = cfg.rwkv.head_size
    b.param("mu_x", (D,), ("embed_w",), init="zeros")
    b.param("mu", (5, D), (None, "embed_w"), init="zeros")
    b.param("w_mix1", (D, 5, r), ("embed_w", None, None), fan_in=D)
    b.param("w_mix2", (5, r, D), (None, None, "embed_w"), fan_in=r)
    b.param("w_r", (D, D), ("embed_w", "qkv"), fan_in=D)
    b.param("w_k", (D, D), ("embed_w", "qkv"), fan_in=D)
    b.param("w_v", (D, D), ("embed_w", "qkv"), fan_in=D)
    b.param("w_g", (D, D), ("embed_w", "qkv"), fan_in=D)
    b.param("w_o", (D, D), ("qkv", "embed_w"), fan_in=D,
            scale=1.0 / math.sqrt(2 * cfg.num_layers))
    b.param("w0", (D,), ("embed_w",), init="const", fill=-5.0)
    b.param("w_decay1", (D, dr), ("embed_w", None), fan_in=D)
    b.param("w_decay2", (dr, D), (None, "embed_w"), fan_in=dr)
    b.param("u", (H, hs), (None, None), init="normal", fan_in=hs)
    norm_init(b, "ln_x", D, "layernorm")  # per-head group norm scales


def time_mix_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    state: dict | None = None,  # {"x_prev": [B,D], "wkv": [B,H,K,V]}
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    H, hs = cfg.num_heads, cfg.rwkv.head_size
    prev = state["x_prev"] if state is not None else None
    xx = shift_tokens(x, prev) - x  # [B,S,D]
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dnr->bsnr", xxx, p["w_mix1"].astype(x.dtype)))
    mm = jnp.einsum("bsnr,nrd->nbsd", lora, p["w_mix2"].astype(x.dtype))
    mixed = {
        name: x + xx * (p["mu"][i].astype(x.dtype) + mm[i])
        for i, name in enumerate(MIX_NAMES)
    }
    r = jnp.einsum("bsd,de->bse", mixed["r"], p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", mixed["k"], p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", mixed["v"], p["w_v"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mixed["g"], p["w_g"].astype(x.dtype)))
    ww = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr->bsr", mixed["w"], p["w_decay1"].astype(x.dtype)
    ).astype(jnp.float32) @ p["w_decay2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww))  # [B,S,D] decay in (0,1)
    w = collector.tag("wkv_decay", w)

    rh = r.reshape(B, S, H, hs)
    kh = k.reshape(B, S, H, hs)
    vh = v.reshape(B, S, H, hs)
    wh = w.reshape(B, S, H, hs)
    s0 = state["wkv"] if state is not None else None
    if S == 1:
        y, s_new = wkv6_sequential(rh, kh, vh, wh, p["u"].astype(jnp.float32), s0)
    elif cfg.kernels_impl != "xla" and s0 is None:
        from repro.kernels.wkv6.ops import wkv6 as wkv6_kernel

        y, s_new = wkv6_kernel(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                               impl=cfg.kernels_impl)
    else:
        y, s_new = wkv6_chunked(rh, kh, vh, wh, p["u"].astype(jnp.float32), s0)
    y = collector.tag("wkv_out", y)

    # per-head group norm, then gate and project
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yf = yf.reshape(B, S, D)
    yf = yf * p["ln_x"]["scale"].astype(jnp.float32) + p["ln_x"]["bias"].astype(jnp.float32)
    out = (yf.astype(x.dtype) * g) @ p["w_o"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"x_prev": x[:, -1], "wkv": s_new}
    return out, new_state


def channel_mix_init(b: ParamBuilder, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    b.param("mu_k", (D,), ("embed_w",), init="zeros")
    b.param("mu_r", (D,), ("embed_w",), init="zeros")
    b.param("w_k", (D, F), ("embed_w", "mlp_w"), fan_in=D)
    b.param("w_v", (F, D), ("mlp_w", "embed_w"), fan_in=F,
            scale=1.0 / math.sqrt(2 * cfg.num_layers))
    b.param("w_r", (D, D), ("embed_w", "qkv"), fan_in=D)


def channel_mix_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: dict | None = None,  # {"x_prev": [B,D]}
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    prev = state["x_prev"] if state is not None else None
    xx = shift_tokens(x, prev) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype))))
    k = shard_act(k, ("batch", "seq_act", "mlp_act"))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(x.dtype))
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype))) * kv
    new_state = {"x_prev": x[:, -1]} if state is not None else None
    return out, new_state


def rwkv_block_init(b: ParamBuilder, cfg: ModelConfig):
    norm_init(b, "ln1", cfg.d_model, cfg.norm_kind)
    norm_init(b, "ln2", cfg.d_model, cfg.norm_kind)
    time_mix_init(b.sub("att"), cfg)
    channel_mix_init(b.sub("ffn"), cfg)


def rwkv_block_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: dict | None = None,
    collector: Collector = NULL_COLLECTOR,
) -> tuple[jax.Array, dict | None]:
    att_state = state["att"] if state is not None else None
    ffn_state = state["ffn"] if state is not None else None
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    h = norm_apply(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    a, att_new = time_mix_apply(p["att"], cfg, h, state=att_state, collector=collector)
    x = x + collector.tag("att_resid", a)
    h = norm_apply(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    f, ffn_new = channel_mix_apply(p["ffn"], cfg, h, state=ffn_state, collector=collector)
    x = x + collector.tag("ffn_resid", f)
    x = shard_act(x, ("batch", "seq_act", "embed_act"))
    new_state = None
    if state is not None:
        new_state = {"att": att_new, "ffn": ffn_new}
    return x, new_state


def rwkv_init_state(cfg: ModelConfig, batch: int) -> dict:
    """Per-layer decode/prefill carry state (stacked over layers by the LM)."""
    H, hs = cfg.num_heads, cfg.rwkv.head_size
    return {
        "att": {
            "x_prev": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "wkv": jnp.zeros((batch, H, hs, hs), jnp.float32),
        },
        "ffn": {"x_prev": jnp.zeros((batch, cfg.d_model), jnp.float32)},
    }
