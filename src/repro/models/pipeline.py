"""Stage-stackable block application for MegaDPP pipeline parallelism.

Bridges the model layer and ``repro.core.dpp.executor``: the LM families
stack their repeating blocks into one scanned ``[layers, ...]`` segment
(``lm.segment_layout``); pipeline parallelism instead needs those same
weights laid out ``[stages, chunks_per_stage, groups_per_cell, ...]`` so each
pipeline stage holds only its slice and the executor can index cell ``(s, c)``
statically.  Three pieces live here:

* :func:`pipeline_layout` — validates a config is pipeline-stackable and
  derives the (pp, n_chunks, groups-per-cell) split of its layer stack;
* :func:`restack_params` — the differentiable ``[G, ...] ->
  [S, C, G/(S*C), ...]`` pytree transform (chunk-major, matching the
  executor's (c, s) traversal: global group ``(c*S + s)*gpc + j``);
* :func:`make_block_fn` / :func:`pipeline_loss` — the per-cell apply (real
  transformer blocks via ``lm._block_apply``) and the full pipelined loss
  (embed -> pipeline_apply -> final norm -> chunked cross-entropy), which
  ``repro.train.train_step`` differentiates; the backward pipeline falls out
  of autodiff through the executor's ``ppermute``.

Restrictions (raise ``ValueError`` up front): families whose layer stack is a
single uniform segment only (MoE's aux losses cannot ride the activation
wire yet; mrope archs need per-block position ids the pipelined apply does
not thread).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dpp.executor import TimeTable, pipeline_apply
from repro.models import layers as L
from repro.models import lm
from repro.models.hooks import NULL_COLLECTOR
from repro.parallel.sharding import axis_rules


@dataclass(frozen=True)
class PipelineLayout:
    """How one family's stacked layer segment splits across the pipeline."""

    seg_key: str               # params key of the (single) stacked segment
    kinds: tuple[str, ...]     # block kinds inside one scanned group
    n_groups: int              # stacked groups in the segment
    pp: int                    # pipeline stages
    n_chunks: int              # virtual chunks per stage (interleaving)
    groups_per_cell: int       # consecutive groups one (stage, chunk) holds
    tp: int = 1                # tensor degree inside each stage's body


def pipeline_layout(
    cfg: ModelConfig, pp: int, n_chunks: int = 1, tp: int = 1
) -> PipelineLayout:
    """Derive (and validate) the stage/chunk split of ``cfg``'s layer stack."""
    if cfg.family == "moe":
        raise ValueError(
            "pipeline parallelism does not support MoE yet: router aux "
            "losses cannot ride the pipeline's activation wire"
        )
    if cfg.input_kind == "embeds_mrope":
        raise ValueError(
            "pipeline parallelism does not support mrope archs: per-block "
            "mrope position ids are not threaded through the pipelined apply"
        )
    segs = lm.segment_layout(cfg)
    if len(segs) != 1:
        raise ValueError(
            f"{cfg.name}: pipeline parallelism needs a single uniform layer "
            f"segment, got {len(segs)} (layout {segs})"
        )
    kinds, n_groups = segs[0]
    cells = pp * n_chunks
    if n_groups % cells != 0:
        raise ValueError(
            f"{cfg.name}: {n_groups} layer group(s) not divisible by "
            f"pp*n_chunks = {pp}*{n_chunks} = {cells}"
        )
    if tp > 1:
        _validate_tp(cfg, tp)
    return PipelineLayout("seg0", tuple(kinds), n_groups, pp, n_chunks,
                          n_groups // cells, tp)


def _validate_tp(cfg: ModelConfig, tp: int) -> None:
    """tp>1 inside the pipeline is the Megatron split of dense GQA blocks:
    heads / kv-heads / ffn width slice across the ``model`` axis, with an
    explicit psum after the attention-out and mlp-down projections."""
    segs = lm.segment_layout(cfg)
    kinds = set(segs[0][0]) if len(segs) == 1 else {k for ks, _ in segs for k in ks}
    if kinds != {"dense"} or cfg.use_mla:
        raise ValueError(
            f"{cfg.name}: tp={tp} inside the pipeline supports dense GQA "
            f"blocks only (got kinds {sorted(kinds)}"
            f"{', mla' if cfg.use_mla else ''})"
        )
    H, K, F = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    if H % tp or K % tp or F % tp:
        raise ValueError(
            f"{cfg.name}: heads={H}/kv_heads={K}/d_ff={F} must all divide "
            f"by tp={tp} for the in-stage tensor split"
        )


def restack_params(seg_params: Any, layout: PipelineLayout) -> Any:
    """``[G, ...]`` leaves -> ``[S, C, G/(S*C), ...]``, chunk-major.

    Execution order is (c=0, s=0..S-1), (c=1, s=0..S-1), ...: cell (s, c)
    holds global groups ``(c*S + s)*gpc + j``.  Pure reshape/transpose, so
    gradients flow back to the canonical stacked layout automatically.
    """
    S, C, g = layout.pp, layout.n_chunks, layout.groups_per_cell

    def one(a):
        a = a.reshape(C, S, g, *a.shape[1:])
        return jnp.swapaxes(a, 0, 1)

    return jax.tree.map(one, seg_params)


# weight logical axes the in-stage tensor split slices over the model axis
_TP_SLICED = ("heads_w", "kv_heads_w", "mlp_w")


def pipeline_param_specs(cfg: ModelConfig, layout: PipelineLayout) -> Any:
    """Per-leaf ``PartitionSpec`` pytree for the restacked segment params.

    Every leaf leads with the stage axis over its ``[S, C, g, ...]`` stacking;
    with ``layout.tp > 1`` the Megatron-sliced weight dims (heads / kv-heads /
    ffn width) additionally shard over ``model``.  Norm scales and biases on
    replicated dims carry no model-axis entry: their in_spec not mentioning
    ``model`` is exactly what makes ``shard_map``'s transpose psum their
    cotangents across the tensor ranks.
    """
    from jax.sharding import PartitionSpec as P

    axes = lm.param_axes(cfg)[layout.seg_key]

    def one(t):
        rest = t[1:]  # drop the "layers" axis: restacked to [S, C, g]
        parts = [
            "model" if (layout.tp > 1 and a in _TP_SLICED) else None
            for a in rest
        ]
        return P("stage", None, None, *parts)

    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t
    )
    return jax.tree.map(one, axes, is_leaf=is_axes)


def _tp_local_cfg(cfg: ModelConfig, tp: int) -> ModelConfig:
    """The per-tensor-rank view of a dense config: H/K/F divided by tp (the
    grouping ratio G = H/K is preserved, so GQA head-grouping is unchanged)."""
    return cfg.replace(
        num_heads=cfg.num_heads // tp,
        num_kv_heads=cfg.num_kv_heads // tp,
        d_ff=cfg.d_ff // tp,
    )


def make_block_fn(
    cfg: ModelConfig,
    layout: PipelineLayout,
) -> Callable[[Any, jax.Array], jax.Array]:
    """Per-cell apply: runs the cell's ``groups_per_cell`` stacked groups of
    real transformer blocks over one microbatch activation ``[B, S_seq, D]``.

    Runs inside ``shard_map`` (per-device code), so the model's logical
    sharding constraints must be inert — callers wrap the pipelined section
    in ``axis_rules(None)`` (``pipeline_loss`` does).  MegaScope collectors
    are not threaded into pipelined blocks: captures cannot ride the
    activation wire, so probes observe only the embed/head ends.

    With ``layout.tp > 1`` each block runs the Megatron tensor split: the
    cell's weights arrive pre-sliced by the executor's param in_specs
    (``pipeline_param_specs``), the attention/mlp submodules run on the local
    head/ffn shard via a narrowed config, and an explicit
    ``psum`` over the ``model`` axis after the attention-out and mlp-down
    projections restores the replicated residual stream.
    """
    if layout.tp > 1:
        cfg_local = _tp_local_cfg(cfg, layout.tp)

        def apply_block(bp: dict, x: jax.Array, positions: jax.Array) -> jax.Array:
            h = L.norm_apply(bp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            a, _ = L.gqa_apply(
                bp["attn"], cfg_local, h, positions=positions, cache=None,
                cache_pos=None, mrope_position_ids=None, paged=None,
                collector=NULL_COLLECTOR,
            )
            x = lm._resid(cfg, x, jax.lax.psum(a, "model"))
            h = L.norm_apply(bp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            f = L.mlp_apply(bp["mlp"], cfg_local, h, NULL_COLLECTOR)
            return lm._resid(cfg, x, jax.lax.psum(f, "model"))

        def apply_group(gp: dict, x: jax.Array) -> jax.Array:
            positions = jnp.arange(x.shape[1])
            for j, _ in enumerate(layout.kinds):
                x = apply_block(gp[f"b{j}"], x, positions)
            return x

    else:
        def apply_group(gp: dict, x: jax.Array) -> jax.Array:
            positions = jnp.arange(x.shape[1])
            for j, kind in enumerate(layout.kinds):
                x, _, aux = lm._block_apply(
                    gp[f"b{j}"], cfg, kind, x,
                    positions=positions, cache=None, cache_pos=None,
                    mrope_position_ids=None, paged=None,
                    collector=NULL_COLLECTOR,
                )
                if aux:
                    raise ValueError(
                        f"block kind {kind!r} produced aux outputs; "
                        "not supported on the pipeline path"
                    )
            return x

    group = apply_group
    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        group = jax.checkpoint(apply_group, policy=policy, prevent_cse=False)

    def block_fn(cell_params: Any, x: jax.Array) -> jax.Array:
        if layout.groups_per_cell == 1:
            return group(jax.tree.map(lambda a: a[0], cell_params), x)

        def body(xc, gp):
            return group(gp, xc), None

        x, _ = jax.lax.scan(body, x, cell_params)
        return x

    return block_fn


def pipeline_forward(
    cfg: ModelConfig,
    params: dict,
    x_micro: jax.Array,           # [n_micro, mb, S_seq, D] embedded inputs
    *,
    layout: PipelineLayout,
    table: TimeTable,
    mesh: jax.sharding.Mesh,
    block_fn: Callable | None = None,
    dp: int = 1,
) -> jax.Array:
    """Pipelined block stack on real weights: returns [n_micro, mb, S, D].

    ``dp > 1`` shards the microbatch axis over the mesh's ``data`` axis (the
    ``table`` must then be built for ``n_micro // dp`` local microbatches);
    ``layout.tp > 1`` slices weights over ``model`` via per-leaf in_specs.
    """
    block_fn = block_fn or make_block_fn(cfg, layout)
    stacked = restack_params(params[layout.seg_key], layout)
    return pipeline_apply(
        stacked, x_micro, table, mesh=mesh, block_fn=block_fn,
        data_axis="data" if dp > 1 else None,
        param_specs=pipeline_param_specs(cfg, layout) if layout.tp > 1 else None,
    )


def pipeline_loss(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    layout: PipelineLayout,
    table: TimeTable,
    mesh: jax.sharding.Mesh,
    n_micro: int,
    block_fn: Callable | None = None,
    dp: int = 1,
) -> tuple[jax.Array, dict]:
    """Full pipelined training loss; same contract as ``lm.loss_fn``.

    Embedding and the norm/cross-entropy head run replicated outside the
    pipeline (they are cheap at repro scale); the block stack — where the
    FLOPs live — runs through the schedule-controlled executor.  The global
    batch splits into ``n_micro`` equal microbatches along the batch axis
    (``n_micro`` is the *global* count; with ``dp > 1`` each dp group
    pipelines a contiguous ``n_micro // dp`` slice); with equal
    per-microbatch token counts the global-mean cross-entropy here equals
    the reference step's mean of per-microbatch means.
    """
    block_fn = block_fn or make_block_fn(cfg, layout)
    # the pipeline body is per-device code under shard_map: logical-axis
    # sharding constraints must resolve to no-ops while it traces
    with axis_rules(None):
        x = lm._embed_inputs(cfg, params, batch, jnp.dtype(cfg.compute_dtype))
        B, S, D = x.shape
        if B % n_micro != 0:
            raise ValueError(
                f"global batch {B} not divisible by n_micro={n_micro}"
            )
        mb = B // n_micro
        x_micro = x.reshape(n_micro, mb, S, D)
        hidden = pipeline_forward(
            cfg, params, x_micro,
            layout=layout, table=table, mesh=mesh, block_fn=block_fn, dp=dp,
        )
        hidden = hidden.reshape(B, S, D)
        hidden = L.norm_apply(
            params["final_norm"], hidden, cfg.norm_kind, cfg.norm_eps
        )
        total, count = L.chunked_xent(
            params, cfg, hidden, batch["targets"], batch.get("loss_mask")
        )
        ce = total / jnp.maximum(count, 1.0)
        metrics = {"loss": ce, "ce": ce,
                   "aux_loss": jnp.zeros((), jnp.float32)}
        return ce, metrics
