"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step:  <dir>/step_<n>/
    manifest.json          tree structure, shapes, dtypes, user metadata
    <flat.key.path>.npy    one file per leaf (per-host shard files in a real
                           multi-host deployment; full arrays here)

Atomicity: written to ``step_<n>.tmp`` then renamed — a crash never leaves a
half-readable checkpoint.  Async: ``Checkpointer.save_async`` snapshots to
host memory synchronously (cheap) and writes on a background thread, so the
training loop is stalled only for the device->host copy.

Elastic restore: leaves are loaded as host arrays and ``jax.device_put`` with
*target* shardings — restoring onto a different mesh shape (scale-up/down
after failures) is just a different target.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = ".".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save(state: Any, step: int, directory: str | Path, metadata: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}, "metadata": metadata or {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":  # np.save can't round-trip ml_dtypes
            arr = arr.view(np.uint16)
        np.save(tmp / f"{key}.npy", arr)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": logical_dtype
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(
    directory: str | Path,
    target: Any,                 # pytree of arrays or ShapeDtypeStructs
    step: int | None = None,
    shardings: Any = None,       # optional pytree of target shardings
) -> tuple[Any, dict]:
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, spec in flat_target.items():
        arr = np.load(d / f"{key}.npy")
        if manifest["leaves"].get(key, {}).get("dtype") == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        want_shape = tuple(spec.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} vs target {want_shape}")
        arr = arr.astype(spec.dtype)
        sh = flat_shard.get(key)
        loaded[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    # rebuild the pytree in target order
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, _ in paths:
        key = ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def wait(self) -> None:
        err = self.drain()
        if err is not None:
            raise err

    def drain(self) -> Exception | None:
        """Join the background save and *return* its error instead of
        raising — for failure paths that must not let a background-save
        error mask the original exception being handled."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        err, self._error = self._error, None
        return err

    def save_async(self, state: Any, step: int, metadata: dict | None = None) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(x), state)  # host copy now

        def _work():
            try:
                save(snapshot, step, self.directory, metadata)
                self._prune()
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def _prune(self) -> None:
        steps = sorted(
            p for p in self.directory.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
