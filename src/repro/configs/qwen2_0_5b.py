"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.

GQA with QKV bias [arXiv:2407.10671].  head_dim = 896/14 = 64.
Small model: the default 2-D (fsdp x tensor) weight sharding applies; the
14-head / 2-kv-head attention activations auto-fall-back to replicated head
dims on a 16-way model axis (size-aware rule resolution).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    notes="GQA kv=2 with QKV bias; tied embeddings.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2-0.5b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
