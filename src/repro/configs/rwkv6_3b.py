"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.

RWKV-6 with data-dependent decay [arXiv:2404.05892].  head_size=64 -> 40 WKV
heads.  Attention-free => O(1) decode state; supports the long_500k cell.
"""

from repro.configs.base import ModelConfig, RWKVConfig, register

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv6",
    num_layers=32,
    d_model=2560,
    num_heads=40,             # d_model / head_size
    num_kv_heads=40,
    head_dim=64,              # rwkv head_size
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_size=64, ddlerp_rank=32, decay_rank=64),
    supports_long_context=True,
    # §Perf: WKV state-passing context parallelism moves B*H*K*V fp32 state
    # per shard boundary; at these batch sizes sharding batch over the model
    # axis instead makes the recurrence fully device-local (size-aware rules
    # drop the extra axes when batch doesn't divide).
    sharding_overrides={
        "train": {
            # batch takes the model axis when it divides (single-pod: fully
            # local recurrence); otherwise the size-aware resolver leaves
            # model free and seq_act claims it (multi-pod: state-passing CP)
            "batch": ("pod", "data", "model"),
            "seq_act": ("model",),
            "seq": ("model",),
        },
    },
    notes="Attention-free; MegaScope attention views replaced by WKV state probes.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="rwkv6-3b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    rwkv=RWKVConfig(head_size=16, ddlerp_rank=8, decay_rank=16),
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
