"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.

MiniCPM [arXiv:2404.06395]: llama-like with MHA (kv=36), depth-scaled residual
(scale_depth=1.4), embedding scale 12, logits scaled by d_model/dim_model_base,
tied embeddings.  Trained with the WSD schedule (see repro/train/optim.py).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_emb=12.0,
    scale_depth=1.4,
    dim_model_base=256,
    notes="WSD schedule; depth-scaled residuals; tied embeddings.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="minicpm-2b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=257,
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
