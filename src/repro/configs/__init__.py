from repro.configs.base import (
    SHAPES,
    ArchEntry,
    GriffinConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "SHAPES",
    "ArchEntry",
    "GriffinConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RWKVConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "list_archs",
    "register",
]
