"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (multimodal 3-D rotary: temporal/height/width sections 16/24/24 on
head_dim 128) + dynamic resolution [arXiv:2409.12191].  The vision frontend is
a STUB per the assignment: ``input_specs()`` provides precomputed patch
embeddings plus 3-component M-RoPE position ids.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    input_kind="embeds_mrope",
    notes="M-RoPE sections (t,h,w)=(16,24,24); patch-embedding frontend stub.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2-vl-7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mrope_sections=(2, 3, 3),
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
