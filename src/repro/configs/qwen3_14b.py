"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

Qwen3: per-head-dim RMSNorm on Q and K (qk_norm), GQA, no QKV bias.
The assignment's explicit dims are used verbatim.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    notes="qk_norm + GQA.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-14b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
