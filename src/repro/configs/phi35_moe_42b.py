"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064.

16 experts, top-2 routing, SwiGLU experts, no shared expert
[hf:microsoft/Phi-3.5-MoE-instruct].
"""

from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(
        num_experts=16,
        num_shared_experts=0,
        top_k=2,
        expert_d_ff=6400,
        capacity_factor=1.25,
        first_k_dense=0,
    ),
    rope_theta=10000.0,
    notes="16e top-2 SwiGLU experts; all layers MoE.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="phi3.5-moe-42b-a6.6b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    moe=MoEConfig(
        num_experts=4,
        num_shared_experts=0,
        top_k=2,
        expert_d_ff=64,
        capacity_factor=1.5,
        first_k_dense=0,
    ),
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
