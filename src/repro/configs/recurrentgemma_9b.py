"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.

Griffin recipe [arXiv:2402.19427] (assignment marks the entry unverified; we
implement the published Griffin/RecurrentGemma recipe): repeating block pattern
(rec, rec, attn) — 2 RG-LRU recurrent blocks per local-attention block, local
window 2048, conv1d width 4, lru_width = d_model, GeGLU MLP.
38 layers = 12 full (rec, rec, attn) groups + 2 trailing rec blocks.
Bounded state => supports the long_500k cell.
"""

from repro.configs.base import GriffinConfig, ModelConfig, register

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="griffin",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_kind="geglu",
    griffin=GriffinConfig(lru_width=0, conv_width=4, window=2048,
                          pattern=("rec", "rec", "attn"), c=8.0),
    rope_theta=10000.0,
    supports_long_context=True,
    # §Perf: same batch-over-model override as rwkv6 — the RG-LRU scan and
    # conv halos become device-local; local attention runs over full seq with
    # batch fully sharded.
    sharding_overrides={
        "train": {
            # batch takes the model axis when it divides (single-pod: fully
            # local recurrence); otherwise the size-aware resolver leaves
            # model free and seq_act claims it (multi-pod: state-passing CP)
            "batch": ("pod", "data", "model"),
            "seq_act": ("model",),
            "seq": ("model",),
        },
    },
    notes="RG-LRU + local attention 1:2; O(1) recurrent state + 2048-window KV.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="recurrentgemma-9b-smoke",
    num_layers=4,              # (rec, rec, attn, rec)
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    griffin=GriffinConfig(lru_width=0, conv_width=4, window=32,
                          pattern=("rec", "rec", "attn"), c=8.0),
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
