"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.

MLA (kv_lora_rank=512, no query compression) + MoE: 64 routed experts top-6 and
2 shared experts [arXiv:2405.04434].  The assignment line mentions both
"MoE 64e top-6" and "2 shared+160 routed"; the published V2-Lite config is
64 routed + 2 shared, which matches "64e" and the HF checkpoint — used here
(see DESIGN.md §7).  Layer 0 uses a dense MLP (first_k_dense_replace=1,
intermediate size 10944 per HF config).
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,          # MLA: all heads share the compressed latent
    head_dim=128,             # v_head_dim / qk_nope_head_dim
    d_ff=10944,               # dense (first_k_dense) MLP width
    vocab_size=102400,
    use_mla=True,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        expert_d_ff=1408,
        capacity_factor=1.25,
        first_k_dense=1,
    ),
    rope_theta=10000.0,
    notes="MLA compressed-KV cache at decode; EP over the model axis.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-v2-lite-16b-smoke",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    mla=MLAConfig(
        kv_lora_rank=32,
        q_lora_rank=0,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    moe=MoEConfig(
        num_experts=4,
        num_shared_experts=1,
        top_k=2,
        expert_d_ff=64,
        capacity_factor=1.5,
        first_k_dense=1,
    ),
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
