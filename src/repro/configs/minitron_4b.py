"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.

Pruned Nemotron [arXiv:2407.14679]: squared-ReLU (non-gated) MLP, no biases.
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_kind="relu2",
    rope_theta=10000.0,
    notes="Nemotron-style squared-ReLU MLP (non-gated).",
)

SMOKE_CONFIG = CONFIG.replace(
    name="minitron-4b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
