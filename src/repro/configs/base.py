"""Model / shape / run configuration for the repro framework.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (full size, exact values from the assignment) and a
``SMOKE_CONFIG`` (same family, tiny dims) used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Literal

Family = Literal["dense", "moe", "rwkv6", "griffin", "encdec"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0            # per-expert hidden width
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # leading layers that use a dense MLP
    seq_groups: int = 16            # seq chunks per sequence for dispatch
                                    # grouping (aligns groups with the
                                    # model-axis activation sharding)
    router_aux_coef: float = 0.001  # load-balance loss coefficient
    router_z_coef: float = 0.0001   # router z-loss coefficient


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no query compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class GriffinConfig:
    lru_width: int = 0              # 0 => d_model
    conv_width: int = 4
    window: int = 2048              # local-attention window
    pattern: tuple[str, ...] = ("rec", "rec", "attn")  # repeating block types
    c: float = 8.0                  # RG-LRU decay sharpness


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    ddlerp_rank: int = 32           # token-shift LoRA rank
    decay_rank: int = 64            # decay LoRA rank
    gate_rank: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # encoder-decoder
    num_encoder_layers: int = 0
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()      # (t, h, w) halves; empty = 1-D RoPE
    use_mla: bool = False
    mla: MLAConfig = field(default_factory=MLAConfig)
    # MoE
    moe: MoEConfig = field(default_factory=MoEConfig)
    # griffin / rwkv
    griffin: GriffinConfig = field(default_factory=GriffinConfig)
    rwkv: RWKVConfig = field(default_factory=RWKVConfig)
    # misc
    mlp_kind: Literal["swiglu", "relu2", "geglu"] = "swiglu"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    vocab_pad_to: int = 256          # pad embedding/unembed rows (Megatron-style)
    tie_embeddings: bool = False
    scale_emb: float = 1.0           # MiniCPM embedding scale
    scale_depth: float = 0.0         # MiniCPM residual scale (0 = off)
    dim_model_base: int = 0          # MiniCPM logit scaling base (0 = off)
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    input_kind: Literal["tokens", "embeds", "embeds_mrope"] = "tokens"
    # implementation knobs (hillclimb surface)
    attn_impl: Literal["naive", "chunked", "pallas"] = "chunked"
    kernels_impl: Literal["xla", "pallas", "pallas_interpret"] = "xla"
    # "xla": pure-jnp paths (CPU dry-run/tests); "pallas": TPU kernels for
    # wkv6 / rglru (flash attention selects via attn_impl="pallas")
    attn_kv_chunk: int = 1024
    remat: Literal["none", "full", "dots"] = "full"
    scan_unroll: bool = False        # python-loop layers (used by cost probes)
    logits_chunk: int = 512          # sequence-chunked cross-entropy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # per-arch logical-axis rule overrides (merged over DEFAULT_RULES)
    sharding_overrides: dict[str, tuple[str, ...] | None] = field(default_factory=dict)
    # which shape cells are applicable (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False
    notes: str = ""

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def lru_width(self) -> int:
        return self.griffin.lru_width or self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, "ArchEntry"] = {}


@dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    smoke_config: ModelConfig


def register(config: ModelConfig, smoke_config: ModelConfig) -> None:
    _REGISTRY[config.name] = ArchEntry(config, smoke_config)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return entry.smoke_config if smoke else entry.config


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(s)
    return out


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    for mod in (
        "rwkv6_3b",
        "qwen2_0_5b",
        "minitron_4b",
        "minicpm_2b",
        "qwen3_14b",
        "deepseek_v2_lite_16b",
        "phi35_moe_42b",
        "seamless_m4t_large_v2",
        "recurrentgemma_9b",
        "qwen2_vl_7b",
    ):
        importlib.import_module(f"repro.configs.{mod}")
