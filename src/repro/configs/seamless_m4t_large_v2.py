"""seamless-m4t-large-v2 [audio] — enc-dec, d_model=1024 16H d_ff=8192 vocab=256206.

Encoder-decoder multimodal backbone [arXiv:2308.11596].  "24L" is read as
24 encoder + 24 decoder layers (the checkpoint's speech-encoder/text-decoder
depths; DESIGN.md §7).  The audio frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings for the encoder.
LayerNorm + GeGLU per the m4t family; RoPE replaces the original positional
scheme for uniformity (noted in DESIGN.md §7).
"""

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,             # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_kind="geglu",
    norm_kind="layernorm",
    rope_theta=10000.0,
    input_kind="embeds",       # encoder consumes precomputed audio-frame embeddings
    notes="enc-dec; audio frontend stubbed with frame embeddings.",
)

SMOKE_CONFIG = CONFIG.replace(
    name="seamless-m4t-large-v2-smoke",
    num_layers=2,
    num_encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    attn_kv_chunk=32,
    logits_chunk=16,
)

register(CONFIG, SMOKE_CONFIG)
