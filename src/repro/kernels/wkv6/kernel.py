"""WKV6 (RWKV-6 "Finch") Pallas TPU kernel.

TPU adaptation of the per-token CUDA recurrence (DESIGN.md §5): the sequence
is processed in VMEM chunks of C tokens; within a chunk the pairwise
contributions are *matmul form* (C x C score and C x V output products on the
MXU); across chunks only the [K, V] matrix state is carried, living in a
revisited output block that doubles as the final-state output.

Numerics: the intra-chunk pair term uses exact per-channel decay differences
(exponents are always <= 0, so extreme decay only underflows to zero) — this
kernel is bit-faithful to the sequential oracle, unlike the XLA batch path
(scan_utils.wkv6_chunked), whose matmul form requires a documented log-decay
clamp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CLAMP = 2.0


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # [C, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # [C, V]
    w = w_ref[0].astype(jnp.float32)          # [C, K]
    u = u_ref[0].astype(jnp.float32)          # [K]
    s_in = s_ref[0].astype(jnp.float32)       # [K, V]

    lw = jnp.minimum(jnp.log(jnp.maximum(w, 1e-37)), -1e-6)
    cum = jnp.cumsum(lw, axis=0)              # inclusive  [C, K]
    cum_prev = cum - lw

    # intra-chunk pair scores, exact per-channel decay: the exponent
    # cum_prev[i] - cum[s] is <= 0 for s < i, so only graceful underflow —
    # the [C, C, K] tile lives entirely in VMEM (no clamp needed here,
    # unlike the XLA batch path)
    diff = cum_prev[:, None, :] - cum[None, :, :]     # [C, C, K]
    pair = jnp.sum(
        r[:, None, :] * k[None, :, :] * jnp.exp(jnp.minimum(diff, 0.0)),
        axis=-1,
    )                                                  # [C, C]
    ri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ci < ri, pair, 0.0)    # strictly lower triangular
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # bonus term  [C]
    qp = r * jnp.exp(cum_prev)                # decayed queries (exp <= 1)

    y = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(
        qp, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S' = diag(prod w) S + sum_s decay(s->end) k_s v_s^T
    a_tot = jnp.exp(cum[-1])                   # [K]
    k_dec = k * jnp.exp(cum[-1][None, :] - cum)
    s_new = a_tot[:, None] * s_in + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(
    r: jax.Array,  # [BH, T, K]
    k: jax.Array,
    v: jax.Array,  # [BH, T, V]
    w: jax.Array,  # [BH, T, K]
    u: jax.Array,  # [BH, K]
    chunk: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    BH, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    y, s = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, K), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, K, V), lambda b, j: (b, 0, 0)),  # revisited state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), r.dtype),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s
