"""Dispatching wrapper for the WKV6 kernel ([B,T,H,*] model layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_ref


def wkv6(
    r: jax.Array,  # [B, T, H, K]
    k: jax.Array,
    v: jax.Array,  # [B, T, H, V]
    w: jax.Array,
    u: jax.Array,  # [H, K]
    *,
    chunk: int = 32,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,V], final_state [B,H,K,V]).

    impls: "xla" = chunked batch path (scan_utils — clamped decay, fast under
    GSPMD); "ref" = exact sequential oracle; "pallas"/"pallas_interpret" =
    the exact TPU kernel."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    B, T, H, K = r.shape
    V = v.shape[-1]
    if impl == "xla":
        from repro.models.scan_utils import wkv6_chunked

        y, s = wkv6_chunked(r, k, v, w, u)
        return y, s
    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, -1)
    if impl == "ref":
        y, s = wkv6_ref(
            to_bh(r), to_bh(k), to_bh(v), to_bh(w),
            jnp.tile(u, (B, 1)),
        )
    else:
        y, s = wkv6_pallas(
            to_bh(r), to_bh(k), to_bh(v), to_bh(w),
            jnp.tile(u, (B, 1)),
            chunk=chunk, interpret=(impl == "pallas_interpret"),
        )
    y = y.reshape(B, H, T, V).transpose(0, 2, 1, 3)
    return y, s.reshape(B, H, K, V)
