"""Exact sequential oracle for the WKV6 kernel (per-token recurrence)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(
    r: jax.Array,  # [BH, T, K]
    k: jax.Array,  # [BH, T, K]
    v: jax.Array,  # [BH, T, V]
    w: jax.Array,  # [BH, T, K] decay in (0, 1)
    u: jax.Array,  # [BH, K] bonus
    state: jax.Array | None = None,  # [BH, K, V]
) -> tuple[jax.Array, jax.Array]:
    BH, T, K = r.shape
    V = v.shape[-1]
    s0 = state.astype(jnp.float32) if state is not None else jnp.zeros((BH, K, V), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # [BH, K/V]
        kv = kt[..., :, None] * vt[..., None, :]
        yt = jnp.einsum("bk,bkv->bv", rt, s + u[..., :, None] * kv)
        return wt[..., None] * s + kv, yt

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
