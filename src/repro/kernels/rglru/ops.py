"""Dispatching wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.rglru.kernel import rglru_pallas
from repro.kernels.rglru.ref import rglru_ref


def rglru_scan(a, b, h0=None, *, chunk: int = 32, impl: str = "auto"):
    """h_t = a_t * h_{t-1} + b_t; returns (h [B,T,W], h_last [B,W]).

    impls: "xla" = two-level associative scan (scan_utils, GSPMD-friendly);
    "ref" = sequential oracle; "pallas"/"pallas_interpret" = TPU kernel."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        from repro.models.scan_utils import lru_scan

        return lru_scan(a, b, h0)
    if impl == "ref":
        return rglru_ref(a, b, h0)
    if h0 is not None:
        raise NotImplementedError("pallas rglru path starts from zero state")
    return rglru_pallas(a, b, chunk=chunk, interpret=(impl == "pallas_interpret"))
