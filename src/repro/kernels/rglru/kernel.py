"""RG-LRU Pallas TPU kernel: gated diagonal linear recurrence.

h_t = a_t * h_{t-1} + b_t, elementwise over the width dim.  Grid
(batch, width-blocks, chunks) with the chunk dim innermost; the [Wb] state
lives in a revisited output block.  Within a chunk the recurrence is exact:
cumulative decay products (linear space — a in (0,1), underflow is graceful)
plus a decay-weighted prefix sum, all VPU elementwise/cumsum ops on
[C, Wb] VMEM tiles.

    h_i = A_i * h_in + A_i * sum_{s<=i} b_s / A_s,   A_i = prod_{j<=i} a_j

For stability the division is computed as exp(log-space difference) with the
same clamp scheme as the WKV6 kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CLAMP = 2.0


def _rglru_kernel(a_ref, b_ref, y_ref, s_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[0].astype(jnp.float32)   # [C, Wb]
    b = b_ref[0].astype(jnp.float32)
    h_in = s_ref[0].astype(jnp.float32)  # [Wb]

    la = jnp.clip(jnp.log(jnp.maximum(a, 1e-37)), -CLAMP, 0.0)
    cum = jnp.cumsum(la, axis=0)       # [C, Wb] inclusive log decay
    A = jnp.exp(cum)
    # prefix = sum_{s<=i} exp(cum_i - cum_s) * b_s  computed stably:
    z = b * jnp.exp(-cum)
    h = A * (h_in[None, :] + jnp.cumsum(z, axis=0))
    y_ref[0] = h.astype(y_ref.dtype)
    s_ref[0] = h[-1]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_pallas(
    a: jax.Array,  # [B, T, W]
    b: jax.Array,
    chunk: int = 32,
    interpret: bool = False,
    block_w: int = 512,
) -> tuple[jax.Array, jax.Array]:
    B, T, W = a.shape
    assert T % chunk == 0, (T, chunk)
    wb = min(block_w, W)
    assert W % wb == 0, (W, wb)
    nc = T // chunk
    nw = W // wb

    y, s = pl.pallas_call(
        _rglru_kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, wb), lambda bi, wi, j: (bi, j, wi)),
            pl.BlockSpec((1, chunk, wb), lambda bi, wi, j: (bi, j, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, wb), lambda bi, wi, j: (bi, j, wi)),
            pl.BlockSpec((1, wb), lambda bi, wi, j: (bi, wi)),  # revisited state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return y, s
