"""Exact sequential oracle for the RG-LRU diagonal recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(
    a: jax.Array,  # [B, T, W] decay in (0, 1)
    b: jax.Array,  # [B, T, W] input
    h0: jax.Array | None = None,  # [B, W]
) -> tuple[jax.Array, jax.Array]:
    B, T, W = a.shape
    h = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((B, W), jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h_fin
