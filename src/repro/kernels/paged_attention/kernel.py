"""Paged-attention decode Pallas TPU kernel (block-table walk, no gather).

Decode attention for ``S`` serving slots directly against the physical KV
block pool: no dense ``[S, max_len, ...]`` view is ever materialized.  Layout:

    q       [S, H, dh] or [S, Q, H, dh]   Q query tokens per slot (Q > 1 is
                                          the speculative-decoding verify step)
    k_pool  [(n_layers,) num_blocks, bs, K, dh]   the physical pool
    v_pool  [(n_layers,) num_blocks, bs, K, dv]   (see PagedKVCache)
    tables  [S, M] int32          per-slot block tables (padding -> null 0)
    kv_len  [S] int32             live positions per slot (incl. all Q tokens)
    layer   scalar int32          pool layer for the 5-D layer-stacked layout
                                  (rides scalar prefetch into the index maps,
                                  so the stacked pool is never sliced in HBM)

Grid ``(slot, table-entry)`` with the table walk innermost/sequential; the
``tables`` and ``kv_len`` arrays ride scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps resolve
``tables[s, j]`` *before* the body runs and each step DMAs exactly one
physical block out of the pool.  All KV heads of a block are fetched in one
block (grid iterates table entries, not kv-heads: each block is touched once
per slot instead of once per head) and the GQA head arithmetic happens
in-register on the ``[Q, K, G, dh]`` reshaped query.  The Q query rows share
every fetched K/V block: multi-token verification costs the same HBM traffic
as single-token decode.

Online softmax state (running max / denominator / unnormalized accumulator)
lives in revisited output blocks whose index maps ignore ``j`` — VMEM-resident
across the sweep, normalized in place on the last step (the same pattern as
``flash_attention``).

Causal masking inside the query block: query ``i`` (0-based of Q) sits at
absolute position ``kv_len - Q + i`` and attends keys
``< kv_len - (Q - 1 - i)``; the window low bound shifts per query the same
way.  At Q = 1 both collapse to the plain decode masks.

Early exit: entries at or past a slot's last live block — and, for windowed
attention, entries wholly before the *oldest* query's window reach —
contribute nothing: ``pl.when`` skips their compute *and* the index map clamps
onto the live range so the pipeline re-fetches a resident block instead of
streaming dead pool blocks.  Per-slot HBM traffic is therefore O(kv_len)
(O(window + Q) for windowed families), not O(max_len); the caller is still
free to slice ``tables`` down to the live-block high-water mark so the grid
itself shrinks too.

(The pool keeps the model's trailing ``[K, dh]`` feature layout, so a K/V
block tile is ``(bs, K, dh)`` with the small kv-head dim second-to-last —
suboptimal TPU sublane tiling for tiny K, traded for gather/scatter-free
interop with the serving cache pytree.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _paged_kernel(
    tbl_ref, len_ref, lay_ref,     # scalar-prefetch: tables [S,M], kv_len [S],
    q_ref, k_ref, v_ref,           #   layer [1]; then q [1, Q*H, dh] and the
    o_ref, m_ref, l_ref,           #   K/V blocks [1, 1, bs, K, d*]; outputs
    *, scale: float, window: int | None, block_size: int,
    n_kv: int, q_per_kv: int, q_len: int,
):
    s = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    kvl = len_ref[s]
    K, G, Q = n_kv, q_per_kv, q_len

    # early exit: skip table entries past the last live position, and — for
    # windowed attention — entries wholly before the oldest query's reach
    live = j * block_size < kvl
    if window is not None:
        live &= j * block_size + block_size > kvl - (Q - 1) - window

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32).reshape(Q, K, G, -1)
        kb = k_ref[0, 0].astype(jnp.float32)                 # [bs, K, dh]
        vb = v_ref[0, 0].astype(jnp.float32)                 # [bs, K, dv]
        sc = jnp.einsum(
            "qkgd,bkd->qkgb", q, kb, preferred_element_type=jnp.float32
        ) * scale                                            # [Q, K, G, bs]

        pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, 1, block_size), 3
        )
        # per-query causal limit: query i attends keys < kvl - (Q - 1 - i)
        limit = kvl - (Q - 1) + jax.lax.broadcasted_iota(
            jnp.int32, (Q, 1, 1, 1), 0
        )
        mask = pos < limit
        if window is not None:
            mask &= pos > limit - 1 - window
        sc = jnp.where(mask, sc, NEG)

        m_prev = m_ref[0].reshape(Q, K, G)
        l_prev = l_ref[0].reshape(Q, K, G)
        m_new = jnp.maximum(m_prev, sc.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * corr + p.sum(-1)
        acc = o_ref[0].astype(jnp.float32).reshape(Q, K, G, -1) * corr[..., None]
        acc = acc + jnp.einsum(
            "qkgb,bkv->qkgv", p, vb, preferred_element_type=jnp.float32
        )
        m_ref[0] = m_new.reshape(Q * K * G)
        l_ref[0] = l_new.reshape(Q * K * G)
        # o_ref is f32: re-quantizing the running accumulator through the
        # model dtype every block step would compound bf16 rounding over
        # long kv_lens and drift off the gathered-dense oracle
        o_ref[0] = acc.reshape(Q * K * G, -1)

    @pl.when(j == nj - 1)
    def _normalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o_ref[0] / denom[:, None]


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "interpret")
)
def paged_attention_pallas(
    q: jax.Array,        # [S, H, dh] or [S, Q, H, dh]
    k_pool: jax.Array,   # [(n,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n,) num_blocks, bs, K, dv]
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32
    *,
    scale: float,
    window: int | None = None,
    interpret: bool = False,
    layer: jax.Array | None = None,  # indexes layer-stacked 5-D pools
) -> jax.Array:
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    S, Q, H, dh = q.shape
    if k_pool.ndim == 4:  # single-layer pool: lift to the stacked layout
        k_pool, v_pool = k_pool[None], v_pool[None]
        layer = jnp.zeros((), jnp.int32)
    _, _, bs, K, dv = v_pool.shape
    M = tables.shape[1]
    G = H // K
    assert K * G == H, (H, K)
    tables = tables.astype(jnp.int32)
    kv_len = kv_len.astype(jnp.int32)
    lay = jnp.asarray(layer, jnp.int32).reshape(1)
    # the Q query rows ride the row axis of one block: every fetched K/V
    # block is scored against all of them at once
    qf = q.reshape(S, Q * H, dh)

    def kv_map(s, j, tbl, kvl, lay):
        # clamp dead entries onto the live range [first, last]: same index as
        # an adjacent step -> the pipeline skips the DMA instead of streaming
        # blocks the body would ignore anyway (past the last live position,
        # or — for windowed attention — wholly before the window's reach)
        last = jnp.maximum(kvl[s] - 1, 0) // bs
        jj = jnp.minimum(j, last)
        if window is not None:
            first = jnp.maximum(kvl[s] - (Q - 1) - window, 0) // bs
            jj = jnp.maximum(jj, jnp.minimum(first, last))
        return (lay[0], tbl[s, jj], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, M),
        in_specs=[
            pl.BlockSpec((1, Q * H, dh), lambda s, j, tbl, kvl, lay: (s, 0, 0)),
            pl.BlockSpec((1, 1, bs, K, dh), kv_map),
            pl.BlockSpec((1, 1, bs, K, dv), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, Q * H, dv), lambda s, j, tbl, kvl, lay: (s, 0, 0)),
            pl.BlockSpec((1, Q * H), lambda s, j, tbl, kvl, lay: (s, 0)),
            pl.BlockSpec((1, Q * H), lambda s, j, tbl, kvl, lay: (s, 0)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_kernel, scale=scale, window=window, block_size=bs,
            n_kv=K, q_per_kv=G, q_len=Q,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, Q * H, dv), jnp.float32),
            jax.ShapeDtypeStruct((S, Q * H), jnp.float32),
            jax.ShapeDtypeStruct((S, Q * H), jnp.float32),
        ],
        interpret=interpret,
    )(tables, kv_len, lay, qf, k_pool, v_pool)
    o = out[0].reshape(S, Q, H, dv).astype(q.dtype)
    return o[:, 0] if squeeze else o
