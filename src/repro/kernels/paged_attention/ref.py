"""XLA reference / fallback for the paged-attention decode kernel.

Gathers each slot's live blocks out of the pool (``k_pool[tables]`` — table
width, not pool size, bounds the traffic) and mirrors the naive masked-softmax
decode attention in ``models.layers.attention`` operation-for-operation: same
einsum labels, same ``BIG_NEG`` masking, same ``p.astype(v.dtype)`` cast, same
f32 accumulation.  Padding positions get exactly-zero probabilities, so the
output is invariant to the table width — which makes this both the interpret-
mode parity oracle for ``kernel.py`` and the serving fast path on non-TPU
backends (the caller slices ``tables`` to the live-block high-water mark, so
cost tracks kv_len, not pool max_len).

``q`` may carry more than one query per slot (``[S, Q, H, dh]``): the
speculative-decoding verify step scores Q = draft_len + 1 positions per slot
in one call.  Query ``i`` (0-based) sits at absolute position
``kv_len - Q + i`` and therefore attends keys ``< kv_len - (Q - 1 - i)`` —
causal masking *inside* the query block; at Q = 1 this degenerates to the
plain decode mask.  The window mask shifts per query the same way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def paged_attention_ref(
    q: jax.Array,        # [S, H, dh] or [S, Q, H, dh]
    k_pool: jax.Array,   # [(n,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n,) num_blocks, bs, K, dv]
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32, live positions incl. all Q new tokens
    *,
    scale: float,
    window: int | None = None,
    layer: jax.Array | None = None,  # indexes layer-stacked 5-D pools
) -> jax.Array:
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    S, Q, H, dh = q.shape
    bs, K, dv = v_pool.shape[-3:]
    M = tables.shape[1]
    G = H // K
    flat = tables.reshape(-1)
    if k_pool.ndim == 5:
        # one fused (layer, block) gather — never materializes a layer slice
        k = k_pool[layer, flat]
        v = v_pool[layer, flat]
    else:
        k = jnp.take(k_pool, flat, axis=0)
        v = jnp.take(v_pool, flat, axis=0)
    k = k.reshape(S, M * bs, K, dh).astype(q.dtype)
    v = v.reshape(S, M * bs, K, dv).astype(q.dtype)

    qg = q.reshape(S, Q, K, G, dh)
    s = jnp.einsum(
        "bskgd,btkd->bskgt", qg, k, preferred_element_type=jnp.float32
    ) * scale                                              # [S, Q, K, G, T]
    pos = jnp.arange(M * bs)[None, None, :]                # key positions
    # per-query causal limit: query i attends keys < kv_len - (Q - 1 - i)
    limit = kv_len[:, None] - (Q - 1 - jnp.arange(Q))[None, :]  # [S, Q]
    mask = pos < limit[:, :, None]
    if window is not None:
        mask &= pos > limit[:, :, None] - 1 - window
    s = jnp.where(mask[:, :, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bskgt,btkd->bskgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(S, Q, H, dv).astype(q.dtype)
    return o[:, 0] if squeeze else o


def paged_prefill_ref(
    q: jax.Array,        # [S, Q, H, dh], already normed + roped
    k_pool: jax.Array,   # [(n,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n,) num_blocks, bs, K, dv]
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32, live positions incl. all Q new tokens
    *,
    scale: float,
    window: int | None = None,
    layer: jax.Array | None = None,
    q_start: int | None = None,  # static absolute position of query 0 (all
                                 # slots); unlocks the causal band
    q_block: int = 32,
) -> jax.Array:
    """Banded q-block oracle for the flash-prefill kernel (`prefill_kernel`).

    Splits the Q query rows into static q-blocks and scores each against
    only the table prefix its causal reach can see: with ``q_start`` known
    (the full-prefill step pins query 0 at absolute position 0), q-block
    ``iq`` gathers ``ceil((q_start + (iq+1)*QB) / bs)`` table entries — the
    lower-triangular band, ~half the dense quadratic gather.  Without a
    static start (chunk/verify calls, where cache_len is traced) every block
    sees the full table width and per-query limits alone carry causality.

    Exactness of the banding: every excluded key position lies at or above
    the block's highest causal limit, so in the full computation its masked
    score contributes an exactly-zero probability (``exp(NEG - m)``
    underflows in f32) — banding changes the result only through XLA's
    reduction-tree order (f32 ulp-level), never through which keys count.

    Each band delegates to :func:`paged_attention_ref` with the kv_len
    shifted to the block's top query (``kv_len - (Q - (iq+1)*QB)``), which
    reproduces the per-query limits ``kv_len - (Q - 1 - i)`` of the full
    call, window masks included.
    """
    S, Q, H, dh = q.shape
    bs = v_pool.shape[-3]
    M = tables.shape[1]
    qb = q_block if (q_block and Q % q_block == 0) else Q
    qb = min(qb, Q)
    outs = []
    for iq in range(Q // qb):
        hi = None if q_start is None else q_start + (iq + 1) * qb
        reach = M if hi is None else max(1, min(M, -(-hi // bs)))
        outs.append(paged_attention_ref(
            q[:, iq * qb:(iq + 1) * qb],
            k_pool, v_pool, tables[:, :reach],
            kv_len - (Q - (iq + 1) * qb),
            scale=scale, window=window, layer=layer,
        ))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
