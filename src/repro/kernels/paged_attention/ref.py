"""XLA reference / fallback for the paged-attention decode kernel.

Gathers each slot's live blocks out of the pool (``k_pool[tables]`` — table
width, not pool size, bounds the traffic) and mirrors the naive masked-softmax
decode attention in ``models.layers.attention`` operation-for-operation: same
einsum labels, same ``BIG_NEG`` masking, same ``p.astype(v.dtype)`` cast, same
f32 accumulation.  Padding positions get exactly-zero probabilities, so the
output is invariant to the table width — which makes this both the interpret-
mode parity oracle for ``kernel.py`` and the serving fast path on non-TPU
backends (the caller slices ``tables`` to the live-block high-water mark, so
cost tracks kv_len, not pool max_len).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def paged_attention_ref(
    q: jax.Array,        # [S, H, dh]
    k_pool: jax.Array,   # [(n,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n,) num_blocks, bs, K, dv]
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32
    *,
    scale: float,
    window: int | None = None,
    layer: jax.Array | None = None,  # indexes layer-stacked 5-D pools
) -> jax.Array:
    S, H, dh = q.shape
    bs, K, dv = v_pool.shape[-3:]
    M = tables.shape[1]
    G = H // K
    flat = tables.reshape(-1)
    if k_pool.ndim == 5:
        # one fused (layer, block) gather — never materializes a layer slice
        k = k_pool[layer, flat]
        v = v_pool[layer, flat]
    else:
        k = jnp.take(k_pool, flat, axis=0)
        v = jnp.take(v_pool, flat, axis=0)
    k = k.reshape(S, M * bs, K, dh).astype(q.dtype)
    v = v.reshape(S, M * bs, K, dv).astype(q.dtype)

    qg = q.reshape(S, 1, K, G, dh)
    s = jnp.einsum(
        "bskgd,btkd->bskgt", qg, k, preferred_element_type=jnp.float32
    ) * scale                                              # [S, 1, K, G, T]
    pos = jnp.arange(M * bs)[None, :]
    mask = pos < kv_len[:, None]
    if window is not None:
        mask &= pos > kv_len[:, None] - 1 - window
    s = jnp.where(mask[:, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bskgt,btkd->bskgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(S, H, dv).astype(q.dtype)
