from repro.kernels.paged_attention.ops import PagedInfo, paged_attention
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = [
    "PagedInfo",
    "paged_attention",
    "paged_attention_pallas",
    "paged_attention_ref",
]
