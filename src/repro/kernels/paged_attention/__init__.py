from repro.kernels.paged_attention.ops import (
    PagedInfo,
    paged_attention,
    paged_prefill,
)
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.prefill_kernel import paged_prefill_pallas
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_prefill_ref,
)

__all__ = [
    "PagedInfo",
    "paged_attention",
    "paged_attention_pallas",
    "paged_attention_ref",
    "paged_prefill",
    "paged_prefill_pallas",
    "paged_prefill_ref",
]
