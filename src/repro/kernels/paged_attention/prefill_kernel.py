"""Flash-prefill Pallas TPU kernel over the paged KV pool (q-block x kv-block).

One kernel for every q_len > 1 attention the serving engine runs — full
prefill, chunked prefill, and the Q = spec_k + 1 speculative verify step —
reading K/V *directly from physical pool blocks via the block table* exactly
like the decode kernel (`kernel.py`), but tiling the query axis too:

    grid (slot, q-block, table-entry)       table walk innermost/sequential

Layout matches the decode kernel:

    q       [S, Q, H, dh]   RAW post-projection queries (pre-norm, pre-rope)
    k_pool  [(n_layers,) num_blocks, bs, K, dh]
    v_pool  [(n_layers,) num_blocks, bs, K, dv]
    tables  [S, M] int32    per-slot block tables (padding -> null block 0)
    kv_len  [S] int32       live positions per slot incl. all Q new tokens
    layer   scalar int32    layer index for the 5-D layer-stacked pool layout

Fused q prologue: the rmsnorm (qwen3 ``qk_norm``) + rope entry into attention
is computed *inside the kernel* once per (slot, q-block) — at the first table
step the raw query tile is normalized, rotated with positions derived
in-kernel (query ``i`` of ``Q`` sits at absolute position ``kv_len - Q + i``,
so ``pos = kv_len - Q + q_block_lo + iota``), requantized through the model
dtype (bit-matching the jnp ``rms_head_norm``/``apply_rope`` chain, which
round-trips through ``x.dtype`` between the two), and parked in a VMEM
scratch tile that the whole kv sweep then reuses.  Prefill stops paying the
separate norm -> rope -> attention HBM round-trips of the generic path.

Causality is *per query inside the block*: query ``i`` attends keys
``< kv_len - (Q - 1 - i)`` (the decode kernel's verify mask, generalized by
the q-block offset), which at Q = full prompt length is plain causal prefill
and at Q = spec_k + 1 is the verify step.  The window mask shifts per query
the same way.

Early exit mirrors the decode kernel and adds the *causal upper clamp*: table
entries wholly above a q-block's highest query — the upper triangle of the
(q-block, kv-block) grid — are skipped by ``pl.when`` and their index maps
clamp onto the live band, so the pipeline never DMAs a block the masks would
zero out anyway.  Per-(slot, q-block) HBM traffic is O(causal reach), i.e.
full prefill costs ~half the dense quadratic sweep and chunked prefill costs
O(kv_len) not O(bucket ceiling).

Online-softmax state (running max / denominator / unnormalized accumulator)
lives in revisited output blocks indexed (slot, q-block) whose maps ignore
the table step — VMEM-resident across the sweep, normalized in place on the
last step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _prefill_kernel(
    tbl_ref, len_ref, lay_ref,     # scalar-prefetch: tables [S,M], kv_len [S],
    q_ref, qs_ref, k_ref, v_ref,   #   layer [1]; q tile [1, QB*H, dh], q_norm
    o_ref, m_ref, l_ref,           #   scale [1, dh], K/V blocks [1,1,bs,K,d*]
    q_vmem,                        # scratch: prepared f32 q tile [QB*H, dh]
    *, scale: float, window: int | None, block_size: int,
    n_kv: int, q_per_kv: int, q_len: int, q_blk: int,
    has_qnorm: bool, eps: float, rope_theta: float,
):
    s = pl.program_id(0)
    iq = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    kvl = len_ref[s]
    K, G, Q, QB = n_kv, q_per_kv, q_len, q_blk
    qlo = iq * QB
    dh = q_ref.shape[-1]
    half = dh // 2

    @pl.when(j == 0)
    def _prologue():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)
        # fused entry: rmsnorm (optional) + rope on the raw query tile, once
        # per (slot, q-block); requantize through the model dtype after each
        # stage so the result bit-matches the jnp rms_head_norm/apply_rope
        # chain (each returns x.dtype) feeding the generic attention path
        x = q_ref[0].astype(jnp.float32)                     # [QB*H, dh]
        if has_qnorm:
            var = (x * x).mean(-1, keepdims=True)
            x = x * jax.lax.rsqrt(var + eps) * qs_ref[0].astype(jnp.float32)
            x = x.astype(q_ref.dtype).astype(jnp.float32)
        # rope angles from in-kernel positions: query i at kvl - Q + qlo + i
        xq = x.reshape(QB, K * G, dh)
        io2 = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
        freqs = 1.0 / (rope_theta ** ((2.0 * io2) / dh))     # rope_freqs
        pos_q = (kvl - Q + qlo) + jax.lax.broadcasted_iota(
            jnp.int32, (QB, 1), 0
        )
        ang = pos_q.astype(jnp.float32) * freqs              # [QB, dh/2]
        cos = jnp.cos(ang)[:, None, :]
        sin = jnp.sin(ang)[:, None, :]
        x1, x2 = xq[..., :half], xq[..., half:]
        xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
        xr = xr.astype(q_ref.dtype).astype(jnp.float32)
        q_vmem[...] = xr.reshape(QB * K * G, dh)

    # early exit: skip entries past this q-block's causal reach (upper
    # triangle) or the slot's live range; windowed families also skip entries
    # wholly before the block's oldest query's window
    hi = kvl - Q + qlo + QB          # exclusive key limit of the last query
    live = j * block_size < jnp.minimum(hi, kvl)
    if window is not None:
        live &= j * block_size + block_size > kvl - (Q - 1) + qlo - window

    @pl.when(live)
    def _accumulate():
        q = q_vmem[...].reshape(QB, K, G, -1)
        kb = k_ref[0, 0].astype(jnp.float32)                 # [bs, K, dh]
        vb = v_ref[0, 0].astype(jnp.float32)                 # [bs, K, dv]
        sc = jnp.einsum(
            "qkgd,bkd->qkgb", q, kb, preferred_element_type=jnp.float32
        ) * scale                                            # [QB, K, G, bs]

        pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, 1, block_size), 3
        )
        # per-query causal limit: query qlo+i attends keys
        # < kvl - (Q - 1 - (qlo + i))
        limit = kvl - (Q - 1) + qlo + jax.lax.broadcasted_iota(
            jnp.int32, (QB, 1, 1, 1), 0
        )
        mask = pos < limit
        if window is not None:
            mask &= pos > limit - 1 - window
        sc = jnp.where(mask, sc, NEG)

        m_prev = m_ref[0].reshape(QB, K, G)
        l_prev = l_ref[0].reshape(QB, K, G)
        m_new = jnp.maximum(m_prev, sc.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * corr + p.sum(-1)
        acc = o_ref[0].astype(jnp.float32).reshape(QB, K, G, -1)
        acc = acc * corr[..., None] + jnp.einsum(
            "qkgb,bkv->qkgv", p, vb, preferred_element_type=jnp.float32
        )
        m_ref[0] = m_new.reshape(QB * K * G)
        l_ref[0] = l_new.reshape(QB * K * G)
        o_ref[0] = acc.reshape(QB * K * G, -1)

    @pl.when(j == nj - 1)
    def _normalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o_ref[0] / denom[:, None]


def pick_q_block(q_len: int, q_block: int) -> int:
    """Largest usable q tile: ``q_block`` when it divides ``q_len`` (the
    pow2/bucketed prefill and chunk widths), else the whole query range (the
    Q = spec_k + 1 verify step degenerates to a single q-block)."""
    qb = min(q_block, q_len) if q_block else q_len
    return qb if q_len % qb == 0 else q_len


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "window", "interpret", "eps", "rope_theta", "q_block"
    ),
)
def paged_prefill_pallas(
    q: jax.Array,        # [S, Q, H, dh] raw (pre-norm, pre-rope) queries
    k_pool: jax.Array,   # [(n,) num_blocks, bs, K, dh], new K already written
    v_pool: jax.Array,   # [(n,) num_blocks, bs, K, dv]
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32
    *,
    scale: float,
    window: int | None = None,
    interpret: bool = False,
    layer: jax.Array | None = None,  # indexes layer-stacked 5-D pools
    q_norm: jax.Array | None = None,  # [dh] qk_norm scale (None = no norm)
    eps: float = 1e-6,
    rope_theta: float = 10000.0,
    q_block: int = 32,
) -> jax.Array:
    S, Q, H, dh = q.shape
    if k_pool.ndim == 4:  # single-layer pool: lift to the stacked layout
        k_pool, v_pool = k_pool[None], v_pool[None]
        layer = jnp.zeros((), jnp.int32)
    _, _, bs, K, dv = v_pool.shape
    M = tables.shape[1]
    G = H // K
    assert K * G == H, (H, K)
    QB = pick_q_block(Q, q_block)
    nq = Q // QB
    tables = tables.astype(jnp.int32)
    kv_len = kv_len.astype(jnp.int32)
    lay = jnp.asarray(layer, jnp.int32).reshape(1)
    has_qnorm = q_norm is not None
    qs = (q_norm if has_qnorm else jnp.ones((dh,), q.dtype)).reshape(1, dh)
    # query rows ride the row axis: q-block iq owns rows [iq*QB*H, (iq+1)*QB*H)
    qf = q.reshape(S, Q * H, dh)

    def kv_map(s, iq, j, tbl, kvl, lay):
        # clamp dead entries onto the live causal band [first, lastq]: same
        # index as an adjacent step -> the pipeline skips the DMA instead of
        # streaming blocks the masks would zero (the upper triangle above
        # this q-block's reach, entries past the last live position, and —
        # for windowed attention — entries before the window's reach)
        last = jnp.maximum(kvl[s] - 1, 0) // bs
        hi = kvl[s] - Q + (iq + 1) * QB      # this q-block's causal limit
        lastq = jnp.minimum(jnp.maximum(hi - 1, 0) // bs, last)
        jj = jnp.minimum(j, lastq)
        if window is not None:
            first = jnp.maximum(kvl[s] - (Q - 1) + iq * QB - window, 0) // bs
            jj = jnp.maximum(jj, jnp.minimum(first, lastq))
        return (lay[0], tbl[s, jj], 0, 0, 0)

    def q_map(s, iq, j, tbl, kvl, lay):
        return (s, iq, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(S, nq, M),
        in_specs=[
            pl.BlockSpec((1, QB * H, dh), q_map),
            pl.BlockSpec((1, dh), lambda s, iq, j, tbl, kvl, lay: (0, 0)),
            pl.BlockSpec((1, 1, bs, K, dh), kv_map),
            pl.BlockSpec((1, 1, bs, K, dv), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, QB * H, dv), q_map),
            pl.BlockSpec((1, QB * H), lambda s, iq, j, tbl, kvl, lay: (s, iq)),
            pl.BlockSpec((1, QB * H), lambda s, iq, j, tbl, kvl, lay: (s, iq)),
        ],
        scratch_shapes=[pltpu.VMEM((QB * H, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(
            _prefill_kernel, scale=scale, window=window, block_size=bs,
            n_kv=K, q_per_kv=G, q_len=Q, q_blk=QB, has_qnorm=has_qnorm,
            eps=eps, rope_theta=rope_theta,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((S, Q * H, dv), jnp.float32),
            jax.ShapeDtypeStruct((S, Q * H), jnp.float32),
            jax.ShapeDtypeStruct((S, Q * H), jnp.float32),
        ],
        interpret=interpret,
    )(tables, kv_len, lay, qf, qs, k_pool, v_pool)
    return out[0].reshape(S, Q, H, dv).astype(q.dtype)
