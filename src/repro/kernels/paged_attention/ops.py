"""Dispatching wrapper: model layout <-> kernel layout + the paged-view token.

``PagedInfo`` is the small pytree the serving engine threads through
``lm.forward`` down to ``layers.attention`` to flip a block from the dense
cached path onto the paged pool: the block's cache leaves then *are* pool
arrays ``[num_blocks, bs, *feat]`` and attention walks ``tables`` instead of
a gathered dense view.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class PagedInfo:
    """Paged-KV view descriptor: the per-slot block tables (traced; possibly
    sliced to the live-block high-water mark) plus static pool geometry and
    kernel dispatch choice.

    ``layer``, when set, marks the cache leaves as *whole layer-stacked*
    pools ``[n_layers, num_blocks, bs, *feat]`` indexed at that layer —
    ``lm.forward`` threads the stacked pools through its scan carry (updated
    in place via layer-indexed scatters) instead of slicing them into scan
    xs/ys, which would re-stack the full pool every decode step."""

    tables: jax.Array       # [S, M] int32, padding entries -> null block 0
    block_size: int
    impl: str = "auto"      # auto | xla | pallas | pallas_interpret
    layer: jax.Array | None = None  # scalar layer index into stacked pools

    def tree_flatten(self):
        return (self.tables, self.layer), (self.block_size, self.impl)

    @classmethod
    def tree_unflatten(cls, aux, children):
        tables, layer = children
        return cls(tables, aux[0], aux[1], layer)


def paged_attention(
    q: jax.Array,        # [S, Q, H, dh] (model layout; Q > 1 = spec-decode
                         #   verify) or [S, H, dh] (bare single-token)
    k_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dv]
    *,
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32 (live positions incl. all Q new tokens)
    scale: float,
    window: int | None = None,
    impl: str = "auto",
    layer: jax.Array | None = None,  # required for layer-stacked (5-D) pools
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    fn = paged_attention_ref if impl == "xla" else functools.partial(
        paged_attention_pallas, interpret=(impl == "pallas_interpret")
    )
    return fn(
        q, k_pool, v_pool, tables, kv_len, scale=scale, window=window,
        layer=layer,
    )
