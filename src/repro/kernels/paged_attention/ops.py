"""Dispatching wrapper: model layout <-> kernel layout + the paged-view token.

``PagedInfo`` is the small pytree the serving engine threads through
``lm.forward`` down to ``layers.attention`` to flip a block from the dense
cached path onto the paged pool: the block's cache leaves then *are* pool
arrays ``[num_blocks, bs, *feat]`` and attention walks ``tables`` instead of
a gathered dense view.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.prefill_kernel import paged_prefill_pallas
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_prefill_ref,
)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class PagedInfo:
    """Paged-KV view descriptor: the per-slot block tables (traced; possibly
    sliced to the live-block high-water mark) plus static pool geometry and
    kernel dispatch choice.

    ``layer``, when set, marks the cache leaves as *whole layer-stacked*
    pools ``[n_layers, num_blocks, bs, *feat]`` indexed at that layer —
    ``lm.forward`` threads the stacked pools through its scan carry (updated
    in place via layer-indexed scatters) instead of slicing them into scan
    xs/ys, which would re-stack the full pool every decode step."""

    tables: jax.Array       # [S, M] int32, padding entries -> null block 0
    block_size: int
    impl: str = "auto"      # auto | xla | pallas | pallas_interpret
    layer: jax.Array | None = None  # scalar layer index into stacked pools
    # prefill=True flips attention blocks with seq > 1 onto the fused
    # flash-prefill path (`paged_prefill`): norm+rope+scatter+attention in
    # one op against the pool, instead of the generic dense-cache branch.
    # The decode/verify distinction stays dynamic-free: q_len == 1 keeps the
    # decode kernel regardless.
    prefill: bool = False
    # static absolute position of the first query when uniform across slots
    # (the full-prefill step pins 0): unlocks the causal band in the ref
    # oracle so its gather cost tracks the lower triangle, not the table
    q_start: int | None = None

    def tree_flatten(self):
        return (self.tables, self.layer), (
            self.block_size, self.impl, self.prefill, self.q_start,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        tables, layer = children
        return cls(tables, aux[0], aux[1], layer, *aux[2:])


def paged_attention(
    q: jax.Array,        # [S, Q, H, dh] (model layout; Q > 1 = spec-decode
                         #   verify) or [S, H, dh] (bare single-token)
    k_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dv]
    *,
    tables: jax.Array,   # [S, M] int32
    kv_len: jax.Array,   # [S] int32 (live positions incl. all Q new tokens)
    scale: float,
    window: int | None = None,
    impl: str = "auto",
    layer: jax.Array | None = None,  # required for layer-stacked (5-D) pools
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    fn = paged_attention_ref if impl == "xla" else functools.partial(
        paged_attention_pallas, interpret=(impl == "pallas_interpret")
    )
    return fn(
        q, k_pool, v_pool, tables, kv_len, scale=scale, window=window,
        layer=layer,
    )


def paged_prefill(
    q: jax.Array,        # [S, Q, H, dh] raw post-projection queries
    kk: jax.Array,       # [S, Q, K, dh] raw post-projection keys
    vv: jax.Array,       # [S, Q, K, dv] values
    k_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dh]
    v_pool: jax.Array,   # [(n_layers,) num_blocks, bs, K, dv]
    *,
    tables: jax.Array,   # [S, M] int32
    positions: jax.Array,  # [S, Q] int32 contiguous write positions per slot
    block_size: int,
    scale: float,
    window: int | None = None,
    impl: str = "auto",
    layer: jax.Array | None = None,
    q_norm: jax.Array | None = None,  # [dh] qk_norm scales (None = off)
    k_norm: jax.Array | None = None,
    eps: float = 1e-6,
    rope_theta: float = 10000.0,
    q_start: int | None = None,
    q_block: int = 32,
) -> tuple[jax.Array, dict]:
    """Fused paged prefill: norm+rope the new K, scatter K/V into the pool
    blocks owning each slot's write positions, then flash-attend the Q query
    rows against the pool through the block table — full prefill, chunked
    prefill, and the spec-decode verify step are all this one op at
    different Q.  Returns ``(attn_out, {"k": pool, "v": pool})``.

    The K-side entry (rmsnorm + rope + the bfloat16 quantization into the
    cache container) reuses the model's own helpers so pool contents are
    bit-identical to the generic `gqa_apply` paged branch; the q-side entry
    is fused *inside* the Pallas kernel (or applied with the same helpers on
    the XLA ref path).  Write positions beyond the table's reach redirect to
    the null block, exactly like the decode-step scatter.
    """
    # lazy import: layers imports this module (the dispatch is a leaf of the
    # model stack), so the model-side helpers load on first call only
    from repro.models.layers import apply_rope, rms_head_norm

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"

    if k_norm is not None:
        kk = rms_head_norm(k_norm, kk, eps)
    kk = apply_rope(kk, positions, rope_theta)
    pos = positions
    bs = block_size
    in_reach = pos < tables.shape[1] * bs
    blk = jnp.where(in_reach, pos // bs, 0)
    phys = jnp.take_along_axis(tables, blk, axis=1)          # [S, Q]
    phys = jnp.where(in_reach, phys, 0)
    off = pos % bs
    k_new = kk.astype(jnp.bfloat16).astype(k_pool.dtype)
    v_new = vv.astype(jnp.bfloat16).astype(v_pool.dtype)
    if layer is None:
        ck = k_pool.at[phys, off].set(k_new)
        cv = v_pool.at[phys, off].set(v_new)
    else:  # layer-stacked pools riding lm.forward's scan carry
        ck = k_pool.at[layer, phys, off].set(k_new)
        cv = v_pool.at[layer, phys, off].set(v_new)
    kv_len = pos[:, -1] + 1

    if impl == "xla":
        qq = q if q_norm is None else rms_head_norm(q_norm, q, eps)
        qq = apply_rope(qq, positions, rope_theta)
        o = paged_prefill_ref(
            qq, ck, cv, tables, kv_len, scale=scale, window=window,
            layer=layer, q_start=q_start, q_block=q_block,
        )
    else:
        o = paged_prefill_pallas(
            q, ck, cv, tables, kv_len, scale=scale, window=window,
            interpret=(impl == "pallas_interpret"), layer=layer,
            q_norm=q_norm, eps=eps, rope_theta=rope_theta, q_block=q_block,
        )
    return o, {"k": ck, "v": cv}
