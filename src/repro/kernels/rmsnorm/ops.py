"""Dispatching wrapper: pallas on TPU, interpret-mode pallas or the jnp
reference elsewhere."""

from __future__ import annotations

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x, scale, eps: float = 1e-6, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps)
    if impl == "pallas_interpret":
        return rmsnorm_pallas(x, scale, eps, interpret=True)
    return rmsnorm_ref(x, scale, eps)
