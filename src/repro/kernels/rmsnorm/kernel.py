"""Fused RMSNorm Pallas TPU kernel.

Bandwidth-bound op: one pass over HBM (read x, write y) with the mean-square
reduction and scale fused — versus separate reduce + normalize + multiply.
Rows are tiled into VMEM blocks of ``block_rows`` x D; the model dim stays
whole (it is the reduction axis and D <= ~18k fits VMEM comfortably).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # [block_rows, D]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,            # [..., D]
    scale: jax.Array,        # [D]
    eps: float = 1e-6,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_blocks = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
