"""jit'd dispatching wrapper: model layout [B,S,H,D] <-> kernel layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,   # [B, S, H, D]
    k: jax.Array,   # [B, T, K, D]
    v: jax.Array,   # [B, T, K, Dv]
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return attention_ref(q, k, v, scale=scale, causal=causal, window=window)

    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, Dv)
    o = flash_attention_pallas(
        qh, kh, vh, scale=scale, causal=causal, window=window,
        q_per_kv=G, block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )
    return o.reshape(B, H, S, Dv).transpose(0, 2, 1, 3)
