"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, T, K, D]
    v: jax.Array,  # [B, T, K, Dv]
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    s = jnp.einsum("bskgd,btkd->bskgt", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    row = jnp.arange(S)[:, None]
    col = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= col <= row
    if window is not None:
        m &= col > row - window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    fully_masked = ~m.any(-1)
    p = jnp.where(fully_masked[None, :, None, None, None], 0.0, p)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)
