"""Flash attention Pallas TPU kernel (tiled online softmax).

Layout: q [BH, S, D] (one grid row per (batch, query-head)); k/v stay in
KV-head layout [BK, T, D] and the BlockSpec index map performs the GQA
head->kv-head arithmetic (no KV expansion in HBM).

Grid (bh, i, j) with the KV dim j innermost/sequential; the running max /
denominator / unnormalized accumulator live in revisited output blocks whose
index maps ignore j, i.e. VMEM-resident across the KV sweep (the standard TPU
flash pattern).  The final j step normalizes in place.

Blocks are MXU-aligned: block_q x D and block_k x D tiles with D the full head
dim (64-256), block_q = block_k = 128 by default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_k: int, t_real: int, s_real: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)           # [bq, D]
    k = k_ref[0].astype(jnp.float32)           # [bk, D]
    v = v_ref[0].astype(jnp.float32)           # [bk, Dv]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # [bq, bk]

    row = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = col < t_real
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[0]                           # [bq]
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * corr + p.sum(axis=1)
    acc = o_ref[0].astype(jnp.float32) * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(j == nk - 1)
    def _normalize():
        l = l_ref[0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (o_ref[0].astype(jnp.float32) / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "block_q", "block_k", "q_per_kv", "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,   # [BH, S, D]   (B*H query-head rows)
    k: jax.Array,   # [BK, T, D]   (B*K kv-head rows)
    v: jax.Array,   # [BK, T, Dv]
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    q_per_kv: int = 1,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, D = q.shape
    BK, T, Dv = v.shape
    s_pad = (-S) % block_q
    t_pad = (-T) % block_k
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0))) if s_pad else q
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0))) if t_pad else k
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0))) if t_pad else v
    Sp, Tp = S + s_pad, T + t_pad
    nq, nk = Sp // block_q, Tp // block_k
    G = q_per_kv
    # GQA head arithmetic: q rows are [b, h] row-major with h in [0, K*G);
    # the kv row is b*K + h//G, which equals bh // G exactly.
    assert BH == BK * G, (BH, BK, G)

    def q_map(b, i, j):
        return (b, i, 0)

    def kv_index(b, i, j):
        return (b // G, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, t_real=T, s_real=S,
        ),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, Dv), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, Dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Sp), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    o = out[0]
    if s_pad:
        o = o[:, :S]
    return o
