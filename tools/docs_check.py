"""Execute every fenced python block in docs/*.md so the docs cannot rot.

Each markdown file's ```python blocks are concatenated in order (snippets in
one page may build on each other) and executed in a fresh subprocess with the
repo's ``src`` on PYTHONPATH — exactly what a reader copy-pasting them into a
CPU-only environment would get.  Blocks fenced as plain ``` or any other
language are ignored.

    PYTHONPATH=src python tools/docs_check.py [docs/megaserve.md ...]
    make docs-check
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def blocks_of(path: Path) -> list[str]:
    return [m.group(1) for m in FENCE.finditer(path.read_text())]


def run_file(path: Path) -> tuple[bool, str]:
    blocks = blocks_of(path)
    if not blocks:
        return True, "no python blocks"
    script = "\n\n".join(blocks)
    env = dict(os.environ)
    src = str(ROOT / "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=900,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stderr or proc.stdout).splitlines()[-25:])
        return False, f"{len(blocks)} block(s) FAILED\n{tail}"
    return True, f"{len(blocks)} block(s) ok"


def main() -> int:
    targets = (
        [Path(a) for a in sys.argv[1:]]
        or sorted((ROOT / "docs").glob("*.md"))
    )
    failed = []
    for path in targets:
        ok, msg = run_file(path)
        status = "PASS" if ok else "FAIL"
        print(f"[{status}] {path.relative_to(ROOT)}: {msg}")
        if not ok:
            failed.append(path)
    if failed:
        print(f"\n{len(failed)} doc file(s) with broken snippets")
        return 1
    print("\nall doc snippets executed cleanly")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
