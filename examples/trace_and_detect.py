"""MegaScan end-to-end: simulate a 3-D-parallel cluster with a down-clocked
GPU and a degraded link, align clocks, run the 3-stage detector, export a
Chrome/Perfetto trace + diagnosis report.

    PYTHONPATH=src python examples/trace_and_detect.py --out artifacts/megascan
"""

import argparse
import json
from pathlib import Path

import numpy as np

from repro.core.simkit.engine import FaultModel
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing import (
    ClockModel,
    align_clocks,
    apply_alignment,
    detect,
    reconstruct_collectives,
    simulate_trace,
)
from repro.core.tracing.chrome import save_chrome


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="artifacts/megascan")
    ap.add_argument("--slow-rank", type=int, default=5)
    ap.add_argument("--slow-factor", type=float, default=0.5)
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    topo = Topology(dp=2, pp=2, tp=2)
    faults = FaultModel(
        compute_slowdown={args.slow_rank: args.slow_factor},
        link_slowdown={(2, 6): 0.3, (6, 2): 0.3},
        jitter=0.01,
    )
    clocks = ClockModel(offset_sigma=10e-3, drift_sigma=5e-5, seed=1)
    events, truth = simulate_trace(
        topo, ModelProfile(), n_micro=8, n_iters=3, faults=faults, clocks=clocks
    )
    print(f"simulated {len(events)} events on {topo.world} ranks "
          f"(ground truth: slow rank {truth['slow_ranks']}, "
          f"degraded links {truth['degraded_links']})")

    # raw vs aligned anchor spread
    raw_inst = reconstruct_collectives(events)
    raw_spread = np.median([
        max(i.ends.values()) - min(i.ends.values())
        for i in raw_inst if len(i.members) > 1
    ])
    alignment = align_clocks(events)
    aligned = apply_alignment(events, alignment)
    ali_inst = reconstruct_collectives(aligned)
    ali_spread = np.median([
        max(i.ends.values()) - min(i.ends.values())
        for i in ali_inst if len(i.members) > 1
    ])
    print(f"clock alignment: median collective end-spread "
          f"{raw_spread*1e3:.3f} ms -> {ali_spread*1e6:.1f} us")

    diag = detect(aligned, topo)
    print("\n== diagnosis ==")
    print(json.dumps(diag.summary(), indent=1))
    ok = diag.slow_ranks == truth["slow_ranks"]
    print("slow-rank detection:", "CORRECT" if ok else "MISMATCH")

    save_chrome(aligned, out / "trace.json")
    (out / "diagnosis.json").write_text(json.dumps(diag.summary(), indent=1))
    print(f"\nwrote {out}/trace.json (chrome://tracing / Perfetto) and "
          f"{out}/diagnosis.json")


if __name__ == "__main__":
    main()
