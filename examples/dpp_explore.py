"""MegaDPP exploration: DFC/BFC/wave trade-offs, best-effort planning under a
memory cap, telemetry-driven re-planning, and the real JAX pipeline executor.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/dpp_explore.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpp.executor import build_time_table, pipeline_apply, reference_apply
from repro.core.dpp.planner import Planner
from repro.core.dpp.schedule import sched_wave
from repro.core.simkit.engine import FaultModel
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing.detect import Diagnosis


def main() -> None:
    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(n_chunks=2, act_bytes=512 << 20, p2p_bytes=64 << 20)
    n_micro = 8

    print("== wave sweep (the DFC..BFC continuum) ==")
    print("wave  makespan_ms  peak_act_GiB  chunk0_grads_ready_ms")
    pl = Planner(topo, prof, n_micro=n_micro, memory_cap=1 << 62)
    for w in (1, 2, 4, 8):
        r = pl._evaluate(w)
        if r:
            mk, peak, gr = r
            print(f"{w:>4}  {mk*1e3:>10.2f}  {peak/2**30:>11.2f}  {gr*1e3:>18.2f}")

    print("\n== best-effort BFC under a 2 GiB activation cap ==")
    plan = Planner(topo, prof, n_micro=n_micro, memory_cap=2 << 30).plan()
    print(f"chosen: {plan.schedule_name} (wave={plan.wave}) "
          f"peak={plan.peak_memory/2**30:.2f} GiB makespan={plan.makespan*1e3:.2f} ms")

    print("\n== re-plan on MegaScan telemetry (stage 2 down-clocked) ==")
    pl2 = Planner(topo, prof, n_micro=n_micro, memory_cap=2 << 30)
    base = pl2.plan()
    new = pl2.replan(Diagnosis(slow_ranks=[2], candidate_ranks=[2], degraded_links=[]))
    print(f"healthy: wave={base.wave} makespan={base.makespan*1e3:.2f} ms | "
          f"degraded: wave={new.wave} makespan={new.makespan*1e3:.2f} ms")

    print("\n== JAX pipeline executor (4 stages x 2 chunks, 8 host devices) ==")
    S, C, B, D = 4, 2, 2, 16
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (S, C, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, B, D))
    mesh = jax.make_mesh((S,), ("stage",))
    for wave, name in ((1, "DFC"), (n_micro, "BFC")):
        table = build_time_table(sched_wave(n_micro, C, wave), S, C, n_micro)
        out = pipeline_apply(params, x, table, mesh=mesh,
                             block_fn=lambda p, h: jnp.tanh(h @ p))
        ref = reference_apply(params, x, lambda p, h: jnp.tanh(h @ p))
        err = float(jnp.abs(out - ref).max())
        print(f"{name}: schedule steps={table.steps}, max |pipe - ref| = {err:.2e}")
        assert err < 1e-5


if __name__ == "__main__":
    main()
