"""Quickstart: train a tiny LM for 30 steps with all four MegatronApp modules
active — MegaScan tracing, a MegaDPP plan, MegaScope probes, and a MegaFBD
placement check.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_config
from repro.core.dpp.planner import Planner
from repro.core.fbd.ranks import colocated_placement, evaluate_placement, plan_placement
from repro.core.scope import ProbeSpec, ScopeCollector
from repro.core.simkit.workload import ModelProfile, Topology
from repro.core.tracing import Tracer, detect, to_chrome
from repro.data.pipeline import DataConfig
from repro.train.loop import LoopConfig, train
from repro.train.optim import OptimizerConfig


def main() -> None:
    cfg = get_config("qwen2-0.5b", smoke=True).replace(name="quickstart-lm")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    scope = ScopeCollector(probes=[ProbeSpec("mlp_hidden", "stats")])
    tracer = Tracer(rank=0, enabled=True)

    print("== training (MegaScope probes + MegaScan tracing on) ==")
    state, history = train(
        cfg, OptimizerConfig(lr=3e-3, warmup_steps=10, total_steps=30),
        data, LoopConfig(n_steps=30, log_every=10),
        collector=scope, tracer=tracer,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} ({len(tracer.events)} trace events)")
    assert last < first, "training should reduce loss"

    print("\n== MegaScan: export chrome trace ==")
    doc = to_chrome(tracer.events)
    print(f"chrome trace with {len(doc['traceEvents'])} entries "
          "(load in chrome://tracing or Perfetto)")

    print("\n== MegaDPP: plan a pipeline schedule ==")
    plan = Planner(
        Topology(dp=1, pp=4, tp=1), ModelProfile(n_chunks=2),
        n_micro=8, memory_cap=8 << 30,
    ).plan()
    print(f"chosen schedule: {plan.schedule_name} (wave={plan.wave}), "
          f"makespan={plan.makespan*1e3:.2f} ms, "
          f"peak act mem={plan.peak_memory >> 20} MiB")

    print("\n== MegaFBD: placement on a heterogeneous cluster ==")
    speed = {d: 1.0 for d in range(4)} | {d: 0.4 for d in range(4, 8)}
    dec = evaluate_placement(plan_placement(8, speed))
    col = evaluate_placement(colocated_placement(8, speed))
    print(f"co-located: {col*1e3:.2f} ms | decoupled F/B: {dec*1e3:.2f} ms "
          f"({col/dec:.2f}x)")


if __name__ == "__main__":
    main()
