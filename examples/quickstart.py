"""Quickstart: one Session, all four MegatronApp modules as plugins.

Trains a tiny LM for 30 steps with MegaScan tracing, MegaScope probes,
MegaDPP pipeline planning, and MegaFBD placement/coordination attached —
each through the same ``ModulePlugin`` interface, toggled by name exactly
like ``python -m repro train --modules scan,scope,dpp,fbd``.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.app import RunConfig, Session
from repro.core.tracing import to_chrome


def main() -> None:
    cfg = RunConfig.for_workload(
        "train",
        arch="qwen2-0.5b",
        smoke=True,
        modules=("scan", "scope", "dpp", "fbd"),
    )
    cfg.train.steps = 30
    cfg.train.lr = 3e-3
    cfg.train.seq_len = 64
    cfg.train.log_every = 10
    cfg.dpp.memory_cap_gib = 8.0

    print("== training (all four modules attached as plugins) ==")
    session = Session(cfg)
    state, history = session.run()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({session.results['scan']['events']} trace events)")
    assert last < first, "training should reduce loss"

    print("\n== MegaScan: export chrome trace ==")
    doc = to_chrome(session.tracer.events)
    print(f"chrome trace with {len(doc['traceEvents'])} entries "
          "(load in chrome://tracing or Perfetto)")

    print("\n== MegaScope: probe captures ==")
    for key, hits in session.results["scope"]["captured"].items():
        print(f"  {key}: {hits} steps")

    print("\n== MegaDPP: planned pipeline schedule ==")
    dpp = session.results["dpp"]
    print(f"chosen schedule: {dpp['schedule']} (wave={dpp['wave']}), "
          f"makespan={dpp['makespan_ms']:.2f} ms, "
          f"peak act mem={dpp['peak_memory_mib']} MiB, "
          f"measured step p50={dpp['step_ms_p50']:.1f} ms")

    print("\n== MegaFBD: placement on a heterogeneous cluster ==")
    fbd = session.results["fbd"]
    print(f"co-located: {fbd['colocated_ms']:.2f} ms | "
          f"decoupled F/B: {fbd['decoupled_ms']:.2f} ms "
          f"({fbd['speedup']:.2f}x, "
          f"{fbd['coordinated_groups']} collectives coordinated)")


if __name__ == "__main__":
    main()
