"""End-to-end LM training driver with checkpoint/restart, on the Session API.

Presets scale from CPU-runnable to the deliverable-scale run:

    PYTHONPATH=src python examples/train_lm.py                  # tiny, CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m \
        --steps 300                                             # accelerator

The 100m preset is the "~100M parameters for a few hundred steps" end-to-end
configuration; on the CPU container use the default tiny preset to see the
same loop (data pipeline -> jit step -> async ckpt -> resume) behave.  The
presets are unregistered ``ModelConfig``s, so this also demonstrates driving
a Session with an explicit model config instead of an ``--arch`` lookup.
"""

import argparse
import logging

from repro.app import RunConfig, Session
from repro.configs.base import ModelConfig

PRESETS = {
    "tiny": dict(
        model=ModelConfig(
            name="tiny-lm", family="dense", num_layers=4, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512,
            vocab_size=2048, tie_embeddings=True, attn_kv_chunk=64,
            logits_chunk=64,
        ),
        seq=128, batch=8, lr=3e-3,
    ),
    "100m": dict(
        model=ModelConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
            vocab_size=32768, tie_embeddings=True,
        ),
        seq=1024, batch=64, lr=6e-4,
    ),
}


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = RunConfig.for_workload("train", modules=("scan",))
    cfg.train.steps = args.steps
    cfg.train.seq_len = p["seq"]
    cfg.train.global_batch = p["batch"]
    cfg.train.lr = p["lr"]
    cfg.train.log_every = max(args.steps // 12, 1)
    cfg.train.ckpt_dir = args.ckpt_dir or ""
    cfg.train.ckpt_every = max(args.steps // 4, 10)
    cfg.train.grad_accum = args.grad_accum

    session = Session(cfg, model_cfg=p["model"])
    state, history = session.run()
    print("\nstep  loss     ce       lr        wall_s")
    for h in history:
        print(f"{h['step']:>4}  {h['loss']:.4f}  {h.get('ce', 0):.4f}  "
              f"{h.get('lr', 0):.2e}  {h['wall_s']:>6}")
    assert history[-1]["loss"] < history[0]["loss"]
    print(f"\nloss decreased over {session.results['scan']['events']} traced "
          "steps — end-to-end pipeline OK")


if __name__ == "__main__":
    main()
