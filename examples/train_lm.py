"""End-to-end LM training driver with checkpoint/restart.

Presets scale from CPU-runnable to the deliverable-scale run:

    PYTHONPATH=src python examples/train_lm.py                  # tiny, CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m \
        --steps 300                                             # accelerator

The 100m preset is the "~100M parameters for a few hundred steps" end-to-end
configuration; on the CPU container use the default tiny preset to see the
same loop (data pipeline -> jit step -> async ckpt -> resume) behave.
"""

import argparse
import logging

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.train.loop import LoopConfig, train
from repro.train.optim import OptimizerConfig

PRESETS = {
    "tiny": dict(
        model=ModelConfig(
            name="tiny-lm", family="dense", num_layers=4, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512,
            vocab_size=2048, tie_embeddings=True, attn_kv_chunk=64,
            logits_chunk=64,
        ),
        seq=128, batch=8, lr=3e-3,
    ),
    "100m": dict(
        model=ModelConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
            vocab_size=32768, tie_embeddings=True,
        ),
        seq=1024, batch=64, lr=6e-4,
    ),
}


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = p["model"]
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq"],
                      global_batch=p["batch"])
    ocfg = OptimizerConfig(
        lr=p["lr"], warmup_steps=max(args.steps // 10, 5),
        total_steps=args.steps, schedule="cosine",
    )
    loop = LoopConfig(
        n_steps=args.steps, log_every=max(args.steps // 12, 1),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10),
        grad_accum=args.grad_accum,
    )
    state, history = train(cfg, ocfg, data, loop)
    print("\nstep  loss     ce       lr        wall_s")
    for h in history:
        print(f"{h['step']:>4}  {h['loss']:.4f}  {h.get('ce', 0):.4f}  "
              f"{h.get('lr', 0):.2e}  {h['wall_s']:>6}")
    assert history[-1]["loss"] < history[0]["loss"]
    print("\nloss decreased — end-to-end pipeline OK")


if __name__ == "__main__":
    main()
