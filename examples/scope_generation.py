"""MegaScope end-to-end: token-by-token generation with live probes, a
perturbation experiment, PCA token trajectories, and the HTML dashboard.

    PYTHONPATH=src python examples/scope_generation.py --out artifacts/scope
"""

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.scope import (
    PerturbSpec,
    ProbeSpec,
    ScopeCollector,
    generate_with_scope,
    pca_fit,
    pca_project,
    write_dashboard,
)
from repro.models import get_model
from repro.models import layers as L
from repro.models import lm as lm_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="artifacts/scope")
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 2, cfg.vocab_size)

    print("== generation with probes ==")
    scope = ScopeCollector(probes=[
        ProbeSpec("final_hidden", "stats"),
        ProbeSpec("attn_probs", "full"),      # decode path materializes probs
        ProbeSpec("mlp_hidden", "stats"),
    ])
    records, toks = generate_with_scope(cfg, params, prompt, args.steps, scope)
    for r in records[:5]:
        print(f"step {r.step}: token={r.token} p={r.prob:.3f} "
              f"top3={list(zip(r.topk_tokens[:3], [round(p,3) for p in r.topk_probs[:3]]))}")

    # attention heatmap from the last decode step (layer 0, head 0)
    attn = None
    for key, val in records[-1].captures.items():
        if key.startswith("attn_probs"):
            a = np.asarray(val)           # [L, B, 1, K, G, T]
            attn = a[0, 0, 0, 0, 0][None, :]  # 1 x T row for the last token
            attn = np.repeat(attn, 8, axis=0)
            break

    print("\n== PCA trajectory of the residual stream ==")
    hidden, _, _ = lm_mod.forward(cfg, params, {"tokens": prompt})
    h = np.asarray(hidden[0], np.float32)    # [S, D]
    fit = pca_fit(h, k=2)
    pts = pca_project(h, fit)
    print(f"explained variance: {[round(v, 3) for v in fit['explained']]}")

    print("\n== perturbation experiment: Gaussian noise on attention output ==")
    batch = {"tokens": prompt, "targets": jnp.roll(prompt, -1, axis=1)}
    base, _ = lm_mod.loss_fn(cfg, params, batch)
    rows = []
    for sigma in (0.0, 0.05, 0.2, 0.8):
        pert = ScopeCollector(perturbs=[PerturbSpec("att_resid", "gaussian", sigma)])
        loss, _ = lm_mod.loss_fn(cfg, params, batch, pert)
        rows.append((sigma, float(loss)))
        print(f"sigma={sigma:<5} loss={float(loss):.4f} (delta={float(loss)-float(base):+.4f})")

    dash = write_dashboard(
        out / "dashboard.html", records,
        attention=attn, pca_points=pts,
        meta=f"{cfg.name}: {args.steps} decode steps; perturbation sweep {rows}",
    )
    print(f"\nwrote {dash}")


if __name__ == "__main__":
    main()
